"""Configuration system for the repro framework.

Two config families:

* :class:`ModelConfig` — the assigned large-model architectures
  (dense / moe / ssm / hybrid / vlm / audio).  These are exercised at
  full scale only through the multi-pod dry-run (ShapeDtypeStruct, no
  allocation) and at reduced scale through smoke tests.

* :class:`PaperNetConfig` — the paper's own Table-1 networks (DNNs and
  small CNNs) used by the figure-for-figure benchmarks.

Everything is a frozen dataclass: hashable, usable as a jit static arg.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


# --------------------------------------------------------------------------
# Sub-configs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Fine-grained mixture-of-experts (DeepSeekMoE-style)."""
    num_experts: int                 # routed experts
    top_k: int
    num_shared_experts: int = 0      # always-on shared experts
    d_expert: int = 0                # intermediate dim of EACH expert
    moe_layer_period: int = 1        # every n-th layer is MoE
    moe_layer_offset: int = 0
    first_dense_layers: int = 0      # leading layers that use a dense FFN
    dense_d_ff: int = 0              # FFN dim of those dense layers
    router_aux_coef: float = 0.001   # load-balance loss coefficient
    capacity_factor: float = 1.25    # per-expert buffer slack
    # "softmax" (Switch/GShard) or "sigmoid" (DeepSeek-V3: sigmoid scores,
    # selection biased by a non-gradient balance term, weights normalised
    # over the selected experts)
    router_type: str = "softmax"


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                  # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64             # LoRA rank for data-dependent decay
    mix_lora: int = 32               # LoRA rank for token-shift mixing


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


# --------------------------------------------------------------------------
# Main model config
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # ---- attention flavour -------------------------------------------------
    attention: str = "gqa"           # gqa | mla | none (attn-free)
    qkv_bias: bool = False
    qk_norm: bool = False
    swa_window: int = 0              # 0 = full attention; >0 = sliding window
    # §Perf: pad query heads to this count with structurally-zero heads
    # (zero wq/wo slices + output mask => mathematically exact) so the
    # head axis divides the model axis.  0 = off.
    pad_heads_to: int = 0
    rope_theta: float = 10_000.0
    mla: Optional[MLAConfig] = None

    # ---- hybrid / ssm ------------------------------------------------------
    # every `attn_layer_period`-th layer (at `attn_layer_offset`) is attention,
    # the rest are `ssm_kind` layers.  attn_layer_period=1 -> all attention,
    # attn_layer_period=0 -> attention-free.
    attn_layer_period: int = 1
    attn_layer_offset: int = 0
    ssm_kind: str = "none"           # mamba | rwkv6 | none
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # ---- MoE ---------------------------------------------------------------
    moe: Optional[MoEConfig] = None

    # ---- encoder-decoder (audio) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0

    # ---- modality frontend stub (vlm / audio) ------------------------------
    frontend: str = "none"           # none | vision | audio
    num_frontend_tokens: int = 0     # image-patch / mel-frame embeddings

    # ---- serving -----------------------------------------------------------
    # paged decode attention implementation: "xla" (paged_read gather +
    # masked softmax — the reference oracle) or "pallas" (fused
    # page-table-gather + online-softmax kernel, kernels/paged_decode.py;
    # interpret-mode on CPU).  Greedy outputs are pinned equal.
    decode_kernel: str = "xla"

    # ---- extras ------------------------------------------------------------
    mtp_depth: int = 0               # DeepSeek-V3 multi-token prediction heads
    mlp_gated: bool = True           # SwiGLU (3 mats) vs plain 2-mat MLP
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # master weights

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    # layer-kind pattern (drives scan-over-layers model assembly)
    # ------------------------------------------------------------------
    def mixer_kind(self, layer_idx: int) -> str:
        """'attn' | 'mamba' | 'rwkv6' for a given layer index."""
        if self.attn_layer_period == 0:
            return self.ssm_kind
        if self.attn_layer_period == 1:
            return "attn"
        if layer_idx % self.attn_layer_period == self.attn_layer_offset:
            return "attn"
        return self.ssm_kind

    def ffn_kind(self, layer_idx: int) -> str:
        """'mlp' | 'moe' for a given layer index."""
        m = self.moe
        if m is None:
            return "mlp"
        if layer_idx < m.first_dense_layers:
            return "mlp"
        if layer_idx % m.moe_layer_period == m.moe_layer_offset % m.moe_layer_period:
            return "moe"
        return "mlp"

    def layer_pattern(self) -> Tuple[Tuple[str, str], ...]:
        """Per-layer (mixer, ffn) kinds for the whole (decoder) stack."""
        return tuple(
            (self.mixer_kind(i), self.ffn_kind(i)) for i in range(self.num_layers)
        )

    def block_structure(self) -> Tuple[Tuple[Tuple[str, str], ...], Tuple[Tuple[str, str], ...], int]:
        """Split layers into (unrolled prefix, repeating super-block, n_repeats).

        The repeating super-block is scanned with jax.lax.scan so the HLO
        contains ONE copy of the block body regardless of depth — essential
        for compiling 60+ layer models under SPMD partitioning on CPU.
        """
        pat = self.layer_pattern()
        n = len(pat)
        # prefix = leading layers that break the periodic pattern
        prefix_len = 0
        if self.moe is not None and self.moe.first_dense_layers:
            prefix_len = self.moe.first_dense_layers
        body = pat[prefix_len:]
        # find the shortest period of the body pattern
        period = len(body)
        for cand in range(1, len(body) + 1):
            if len(body) % cand:
                continue
            if body == body[:cand] * (len(body) // cand):
                period = cand
                break
        return pat[:prefix_len], body[:period], len(body) // period

    # ------------------------------------------------------------------
    # parameter counting (for roofline MODEL_FLOPS and memory estimates)
    # ------------------------------------------------------------------
    def attn_params(self) -> int:
        d = self.d_model
        if self.attention == "mla":
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_hd
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.num_heads * m.v_head_dim * d
            return p
        hd = self.head_dim
        p = d * self.num_heads * hd            # q
        p += 2 * d * self.num_kv_heads * hd    # k, v
        p += self.num_heads * hd * d           # o
        if self.qkv_bias:
            p += (self.num_heads + 2 * self.num_kv_heads) * hd
        return p

    def mamba_params(self) -> int:
        mc = self.mamba or MambaConfig()
        d_in = mc.expand * self.d_model
        p = self.d_model * 2 * d_in                      # in_proj (x, z)
        p += d_in * mc.d_conv                            # conv1d
        p += d_in * (mc.d_state * 2 + d_in // 16)        # B, C, dt projections
        p += d_in * mc.d_state                           # A
        p += d_in * self.d_model                         # out_proj
        return p

    def rwkv_params(self) -> int:
        rc = self.rwkv or RWKVConfig()
        d = self.d_model
        p = 4 * d * d                                    # r, k, v, o (time-mix)
        p += d * d                                       # gate
        p += 2 * (d * rc.decay_lora + rc.decay_lora * d) # decay lora + u
        p += 5 * (d * rc.mix_lora + rc.mix_lora * d)     # token-shift loras
        p += 2 * d * self.d_ff                           # channel-mix (r,k)
        return p

    @property
    def _mlp_mats(self) -> int:
        return 3 if self.mlp_gated else 2

    def ffn_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "mlp":
            return self._mlp_mats * d * self.d_ff
        m = self.moe
        per_exp = self._mlp_mats * d * m.d_expert
        return (m.num_experts + m.num_shared_experts) * per_exp + d * m.num_experts

    def ffn_active_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "mlp":
            return self._mlp_mats * d * self.d_ff
        m = self.moe
        per_exp = self._mlp_mats * d * m.d_expert
        return (m.top_k + m.num_shared_experts) * per_exp + d * m.num_experts

    def _mixer_params(self, kind: str) -> int:
        return {"attn": self.attn_params(),
                "mamba": self.mamba_params(),
                "rwkv6": self.rwkv_params()}[kind]

    def param_count(self, active_only: bool = False) -> int:
        """Total (or activated) parameter count for MODEL_FLOPS = 6·N·D."""
        total = 2 * self.vocab_size * self.d_model       # embed + unembed
        if self.tie_embeddings:
            total -= self.vocab_size * self.d_model
        ffn_p = self.ffn_active_params if active_only else self.ffn_params
        for (mixer, ffn) in self.layer_pattern():
            if mixer == "attn":
                total += self.attn_params()
            elif mixer == "mamba":
                total += self.mamba_params()
            elif mixer == "rwkv6":
                # rwkv block includes its own channel-mix ffn
                total += self.rwkv_params()
                continue
            total += ffn_p(ffn)
        if self.is_encoder_decoder:
            for _ in range(self.encoder_layers):
                total += self.attn_params() + ffn_p("mlp")
            # decoder cross-attention
            total += self.num_layers * self.attn_params()
        return total

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Paper Table-1 networks
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PaperNetConfig:
    """A network from Table 1 of Vishnu et al. 2016."""
    name: str
    kind: str                        # dnn | cnn
    layer_sizes: Tuple[int, ...] = ()        # dnn: in-hidden...-out
    # cnn fields (paper: 5x5 conv, stride 1, relu, 2x2 maxpool, sigmoid fc)
    image_hw: Tuple[int, int] = (0, 0)
    image_channels: int = 0
    conv_channels: Tuple[int, ...] = ()
    fc_size: int = 0
    num_classes: int = 0
    dataset: str = ""


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
