"""Config registry: `get_config(arch_id)` + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    ModelConfig, MoEConfig, MambaConfig, RWKVConfig, MLAConfig,
    PaperNetConfig, InputShape, INPUT_SHAPES,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)
from repro.configs import (
    rwkv6_1p6b, deepseek_coder_33b, deepseek_moe_16b, deepseek_v3_671b,
    llava_next_mistral_7b, granite_20b, jamba_v0p1_52b, qwen2p5_32b,
    qwen3_1p7b, seamless_m4t_large_v2,
)
from repro.configs.paper_nets import PAPER_NETS

ARCHITECTURES = {
    m.CONFIG.name: m.CONFIG
    for m in (
        rwkv6_1p6b, deepseek_coder_33b, deepseek_moe_16b, deepseek_v3_671b,
        llava_next_mistral_7b, granite_20b, jamba_v0p1_52b, qwen2p5_32b,
        qwen3_1p7b, seamless_m4t_large_v2,
    )
}

# Archs that must NOT lower long_500k at all (documented skip in DESIGN.md §4)
LONG_500K_SKIPS = {"seamless-m4t-large-v2"}
# Dense/full-attention archs that get the sliding-window variant for long_500k
SWA_FOR_LONG = {
    "deepseek-coder-33b", "granite-20b", "qwen2.5-32b", "qwen3-1.7b",
    "deepseek-moe-16b", "deepseek-v3-671b", "llava-next-mistral-7b",
}
SWA_WINDOW = 8192


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[arch]


def config_for_shape(arch: str, shape_name: str) -> ModelConfig:
    """Arch config adjusted for an input shape (SWA for long_500k)."""
    cfg = get_config(arch)
    if shape_name == "long_500k":
        if arch in LONG_500K_SKIPS:
            raise ValueError(f"{arch} skips long_500k (DESIGN.md §4)")
        if arch in SWA_FOR_LONG:
            cfg = cfg.with_overrides(swa_window=SWA_WINDOW)
    return cfg


def smoke_config(arch: str) -> ModelConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts.

    Keeps every structural feature (GQA ratio, MLA, MoE shared/routed,
    hybrid interleave, enc-dec, frontend stub) at toy scale for CPU tests.
    """
    cfg = get_config(arch)
    kw = dict(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 4) * 4 // max(cfg.num_heads, 1)) or 1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
    # keep the GQA ratio where possible
    ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    kw["num_kv_heads"] = max(1, 4 // min(ratio, 4))
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_expert=128,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            dense_d_ff=512,
            # generous capacity: smoke tests assert exact path equality;
            # capacity-drop semantics are tested separately
            capacity_factor=8.0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        kw["head_dim"] = 48
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=32, decay_lora=16, mix_lora=8)
        kw["num_heads"] = 8   # 256 / 32
        kw["num_kv_heads"] = 8
        kw["head_dim"] = 32
    if cfg.ssm_kind == "mamba":
        kw["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2)
        # keep 1:7-style interleave but fit in 2 layers: attn at layer 1
        kw["attn_layer_period"] = 2
        kw["attn_layer_offset"] = 1
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = 2
    if cfg.frontend == "vision":
        kw["num_frontend_tokens"] = 16
    return cfg.with_overrides(**kw)


__all__ = [
    "ModelConfig", "MoEConfig", "MambaConfig", "RWKVConfig", "MLAConfig",
    "PaperNetConfig", "InputShape", "INPUT_SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "ARCHITECTURES", "PAPER_NETS", "LONG_500K_SKIPS", "SWA_FOR_LONG",
    "get_config", "config_for_shape", "smoke_config",
]
