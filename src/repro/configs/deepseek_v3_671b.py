"""DeepSeek-V3 671B — MLA + fine-grained MoE + multi-token prediction.

[arXiv:2412.19437] 61 layers, d_model=7168, 128 heads, MLA
(q_lora=1536, kv_lora=512, nope=128, rope=64, v=128), expert d_ff=2048,
vocab=129280.  1 shared + 256 routed experts, top-8; first 3 layers
dense (d_ff=18432).  MTP depth 1.
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: kv "heads" = heads (latent-compressed)
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        num_shared_experts=1,
        d_expert=2048,
        first_dense_layers=3,
        dense_d_ff=18432,
        router_type="sigmoid",   # V3: aux-free bias-balanced sigmoid router
    ),
    mtp_depth=1,
)
