"""LLaVA-NeXT (Mistral-7B backbone) — VLM with anyres tiling.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] Language backbone: 32 layers,
d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=32000.  The vision
tower (CLIP ViT-L/336 + 2-layer MLP projector) is a STUB per the brief:
input_specs() supplies precomputed patch embeddings.  anyres tiling:
base 576 patches + 4 tiles x 576 = 2880 image tokens.  Mistral's native
sliding window (4096) makes long_500k legitimate.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    frontend="vision",
    num_frontend_tokens=2880,   # anyres: (1 base + 4 tiles) x 576 patches
)
