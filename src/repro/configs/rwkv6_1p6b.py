"""RWKV-6 "Finch" 1.6B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] Eagle and Finch: RWKV with Matrix-Valued States and
Dynamic Recurrence.  24 layers, d_model=2048, d_ff=7168, vocab=65536,
head_dim=64 (32 heads).  Sub-quadratic by construction -> runs long_500k.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # 2048 / head_dim 64
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attention="none",
    attn_layer_period=0,     # attention-free
    ssm_kind="rwkv6",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
)
