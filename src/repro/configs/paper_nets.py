"""The paper's own networks — Table 1 of Vishnu et al. 2016.

| Data set | Algo | Network Architecture        |
|----------|------|-----------------------------|
| Adult    | DNN  | 123-200-100-2               |
| Acoustic | DNN  | 50-200-100-3                |
| MNIST    | DNN  | 784-200-100-10              |
| MNIST    | CNN  | 32,64 (CONV), 1024 (FULL)   |
| CIFAR10  | DNN  | 3072-200-100-10             |
| CIFAR10  | CNN  | 32,64 (CONV), 1024 (FULL)   |
| HIGGS    | DNN  | 28-1024-2                   |

CNNs: 5x5 conv windows, stride 1, ReLU, each followed by 2x2 max-pool;
then sigmoid fully-connected layer(s), then softmax output (paper §4.1).
"""
from repro.configs.base import PaperNetConfig

ADULT_DNN = PaperNetConfig(
    name="adult-dnn", kind="dnn", layer_sizes=(123, 200, 100, 2),
    dataset="adult")
ACOUSTIC_DNN = PaperNetConfig(
    name="acoustic-dnn", kind="dnn", layer_sizes=(50, 200, 100, 3),
    dataset="acoustic")
MNIST_DNN = PaperNetConfig(
    name="mnist-dnn", kind="dnn", layer_sizes=(784, 200, 100, 10),
    dataset="mnist")
MNIST_CNN = PaperNetConfig(
    name="mnist-cnn", kind="cnn", image_hw=(28, 28), image_channels=1,
    conv_channels=(32, 64), fc_size=1024, num_classes=10, dataset="mnist")
CIFAR10_DNN = PaperNetConfig(
    name="cifar10-dnn", kind="dnn", layer_sizes=(3072, 200, 100, 10),
    dataset="cifar10")
CIFAR10_CNN = PaperNetConfig(
    name="cifar10-cnn", kind="cnn", image_hw=(32, 32), image_channels=3,
    conv_channels=(32, 64), fc_size=1024, num_classes=10, dataset="cifar10")
HIGGS_DNN = PaperNetConfig(
    name="higgs-dnn", kind="dnn", layer_sizes=(28, 1024, 2),
    dataset="higgs")

PAPER_NETS = {c.name: c for c in (
    ADULT_DNN, ACOUSTIC_DNN, MNIST_DNN, MNIST_CNN,
    CIFAR10_DNN, CIFAR10_CNN, HIGGS_DNN)}
