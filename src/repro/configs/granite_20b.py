"""Granite 20B Code — llama-architecture dense with MQA.

[arXiv:2405.04324] 52 layers, d_model=6144, 48 heads (MQA kv=1),
d_ff=24576, vocab=49152.  long_500k uses the sliding-window variant.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_gated=False,       # GPT-BigCode-style plain MLP (gelu)
)
