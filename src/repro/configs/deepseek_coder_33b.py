"""DeepSeek-Coder 33B — llama-architecture dense code model.

[arXiv:2401.14196] 62 layers, d_model=7168, 56 heads (GQA kv=8),
d_ff=19200, vocab=32256.  Pure full attention; long_500k uses the
sliding-window variant (swa_window=8192) per DESIGN.md §4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
)
