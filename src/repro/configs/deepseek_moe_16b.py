"""DeepSeekMoE 16B — fine-grained expert segmentation + shared experts.

[arXiv:2401.06066] 28 layers, d_model=2048, 16 heads (kv=16 i.e. MHA),
expert d_ff=1408, vocab=102400.  2 shared + 64 routed experts, top-6.
First layer uses a dense FFN (d_ff=10944, model card value).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        d_expert=1408,
        first_dense_layers=1,
        dense_d_ff=10944,
    ),
)
