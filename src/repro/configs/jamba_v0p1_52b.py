"""Jamba v0.1 52B — hybrid Mamba + attention (1:7) with MoE.

[arXiv:2403.19887] 32 layers, d_model=4096, 32 heads (GQA kv=8),
d_ff=14336, vocab=65536.  Attention every 8th layer (offset 4); MoE
(16 experts, top-2) every other layer (offset 1).  Mamba: d_state=16,
d_conv=4, expand=2.  Hybrid -> runs long_500k natively.
"""
from repro.configs.base import ModelConfig, MoEConfig, MambaConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm_kind="mamba",
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        num_shared_experts=0,
        d_expert=14336,
        moe_layer_period=2,
        moe_layer_offset=1,
    ),
)
