"""SeamlessM4T-Large v2 — encoder-decoder multimodal translation backbone.

[arXiv:2308.11596] Text decoder: 24 layers, d_model=1024, 16 heads
(MHA kv=16), d_ff=8192, vocab=256206; speech/text encoder: 24 layers.
The audio frontend (mel-spectrogram + conformer feature extractor) is a
STUB per the brief: input_specs() supplies precomputed frame embeddings.
long_500k is SKIPPED for this arch (DESIGN.md §4): a 524k-frame source
in one utterance is outside the enc-dec speech family's operating range.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    is_encoder_decoder=True,
    encoder_layers=24,
    frontend="audio",
    num_frontend_tokens=0,   # encoder input IS the frame-embedding sequence
)
