"""Mamba-1 selective scan — Pallas TPU kernel.

TPU adaptation: the GPU kernel assigns one thread per channel and
serialises over time in registers.  On TPU we tile the channel dim
(dI) over the grid's second axis so each step's elementwise update
vectorises over (block_dI lanes x d_state sublanes) on the VPU, carry
the (block_dI, dS) state in VMEM scratch across the sequential chunk
axis, and walk time with a fori_loop inside each chunk:

  grid = (B, dI/block_dI, T/C)   (last axis sequential)
  per step t in chunk:  h = exp(dt_t * A) * h + (dt_t x_t) B_t
                        y_t = h @ C_t + D x_t

VMEM per step ≈ (2·C·bI + 2·C·dS + 3·bI·dS + C·bI)·4B
             ≈ 1.1 MB at C=64, bI=512, dS=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, s0_ref,
                  y_ref, sT_ref, h_scr, *, chunk, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = s0_ref[...].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)        # (C, bI)
    dt = dt_ref[...].astype(jnp.float32)      # (C, bI)
    A = A_ref[...].astype(jnp.float32)        # (bI, dS)
    Bm = B_ref[...].astype(jnp.float32)       # (C, dS)
    Cm = C_ref[...].astype(jnp.float32)       # (C, dS)
    D = D_ref[...].astype(jnp.float32)        # (1, bI)

    def step(t, carry):
        h, ys = carry
        da = jnp.exp(dt[t][:, None] * A)                  # (bI, dS)
        h = da * h + (dt[t] * x[t])[:, None] * Bm[t][None, :]
        y = jnp.sum(h * Cm[t][None, :], axis=1) + D[0] * x[t]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    y_ref[...] = ys.astype(y_ref.dtype)
    h_scr[...] = h

    @pl.when(ci == n_chunks - 1)
    def _fin():
        sT_ref[...] = h_scr[...].astype(sT_ref.dtype)


def mamba_pallas(x, dt, A, B, C, D, state, *, chunk=64, block_di=512,
                 interpret=None):
    """x, dt: (Bb,T,dI); A: (dI,dS); B,C: (Bb,T,dS); D: (dI,);
    state: (Bb,dI,dS)."""
    Bb, T, dI = x.shape
    dS = A.shape[1]
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    chunk = min(chunk, T)
    block_di = min(block_di, dI)
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    ndi = dI // block_di
    D2 = D.reshape(1, dI)

    kernel = functools.partial(_mamba_kernel, chunk=chunk, n_chunks=nc)
    y, sT = pl.pallas_call(
        kernel,
        grid=(Bb, ndi, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, block_di),
                         lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((None, chunk, block_di),
                         lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((block_di, dS), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((None, chunk, dS), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((None, chunk, dS), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, block_di), lambda b, di, ci: (0, di)),
            pl.BlockSpec((None, block_di, dS),
                         lambda b, di, ci: (b, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, block_di),
                         lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((None, block_di, dS),
                         lambda b, di, ci: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, T + pad, dI), x.dtype),
            jax.ShapeDtypeStruct((Bb, dI, dS), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_di, dS), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D2, state)
    return y[:, :T], sT
