"""Pure-jnp oracles for every kernel.  Naive, obviously-correct forms —
the ground truth that ops.py fast paths and the Pallas kernels are
tested against (tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,S,h,hd); k,v: (B,T,hk,hd) with h % hk == 0 -> (B,S,h,hd)."""
    B, S, h, hd = q.shape
    T, hk = k.shape[1], k.shape[2]
    if h != hk:
        k = jnp.repeat(k, h // hk, axis=2)
        v = jnp.repeat(v, h // hk, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q, k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    qpos = jnp.arange(S)[:, None] + (T - S)   # right-aligned queries
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)


def wkv6_ref(r, k, v, w_log, u, state):
    """RWKV-6 WKV recurrence, naive scan over time.

    r,k,v,w_log: (B,T,H,K); u: (H,K); state: (B,H,K,V) with V == K.
      y_t[v]   = sum_k r_t[k] * (S_t[k,v] + u[k] * k_t[k] * v_t[v])
      S_{t+1}  = diag(exp(w_log_t)) S_t + k_t v_t^T
    Returns y: (B,T,H,K), final state.
    """
    r, k, v, w_log = (a.astype(jnp.float32) for a in (r, k, v, w_log))
    u = u.astype(jnp.float32)
    state = state.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,K) each
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., :, None] * kv)
        S = jnp.exp(wt)[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w_log))
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def mamba_ref(x, dt, A, B, C, D, state):
    """Mamba-1 selective scan, naive scan over time.

    x, dt: (Bb,T,dI); A: (dI,dS); B,C: (Bb,T,dS); D: (dI,)
    state: (Bb,dI,dS).
      h_t = exp(dt_t * A) h_{t-1} + (dt_t * x_t) B_t^T
      y_t = h_t C_t + D * x_t
    Returns y: (Bb,T,dI), final state.
    """
    x, dt, B, C = (a.astype(jnp.float32) for a in (x, dt, B, C))
    A = A.astype(jnp.float32)
    D = D.astype(jnp.float32)
    state = state.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                          # (Bb,dI),(Bb,dI),(Bb,dS)
        da = jnp.exp(dtt[..., None] * A)               # (Bb,dI,dS)
        h = da * h + (dtt * xt)[..., None] * Bt[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, Ct) + D * xt
        return h, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (x, dt, B, C))
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), final
