"""Flash attention — Pallas TPU kernel.

TPU adaptation (not a CUDA port): the online-softmax loop is expressed
as a sequential grid dimension over KV blocks with the running
(max, sum, accumulator) carried in VMEM scratch; each grid step does an
MXU matmul on a (block_q x head_dim) x (head_dim x block_kv) tile.
Block shapes are MXU-aligned (multiples of 128 in the contracted dims)
and sized so q/k/v/acc tiles fit VMEM:

    VMEM per step ≈ (bq·hd + 2·bkv·hd + bq·bkv + bq·hd) · 4B
    (256·128 + 2·512·128 + 256·512 + 256·128) · 4 ≈ 1.2 MB  « 16 MB

GQA is handled in the index maps: the KV block row for flattened
query-head ``bh`` is ``(bh // g)`` where g = h // hk.

Causal/sliding-window masking is positional (supports right-aligned
queries for decode-style calls).  Fully-masked KV blocks are skipped
with ``pl.when`` (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_kv, seq_q, seq_kv, causal, window,
                  n_kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = (qi * block_q + jax.lax.iota(jnp.int32, block_q)
             + (seq_kv - seq_q))                         # right-aligned
    k_pos = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)

    # block-level visibility test (skip fully-masked blocks)
    first_q, last_q = qi * block_q + (seq_kv - seq_q), \
        qi * block_q + block_q - 1 + (seq_kv - seq_q)
    first_k = ki * block_kv
    visible = True
    if causal:
        visible = jnp.asarray(first_k <= last_q)
    if window:
        visible = jnp.logical_and(
            visible, first_k + block_kv - 1 > first_q - window)

    @pl.when(visible)
    def _step():
        q = q_ref[...].astype(jnp.float32)               # (bq, hd)
        k = k_ref[...].astype(jnp.float32)               # (bkv, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[...].astype(jnp.float32)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                  # fully-masked rows
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0,
                           block_q=None, block_kv=None, interpret=None):
    """q: (B, S, h, hd); k, v: (B, T, hk, hd) -> (B, S, h, hd).

    block_q/block_kv default to the shared tuning surface
    (``kernels.ops.set_flash_blocks`` — swept and recorded by
    ``benchmarks/decode_microbench.py``)."""
    from repro.kernels.ops import get_flash_blocks
    dq, dkv = get_flash_blocks()
    block_q = dq if block_q is None else block_q
    block_kv = dkv if block_kv is None else block_kv
    B, S, h, hd = q.shape
    T, hk = k.shape[1], k.shape[2]
    g = h // hk
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    pad_q = (-S) % block_q
    pad_kv = (-T) % block_kv
    scale = 1.0 / np.sqrt(hd)

    qf = q.transpose(0, 2, 1, 3).reshape(B * h, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * hk, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * hk, T, hd)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kf = jnp.pad(kf, ((0, 0), (0, pad_kv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_kv), (0, 0)))
    nq = qf.shape[1] // block_q
    nkv = kf.shape[1] // block_kv

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        seq_q=S, seq_kv=T, causal=causal, window=window, n_kv_blocks=nkv)

    out = pl.pallas_call(
        kernel,
        grid=(B * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((None, block_kv, hd),
                         lambda b, qi, ki, g=g: (b // g, ki, 0)),
            pl.BlockSpec((None, block_kv, hd),
                         lambda b, qi, ki, g=g: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd),
                               lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * h, S + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :S].reshape(B, h, S, hd).transpose(0, 2, 1, 3)
    return out
