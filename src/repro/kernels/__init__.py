"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three artifacts (tests sweep shapes/dtypes):
  <name>.py — pl.pallas_call + explicit VMEM BlockSpecs (TPU target;
              interpret=True on CPU)
  ops.py    — jit'd wrappers with implementation dispatch
              (ref | chunked-jnp | pallas)
  ref.py    — pure-jnp oracles
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (
    flash_attention, wkv6, wkv6_step, mamba_scan, mamba_step,
    set_default_impl, get_default_impl,
    set_flash_blocks, get_flash_blocks,
)
from repro.kernels.paged_decode import (paged_flash_decode,
                                        paged_flash_decode_mla)

__all__ = ["ops", "ref", "flash_attention", "wkv6", "wkv6_step",
           "mamba_scan", "mamba_step", "set_default_impl",
           "get_default_impl", "set_flash_blocks", "get_flash_blocks",
           "paged_flash_decode", "paged_flash_decode_mla"]
