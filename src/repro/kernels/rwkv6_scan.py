"""RWKV-6 WKV recurrence — Pallas TPU kernel (chunked form).

TPU adaptation: GPU RWKV kernels serialise over time with one thread per
channel; on TPU we use the chunked linear-attention form so the inner
work is dense (C x C) / (C x K) matmuls on the MXU, with the (K, V)
state carried across chunks in VMEM scratch:

  grid = (B*H, T/C), second dim sequential.
  per chunk (all fp32 in VMEM):
    L     = cumsum(w_log)                 (C, K)   log-decays
    y_st  = (r * exp(L - w)) @ S          state contribution (MXU)
    W     = exp(clip(Lprev_t - L_j)) strictly-lower-tri pairwise decay
    A     = ((r * eLp) @ (k / eL)^T) masked by tri  -> intra-chunk (MXU)
            computed stably as sum_k r_t k_j exp(Lprev_t - L_j)
    y     = y_st + A @ v + (r·u·k) v      diag bonus
    S'    = exp(L_C) * S + (k * exp(L_C - L))^T @ v

VMEM per step ≈ (5·C·K + C·C·K + K·K)·4B ≈ 1.3 MB at C=32, K=64.

The pairwise (C, C, K) tensor is inherent to RWKV-6's per-channel decay
(this is exactly why it needs a custom kernel on every platform); C is
chosen small enough to keep it VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CLIP = -60.0


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sT_ref, s_scr, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)          # (C, K)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    wl = w_ref[...].astype(jnp.float32)         # log-decay <= 0
    u = u_ref[...].astype(jnp.float32)          # (1, K)
    S = s_scr[...]                              # (K, V)

    L = jnp.cumsum(wl, axis=0)
    Lprev = L - wl
    r_dec = r * jnp.exp(Lprev)
    y_state = jax.lax.dot(r_dec, S, preferred_element_type=jnp.float32)

    # intra-chunk pairwise scores with per-channel decay
    D = Lprev[:, None, :] - L[None, :, :]       # (C, C, K)
    tri = (jax.lax.iota(jnp.int32, chunk)[:, None]
           > jax.lax.iota(jnp.int32, chunk)[None, :])
    W = jnp.exp(jnp.clip(D, _CLIP, 0.0))
    scores = jnp.einsum("tk,jk,tjk->tj", r, k, W,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(tri, scores, 0.0)
    y_intra = jax.lax.dot(scores, v, preferred_element_type=jnp.float32)

    coef = jnp.sum(r * u * k, axis=1, keepdims=True)    # (C, 1)
    y_ref[...] = (y_state + y_intra + coef * v).astype(y_ref.dtype)

    Llast = L[-1:, :]
    k_sc = k * jnp.exp(Llast - L)
    s_scr[...] = (jnp.exp(Llast[0])[:, None] * S
                  + jax.lax.dot(k_sc.T, v,
                                preferred_element_type=jnp.float32))

    @pl.when(ci == n_chunks - 1)
    def _fin():
        sT_ref[...] = s_scr[...].astype(sT_ref.dtype)


def wkv6_pallas(r, k, v, w_log, u, state, *, chunk=32, interpret=None):
    """r,k,v,w_log: (B,T,H,K); u: (H,K); state: (B,H,K,V)."""
    B, T, H, K = r.shape
    V = state.shape[-1]
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    chunk = min(chunk, T)
    pad = (-T) % chunk
    args = [a.transpose(0, 2, 1, 3).reshape(B * H, T, K)
            for a in (r, k, v, w_log)]
    if pad:
        args = [jnp.pad(a, ((0, 0), (0, pad), (0, 0))) for a in args]
    nc = args[0].shape[1] // chunk
    uf = u                                        # (H, K)
    s0 = state.reshape(B * H, K, V)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=nc)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((None, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((None, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((None, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((None, K), lambda b, ci, H=H: (b % H, 0)),
            pl.BlockSpec((None, K, V), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((None, K, V), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T + pad, K), r.dtype),
            jax.ShapeDtypeStruct((B * H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(*args, uf, s0)
    y = y[:, :T].reshape(B, H, T, K).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, K, V)
