"""Paged flash-decode — Pallas TPU kernel for the serving hot path.

Fuses the page-table gather with the online-softmax attention inner
loop: the XLA reference path (``models.attention``: ``paged_read`` →
``masked_attention``) first materialises a slot-major
``(B, table_width * page_size, ...)`` gather of the token-major pool
and then attends over it — two passes over the slot's KV bytes and a
full-width softmax.  Here the page table is a SCALAR-PREFETCH operand
(``pltpu.PrefetchScalarGridSpec``): the KV BlockSpec index map reads
``table[b, w]`` to stream each physical page straight from the pool
into VMEM, so the gather never exists as a tensor and each page's
scores fold into the running (max, sum, accumulator) as it arrives.

Grid: ``(B, hk, W)`` (MLA: ``(B, W)``) with the page axis innermost and
sequential — the online-softmax state lives in VMEM scratch across the
W steps, exactly the ``kernels.flash_attention`` schedule with the
block index indirected through the page table.

Masking follows the paged contract (see ``models.attention``):
  * per-slot causal — key at logical position ``t`` (page ``w`` holds
    ``w*page_size + [0, page_size)``) is visible to query ``(b, s)``
    iff ``t <= q_positions[b, s]`` (sliding window when set);
  * pages past a slot's write head are NEVER visible (every visible
    position has been written by the slot), so unallocated table
    entries (0 = the trash page) only back positions the mask already
    kills — trash-page garbage cannot leak into the output;
  * fully-masked pages are skipped with ``pl.when`` (no MXU work), so
    a slot pays for the pages it has written, not the table width.

Multi-query verify shape: speculative decode's verify forward is this
same kernel at query width ``S = spec_decode`` — a q-block of S rows
per (slot, kv-head) grid step with per-query positions, exactly the
shape prefill chunks already lower.  The per-query-row causal mask is
what makes the scheduler's rewind-rollback sound: stale K/V written by
rejected drafts sits at positions strictly greater than every live
query's position, so it is invisible until the next verify chunk
overwrites it in place.

GQA head-group tiling: queries are laid out ``(B, hk, g*S, hd)`` so
one grid step attends a whole kv-head's group against its page — the
MXU tile is ``(g*S, hd) x (hd, page_size)``.  The absorbed-MLA variant
scores ``q_latent·ckv + q_rope·krope`` against the latent pool
(one kv head, ``dv = kv_lora_rank``) and returns the latent-space
output for the caller's ``w_uv`` up-projection.

On CPU the kernels run in interpret mode (plain-JAX lowering: jit-able,
scan-able, GSPMD-partitionable — the serve-mesh tests run them under
the (data, model) topology).  Numerics: fp32 scores and accumulation
like the XLA path; the block-ordered online softmax is not bit-identical
to the flat softmax, but greedy argmax outputs are (pinned by
``tests/test_paged_decode.py`` on host and mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

__all__ = ["paged_flash_decode", "paged_flash_decode_mla"]


def _pin(*xs):
    """Pin every kernel operand (and, at the other end, the raw output)
    fully replicated under a serve topology.  The interpret-mode grid
    loop is a scan whose VMEM scratch the CPU SPMD partitioner reshards
    between steps when ANY operand — q, the pools, the page table or
    the positions — carries a sharding ("involuntary full
    rematerialization" warnings, wrong numbers; positions arrive
    sequence-sharded whenever ``constrain_bsd`` split the prefill chunk
    over "data").  Pinning at the pallas_call boundary keeps the fused
    loop whole; pool STORAGE stays model-sharded (the pin is the
    all-gather the XLA path pays at ``paged_read``).  Host mesh: no-op.
    """
    from repro.sharding.ctx import replicate_for_kernel
    return tuple(replicate_for_kernel(x) for x in xs)


def _row_positions(pos_row, g, seq_q, rows):
    """Per-query positions for the (g, S)-flattened row layout.

    pos_row: (1, S) int32 loaded from VMEM.  Rows r in [0, g*S) map to
    query s = r % S; padding rows (MXU row alignment) get -1 so the
    mask kills them.
    """
    qpos = jnp.broadcast_to(pos_row, (g, seq_q)).reshape(g * seq_q)
    if rows > g * seq_q:
        qpos = jnp.concatenate(
            [qpos, jnp.full((rows - g * seq_q,), -1, jnp.int32)])
    return qpos[:, None]                                 # (rows, 1)


def _online_update(s, v, m_scr, l_scr, acc_scr):
    """Fold one page's fp32 scores s: (rows, ps) and values v: (ps, dv)
    into the running (max, sum, accumulator) scratch."""
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot(p, v.astype(jnp.float32),
                                  preferred_element_type=jnp.float32))
    m_scr[...] = m_new


def _finish(l_scr, acc_scr, o_ref):
    l = l_scr[...]
    l = jnp.where(l == 0.0, 1.0, l)                      # fully-masked rows
    o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _page_mask(qpos2, w, page_size, window):
    """(rows, ps) visibility of page w's logical positions."""
    kv_pos = (w * page_size
              + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1))
    mask = kv_pos <= qpos2
    if window:
        mask &= kv_pos > qpos2 - window
    mask &= qpos2 >= 0                                   # padding rows
    return mask


def _page_visible(pos_row, w, page_size, window):
    """Block-level skip test: page w intersects [max-window, max] of the
    slot's query positions (positions are never negative on the paged
    decode path — idle slots freeze theirs)."""
    visible = w * page_size <= jnp.max(pos_row)
    if window:
        visible = jnp.logical_and(
            visible,
            (w + 1) * page_size - 1 > jnp.min(pos_row) - window)
    return visible


def _gqa_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                m_scr, l_scr, acc_scr, *, scale, page_size, g, seq_q,
                rows, n_pages_per_slot, window):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos_row = pos_ref[...]                               # (1, S)
    qpos2 = _row_positions(pos_row, g, seq_q, rows)

    @pl.when(_page_visible(pos_row, w, page_size, window))
    def _step():
        q = q_ref[...].astype(jnp.float32)               # (rows, hd)
        k = k_ref[...].astype(jnp.float32)               # (ps, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (rows, ps)
        s = jnp.where(_page_mask(qpos2, w, page_size, window), s, NEG_INF)
        _online_update(s, v_ref[...], m_scr, l_scr, acc_scr)

    @pl.when(w == n_pages_per_slot - 1)
    def _done():
        _finish(l_scr, acc_scr, o_ref)


def _row_pad(rows):
    """Round the query-row tile up to the fp32 sublane multiple."""
    return max(8, -(-rows // 8) * 8)


def paged_flash_decode(q, k_pool, v_pool, page_table, q_positions, *,
                       page_size, window=0, interpret=None):
    """Fused paged-gather + flash attention for GQA decode.

    q: (B, S, h, hd) — S is a decode token or a prefill chunk;
    k_pool, v_pool: (N, hk, hd) token-major page pool;
    page_table: (B, W) int32 physical page ids (0 = trash page);
    q_positions: (B, S) per-slot logical positions.
    Returns (B, S, h, hd) in q.dtype.
    """
    B, S, h, hd = q.shape
    hk = k_pool.shape[1]
    g = h // hk
    n_pages = k_pool.shape[0] // page_size
    W = page_table.shape[1]
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    rows = _row_pad(g * S)
    scale = 1.0 / np.sqrt(hd)

    # (B, S, h, hd) -> (B, hk, g*S, hd): kv head's whole group as one tile
    qr = q.reshape(B, S, hk, g, hd).transpose(0, 2, 3, 1, 4).reshape(
        B, hk, g * S, hd)
    if rows > g * S:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, rows - g * S), (0, 0)))
    kp = k_pool.reshape(n_pages, page_size, hk, hd)
    vp = v_pool.reshape(n_pages, page_size, hk, hd)
    pos = q_positions.astype(jnp.int32).reshape(B, 1, S)
    table = page_table.astype(jnp.int32)

    kernel = functools.partial(
        _gqa_kernel, scale=scale, page_size=page_size, g=g, seq_q=S,
        rows=rows, n_pages_per_slot=W, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, hk, W),
        in_specs=[
            pl.BlockSpec((None, 1, S), lambda b, h_, w, t: (b, 0, 0)),
            pl.BlockSpec((None, None, rows, hd),
                         lambda b, h_, w, t: (b, h_, 0, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda b, h_, w, t: (t[b, w], 0, h_, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda b, h_, w, t: (t[b, w], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, rows, hd),
                               lambda b, h_, w, t: (b, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, hk, rows, hd), q.dtype),
        interpret=interpret,
    )(*_pin(table, pos, qr, kp, vp))
    out, = _pin(out)
    return (out[:, :, :g * S]
            .reshape(B, hk, g, S, hd).transpose(0, 3, 1, 2, 4)
            .reshape(B, S, h, hd))


def _mla_kernel(table_ref, pos_ref, ql_ref, qr_ref, ckv_ref, kr_ref,
                o_ref, m_scr, l_scr, acc_scr, *, scale, page_size, g,
                seq_q, rows, n_pages_per_slot, window):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos_row = pos_ref[...]
    qpos2 = _row_positions(pos_row, g, seq_q, rows)

    @pl.when(_page_visible(pos_row, w, page_size, window))
    def _step():
        ckv = ckv_ref[...].astype(jnp.float32)           # (ps, r)
        # absorbed scores: latent dot + decoupled-rope dot, one page
        s = jax.lax.dot_general(
            ql_ref[...].astype(jnp.float32), ckv,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s += jax.lax.dot_general(
            qr_ref[...].astype(jnp.float32), kr_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s *= scale
        s = jnp.where(_page_mask(qpos2, w, page_size, window), s, NEG_INF)
        _online_update(s, ckv, m_scr, l_scr, acc_scr)    # V == latent

    @pl.when(w == n_pages_per_slot - 1)
    def _done():
        _finish(l_scr, acc_scr, o_ref)


def paged_flash_decode_mla(q_lat, q_rope, ckv_pool, krope_pool,
                           page_table, q_positions, *, page_size, scale,
                           window=0, interpret=None):
    """Absorbed-MLA variant: attend in the latent space against the
    compressed pool (one kv head; V is the latent itself).

    q_lat: (B, S, h, r) — q_nope absorbed through w_uk;
    q_rope: (B, S, h, rope_dim); ckv_pool: (N, r); krope_pool:
    (N, rope_dim); scale — 1/sqrt(nope+rope), the caller's convention.
    Returns the latent-space output (B, S, h, r) in q_lat.dtype for the
    caller's ``w_uv`` up-projection.
    """
    B, S, h, r = q_lat.shape
    rope_dim = q_rope.shape[-1]
    n_pages = ckv_pool.shape[0] // page_size
    W = page_table.shape[1]
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    rows = _row_pad(h * S)

    # one kv head: all h query heads share every page -> (B, h*S, ·)
    qlr = q_lat.reshape(B, S, h, r).transpose(0, 2, 1, 3).reshape(
        B, h * S, r)
    qrr = q_rope.reshape(B, S, h, rope_dim).transpose(0, 2, 1, 3).reshape(
        B, h * S, rope_dim)
    if rows > h * S:
        qlr = jnp.pad(qlr, ((0, 0), (0, rows - h * S), (0, 0)))
        qrr = jnp.pad(qrr, ((0, 0), (0, rows - h * S), (0, 0)))
    ckv = ckv_pool.reshape(n_pages, page_size, r)
    krp = krope_pool.reshape(n_pages, page_size, rope_dim)
    pos = q_positions.astype(jnp.int32).reshape(B, 1, S)
    table = page_table.astype(jnp.int32)

    kernel = functools.partial(
        _mla_kernel, scale=scale, page_size=page_size, g=h, seq_q=S,
        rows=rows, n_pages_per_slot=W, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, W),
        in_specs=[
            pl.BlockSpec((None, 1, S), lambda b, w, t: (b, 0, 0)),
            pl.BlockSpec((None, rows, r), lambda b, w, t: (b, 0, 0)),
            pl.BlockSpec((None, rows, rope_dim), lambda b, w, t: (b, 0, 0)),
            pl.BlockSpec((None, page_size, r),
                         lambda b, w, t: (t[b, w], 0, 0)),
            pl.BlockSpec((None, page_size, rope_dim),
                         lambda b, w, t: (t[b, w], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, rows, r), lambda b, w, t: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows,), jnp.float32),
            pltpu.VMEM((rows, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, rows, r), q_lat.dtype),
        interpret=interpret,
    )(*_pin(table, pos, qlr, qrr, ckv, krp))
    out, = _pin(out)
    return (out[:, :h * S]
            .reshape(B, h, S, r).transpose(0, 2, 1, 3))
