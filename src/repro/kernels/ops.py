"""jit'd wrappers + implementation dispatch for the compute kernels.

Implementations per op:
  * "ref"      — naive oracle (kernels/ref.py)
  * "chunked"  — chunked/blocked pure-jnp form (XLA path; what the full
                 models use on CPU and what GSPMD partitions in the
                 dry-run).  Mathematically identical to ref.
  * "pallas"   — the Pallas TPU kernel (kernels/<name>.py); on CPU this
                 runs in interpret mode automatically.

The chunked forms below are the TPU-shaped algorithms (per-chunk dense
matmuls for the MXU, O(chunk) state carries); the Pallas kernels
implement the same schedule with explicit VMEM BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref

_DEFAULT_IMPL = "chunked"
_EXP_CLIP = -60.0

# flash-attention tile sizes — ONE tuning surface shared by the
# training kernel (pallas), the chunked XLA path (block_q = its q-chunk)
# and the decode microbenchmark sweep (benchmarks/decode_microbench.py
# times candidate pairs and the chosen best lands in BENCH_decode.json)
_FLASH_BLOCKS = {"block_q": 256, "block_kv": 512}


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("ref", "chunked", "pallas")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def set_flash_blocks(block_q=None, block_kv=None):
    """Set the default flash tile sizes (None leaves a knob unchanged).
    Returns the previous ``(block_q, block_kv)`` so sweeps can restore."""
    prev = (_FLASH_BLOCKS["block_q"], _FLASH_BLOCKS["block_kv"])
    if block_q is not None:
        assert block_q > 0
        _FLASH_BLOCKS["block_q"] = int(block_q)
    if block_kv is not None:
        assert block_kv > 0
        _FLASH_BLOCKS["block_kv"] = int(block_kv)
    return prev


def get_flash_blocks():
    return _FLASH_BLOCKS["block_q"], _FLASH_BLOCKS["block_kv"]


# --------------------------------------------------------------------------
# WKV6 (RWKV-6 recurrence with data-dependent decay)
# --------------------------------------------------------------------------

def _wkv6_chunk(S, inp, u):
    """One chunk.  S: (B,H,K,V) fp32.  inp arrays: (B,C,H,K) fp32."""
    r, k, v, wl = inp
    L = jnp.cumsum(wl, axis=1)                       # inclusive log-decay
    Lprev = L - wl                                   # exclusive
    # contribution of the carried-in state
    y_state = jnp.einsum("bchk,bhkv->bchv", r * jnp.exp(Lprev), S)
    # intra-chunk pairwise (strictly lower-triangular)
    D = Lprev[:, :, None] - L[:, None]               # (B,C,C,H,K), t x j
    C_ = L.shape[1]
    tri = jnp.tril(jnp.ones((C_, C_), bool), k=-1)
    W = jnp.exp(jnp.clip(D, _EXP_CLIP, 0.0)) * tri[None, :, :, None, None]
    scores = jnp.einsum("bthk,bjhk,btjhk->bthj", r, k, W)
    y_intra = jnp.einsum("bthj,bjhv->bthv", scores, v)
    # diagonal (bonus u) term
    coef = jnp.einsum("bthk,hk,bthk->bth", r, u, k)
    y = y_state + y_intra + coef[..., None] * v
    # carry state across the chunk boundary
    Llast = L[:, -1:]
    k_sc = k * jnp.exp(Llast - L)
    S_new = jnp.exp(Llast[:, 0])[..., None] * S + jnp.einsum(
        "bchk,bchv->bhkv", k_sc, v)
    return S_new, y


def wkv6_chunked(r, k, v, w_log, u, state, *, chunk=32):
    B, T, H, K = r.shape
    dt = r.dtype
    chunk = min(chunk, T)
    pad = (-T) % chunk
    args = [a.astype(jnp.float32) for a in (r, k, v, w_log)]
    if pad:
        args = [jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in args]
    nc = args[0].shape[1] // chunk
    xs = tuple(a.reshape(B, nc, chunk, H, K).swapaxes(0, 1) for a in args)
    step = functools.partial(_wkv6_chunk, u=u.astype(jnp.float32))
    # checkpoint per chunk: bwd recomputes the (C,C,H,K) pairwise-decay
    # tensor instead of saving one per chunk
    final, ys = jax.lax.scan(jax.checkpoint(step),
                             state.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(B, nc * chunk, H, K)[:, :T]
    return y.astype(dt), final


def wkv6(r, k, v, w_log, u, state, *, impl=None, chunk=32):
    impl = impl or _DEFAULT_IMPL
    if impl == "ref":
        return _ref.wkv6_ref(r, k, v, w_log, u, state)
    if impl == "chunked":
        return wkv6_chunked(r, k, v, w_log, u, state, chunk=chunk)
    from repro.kernels.rwkv6_scan import wkv6_pallas
    return wkv6_pallas(r, k, v, w_log, u, state, chunk=chunk)


def wkv6_step(r, k, v, w_log, u, state):
    """Single decode step.  r,k,v,w_log: (B,H,K); state: (B,H,K,V)."""
    r, k, v, wl = (a.astype(jnp.float32) for a in (r, k, v, w_log))
    state = state.astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv",
                   r, state + u.astype(jnp.float32)[..., :, None] * kv)
    new = jnp.exp(wl)[..., :, None] * state + kv
    return y, new


# --------------------------------------------------------------------------
# Mamba selective scan
# --------------------------------------------------------------------------

def _mamba_chunk(h, inp, A, D):
    x, dt, B_, C_ = inp                              # (Bb,C,dI),(Bb,C,dI),(Bb,C,dS)
    logda = dt[..., None] * A                        # (Bb,C,dI,dS) <= 0
    L = jnp.cumsum(logda, axis=1)
    b = (dt * x)[..., None] * B_[:, :, None, :]      # input terms (Bb,C,dI,dS)

    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2        # log-space decays

    _, Hin = jax.lax.associative_scan(comb, (logda, b), axis=1)
    ht = jnp.exp(L) * h[:, None] + Hin               # (Bb,C,dI,dS)
    y = jnp.einsum("bcis,bcs->bci", ht, C_) + D * x
    return ht[:, -1], y


def mamba_chunked(x, dt, A, B, C, D, state, *, chunk=64):
    Bb, T, dI = x.shape
    out_dt = x.dtype
    chunk = min(chunk, T)
    pad = (-T) % chunk
    args = [a.astype(jnp.float32) for a in (x, dt, B, C)]
    if pad:
        args = [jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
                for a in args]
    nc = args[0].shape[1] // chunk
    xs = tuple(a.reshape((Bb, nc, chunk) + a.shape[2:]).swapaxes(0, 1)
               for a in args)
    step = functools.partial(_mamba_chunk, A=A.astype(jnp.float32),
                             D=D.astype(jnp.float32))
    # checkpoint per chunk: bwd recomputes the (C,dI,dS) decay/scan
    # trajectory per chunk instead of materialising the whole sequence
    final, ys = jax.lax.scan(jax.checkpoint(step),
                             state.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(Bb, nc * chunk, dI)[:, :T]
    return y.astype(out_dt), final


def mamba_scan(x, dt, A, B, C, D, state, *, impl=None, chunk=64):
    impl = impl or _DEFAULT_IMPL
    if impl == "ref":
        return _ref.mamba_ref(x, dt, A, B, C, D, state)
    if impl == "chunked":
        return mamba_chunked(x, dt, A, B, C, D, state, chunk=chunk)
    from repro.kernels.mamba_scan import mamba_pallas
    return mamba_pallas(x, dt, A, B, C, D, state, chunk=chunk)


def mamba_step(x, dt, A, B, C, D, state):
    """Single decode step.  x,dt: (Bb,dI); B,C: (Bb,dS); state: (Bb,dI,dS)."""
    x32, dt32, B32, C32 = (a.astype(jnp.float32) for a in (x, dt, B, C))
    state = state.astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * A.astype(jnp.float32))
    h = da * state + (dt32 * x32)[..., None] * B32[:, None, :]
    y = jnp.einsum("bis,bs->bi", h, C32) + D.astype(jnp.float32) * x32
    return y, h


# --------------------------------------------------------------------------
# Flash attention
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=0, impl=None,
                    block_q=None, block_kv=None):
    """block_q/block_kv default to the shared ``set_flash_blocks``
    surface; pass explicitly to override one call."""
    impl = impl or _DEFAULT_IMPL
    if block_q is None:
        block_q = _FLASH_BLOCKS["block_q"]
    if block_kv is None:
        block_kv = _FLASH_BLOCKS["block_kv"]
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    if impl == "chunked":
        from repro.models.attention import chunked_attention
        B, S = q.shape[:2]
        T = k.shape[1]
        return chunked_attention(
            q, k, v, q_positions=jnp.arange(S) + (T - S),
            kv_positions=jnp.arange(T), causal=causal, window=window,
            chunk=block_q)
    from repro.kernels.flash_attention import flash_attention_pallas
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_kv=block_kv)
