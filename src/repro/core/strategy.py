"""First-class data-parallel strategies: protocol + registry.

The source paper's pitch is *user-transparency*: distributed execution
with minimal user-visible changes (its MaTEx follow-on makes the API
itself the contribution).  This module is that API for the
reproduction: a gradient-sync strategy is ONE pluggable object, not a
string special-cased through every layer.  Each :class:`Strategy` owns

  * its **layout** (``layout(mesh, dp, params)`` -> ``Layout``) and
    **state construction** (``init(optimizer, params, mesh, dp)`` ->
    ``TrainState`` — from shape structs where possible, so zero3 keeps
    1/p residency even at construction);
  * its **step dataflow** — ``grad_sync(...)`` (how gradients are
    averaged/sharded, incl. the overlap-scheduler hooks) and
    ``step_transform(...)`` (how the optimizer update is applied and
    parameters re-synchronised);
  * its **perf-model entries** — ``comm_time(...)``,
    ``bucket_comm_time(...)`` and ``memory_entry(...)`` (the rows
    ``perf_model.dp_memory_report`` assembles);
  * its **checkpoint identity** — ``checkpoint_layout(layout)``, the
    meta.json record ``restore_sharded_checkpoint`` resolves back
    through the registry.

``make_dp_train_step``, ``init_train_state``, ``dp_memory_report`` and
the launchers are thin drivers that ask the registered strategy; to add
a new strategy, subclass and :func:`register_strategy` it — no core
edits.  ``zero1_hier`` (multi-pod hierarchical ZeRO-1) is registered
through exactly this public path, as the proof.

Registered built-ins:

  flat / bucketed / hierarchical — replicated state, allreduce grads;
  zero1 / zero2 / zero3          — the ZeRO ladder (sharded optimizer
                                   state / grads / params);
  zero1_hier                     — two-level ZeRO-1 for pod×data
                                   meshes: reduce-scatter intra-pod
                                   over ICI, reduce-scatter + all-gather
                                   of the 1/n_intra shard over DCN (an
                                   all-reduce split around the update),
                                   optimizer sharded over the *global*
                                   pod×data axes, big all-gather
                                   intra-pod only — the DCN link never
                                   carries more than 1/n_intra of the
                                   volume (``zero1_hier_comm_time``).

Old string names keep working — ``DPConfig(strategy="zero1")`` is a
registry lookup — and pre-registry spellings (``"zero-1"``,
``"allreduce"``, ...) resolve through a deprecation shim that warns
with a migration hint.  Unknown names raise, listing the registered
names.
"""
from __future__ import annotations

import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map, shard_map_kwargs
from repro.core.collectives import (
    all_gather_tree, allreduce_mean, axes_spec as _axes_spec,
    dp_batch_axes, dp_world_size, flatten_padded, hier_all_gather_tree,
    hier_reduce_scatter_mean, local_shard, reduce_scatter_mean,
    unflatten_padded,
)
from repro.core.overlap import (
    overlapped_all_gather, overlapped_all_gather_flat, overlapped_allreduce,
    overlapped_hier_all_gather_flat, overlapped_hier_reduce_scatter_flat,
    overlapped_reduce_scatter, overlapped_reduce_scatter_flat,
    plan_local_shard,
)
from repro.core.perf_model import (
    TPU_DCN, TPU_V5E_ICI, allreduce_comm_time, hierarchical_comm_time,
    zero1_comm_time, zero1_hier_comm_time, zero2_comm_time, zero3_comm_time,
    zero3_hier_comm_time,
)
from repro.core.train_state import (
    Layout, TrainState, _param_spec_of, _tree_total, concrete_params,
    opt_state_specs, register_layout_kind, shard_worker_index,
    split_flat_shards,
)


# --------------------------------------------------------------------------
# shared step machinery (strategy-agnostic)
# --------------------------------------------------------------------------

def _split_micro(batch, n):
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _accumulate(loss_fn, params, batch, n_micro):
    """loss, grads for the worker's batch, scanning microbatches; the
    full (replicated) gradient accumulates in fp32."""
    if n_micro == 1:
        return jax.value_and_grad(loss_fn)(params, batch)
    micro = _split_micro(batch, n_micro)
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def acc(carry, mb):
        g_acc, l_acc = carry
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, l_acc + l), None

    (grads, loss), _ = jax.lax.scan(
        acc, (zeros, jnp.zeros((), jnp.float32)), micro)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    return loss * inv, grads


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _shard_len(tree, n):
    """Per-worker shard length of `tree` flattened and padded to a
    multiple of n — must agree with ``flatten_padded``'s layout."""
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(tree))
    return (total + (-total) % n) // n


# --------------------------------------------------------------------------
# the protocol
# --------------------------------------------------------------------------

class Strategy:
    """One pluggable data-parallel strategy (see module docstring).

    Subclass :class:`ReplicatedStrategy` (replicated state, override
    ``grad_sync``) or :class:`ShardedStrategy` (sharded flat state,
    override ``grad_sync``/``step_transform``), set ``name``/``kind``,
    and :func:`register_strategy` an instance.
    """
    name: str = ""
    kind: str = "replicated"        # Layout kind of the persistent state
    sharded: bool = False           # opt state (at least) sharded 1/p?
    # params a flat 1/p shard (zero3-style)?  Such strategies MUST put
    # param_spec/param_dtypes in their layout (see Zero3Strategy.layout)
    # — host_params and the checkpoint store key off layout.params_flat.
    params_sharded: bool = False
    memory_key: str = "replicated"  # row key in dp_memory_report

    # ---- layout / state construction ------------------------------------
    def dp_axes(self, mesh) -> tuple:
        """Mesh axes (and linearisation order) the shards/batch span."""
        return dp_batch_axes(mesh)

    def state_kind(self, dp) -> str:
        """Layout kind the train step expects of its input state."""
        return self.kind if (self.sharded and dp.sync == "grads") \
            else "replicated"

    def bucket_layout(self, dp) -> Optional[int]:
        """bucket_bytes of the persistent shards' bucket-major
        permutation, or None when they are contiguous."""
        return None

    def layout(self, mesh, dp, params) -> Layout:
        """The Layout this strategy's state uses on `mesh` (works on
        shape structs — no values are read)."""
        axes = self.dp_axes(mesh)
        n = dp_world_size(mesh)
        total = _tree_total(params)
        if self.state_kind(dp) == "replicated":
            return Layout("replicated", axes, n, total, total,
                          strategy=self.name)
        padded = total + (-total) % n
        return Layout(self.kind, axes, n, total, padded,
                      self.bucket_layout(dp), strategy=self.name)

    def init(self, optimizer, params, mesh, dp) -> TrainState:
        """Materialise the TrainState the step consumes.  ``params``
        leaves may be ShapeDtypeStructs (zero-filled — a restore
        template)."""
        layout = self.layout(mesh, dp, params)
        if not layout.sharded:
            return _init_replicated(optimizer, params, mesh, layout)
        return self._init_sharded(optimizer, params, mesh, dp, layout)

    def _init_sharded(self, optimizer, params, mesh, dp, layout):
        raise NotImplementedError(
            f"strategy {self.name!r} declares sharded state but does not "
            "implement _init_sharded")

    # ---- step dataflow ---------------------------------------------------
    def validate(self, dp, mesh):
        """Reject DPConfig/mesh combinations this strategy cannot run."""

    def make_inner(self, loss_fn, optimizer, mesh, dp):
        """Build ``inner(params, opt_state, step_idx, batch, layout)``
        -> ``(params, opt_state, step_idx+1, metrics)`` — the function
        ``make_dp_train_step`` jits (layout static)."""
        raise NotImplementedError

    # ---- perf model ------------------------------------------------------
    @staticmethod
    def _ring_fabric(n_pods, fabric, inter):
        """A single-level ring spanning pods is bottlenecked by its
        slowest link: on a multi-pod mesh the whole volume crosses DCN.
        (The pod-aware strategies override comm_time and never pay
        this.)"""
        return inter if (n_pods or 1) > 1 else fabric

    def comm_time(self, v_bytes, *, p=None, n_intra=None, n_pods=None,
                  microbatches=1, fabric=TPU_V5E_ICI, inter=TPU_DCN):
        """Modeled per-step wire time for `v_bytes` of gradients."""
        p = p if p is not None else (n_intra or 1) * (n_pods or 1)
        return allreduce_comm_time(
            v_bytes, p=p, fabric=self._ring_fabric(n_pods, fabric, inter))

    def bucket_comm_time(self, v_bytes, *, p, fabric=TPU_V5E_ICI):
        """Wire time for ONE overlap-scheduler bucket of `v_bytes`."""
        return allreduce_comm_time(v_bytes, p=p, fabric=fabric)

    def memory_entry(self, n_params, state_factor, n_workers, *,
                     param_bytes=4, grad_bytes=4) -> dict:
        """Per-device persistent bytes: params / grads / opt_state."""
        shard = _padded_shard(n_params, n_workers)
        p_n, g_n, o_n = self._persistent_elems(n_params, shard)
        return {"params": param_bytes * p_n, "grads": grad_bytes * g_n,
                "opt_state": 4.0 * state_factor * o_n}

    def _persistent_elems(self, n_params, shard):
        """(param, grad, opt) element counts per device."""
        return n_params, n_params, n_params

    # ---- checkpointing ---------------------------------------------------
    def checkpoint_layout(self, layout: Layout) -> dict:
        """The meta.json record identifying this state — resolved back
        through the registry on restore."""
        d = layout.to_json()
        d["strategy"] = self.name
        return d


def _padded_shard(n_params, n_workers):
    if n_workers <= 1:
        return n_params
    padded = n_params + (-n_params) % n_workers
    return padded // n_workers


def _init_replicated(optimizer, params, mesh, layout) -> TrainState:
    """Replicated state, every leaf committed to the mesh so shardings
    are explicit (per-shard checkpointing, donation without transfers)."""
    rep = NamedSharding(mesh, P())
    params = jax.device_put(concrete_params(params), rep)
    opt_state = jax.device_put(optimizer.init(params), rep)
    step0 = jax.device_put(jnp.zeros((), jnp.int32), rep)
    return TrainState(params, opt_state, step0, layout)


# --------------------------------------------------------------------------
# replicated strategies: flat / bucketed / hierarchical
# --------------------------------------------------------------------------

class ReplicatedStrategy(Strategy):
    """Params + optimizer state replicated per worker (the paper's
    per-rank model copies); subclasses choose the gradient collective
    via ``grad_sync`` (default: the named ``collective`` algorithm of
    ``repro.core.collectives`` / the overlap scheduler)."""
    sharded = False
    kind = "replicated"
    memory_key = "replicated"
    collective = "flat"             # collectives/overlap algorithm key

    def grad_sync(self, grads, axes, dp):
        """Average `grads` over the DP axes (inside shard_map)."""
        if dp.overlap:
            return overlapped_allreduce(
                grads, axes, strategy=self.collective,
                bucket_bytes=dp.bucket_bytes, compress=dp.compress,
                serialize=(dp.overlap == "serial"))
        return allreduce_mean(grads, axes, strategy=self.collective,
                              compress=dp.compress,
                              bucket_bytes=dp.bucket_bytes)

    def weight_sync(self, params, axes, dp):
        """Average `params` (sync="weights" local-SGD mode)."""
        return allreduce_mean(params, axes, strategy=self.collective,
                              compress=dp.compress,
                              bucket_bytes=dp.bucket_bytes)

    def make_inner(self, loss_fn, optimizer, mesh, dp):
        axes = self.dp_axes(mesh)

        def worker(params, opt_state, batch, step_idx):
            loss, grads = _accumulate(loss_fn, params, batch,
                                      dp.microbatches)
            gnorm_local = _global_norm(grads)
            gnorm = None
            if dp.sync == "grads":
                grads = self.grad_sync(grads, axes, dp)
                gnorm = _global_norm(grads)     # norm of the averaged grad
                params, opt_state = optimizer.update(grads, opt_state,
                                                     params)
            elif dp.sync == "weights":
                params, opt_state = optimizer.update(grads, opt_state,
                                                     params)
                due = (step_idx + 1) % dp.sync_period == 0
                params = jax.lax.cond(
                    due, lambda p: self.weight_sync(p, axes, dp),
                    lambda p: p, params)
            else:  # "none": fully independent workers (divergence baseline)
                params, opt_state = optimizer.update(grads, opt_state,
                                                     params)
            loss_avg = jax.lax.pmean(loss, axes)
            metrics = {"loss": loss_avg, "grad_norm_local": gnorm_local,
                       "grad_norm": gnorm if gnorm is not None
                       else gnorm_local}
            return params, opt_state, metrics

        replicated = P()
        bspec = _axes_spec(axes)

        def inner(params, opt_state, step_idx, batch, layout):
            del layout
            wrapped = shard_map(
                worker, mesh=mesh,
                in_specs=(replicated, replicated, bspec, replicated),
                out_specs=(replicated, replicated, replicated),
                **shard_map_kwargs(check_vma=False))
            params, opt_state, metrics = wrapped(params, opt_state, batch,
                                                 step_idx)
            return params, opt_state, step_idx + 1, metrics

        return inner


class FlatStrategy(ReplicatedStrategy):
    """One pmean per tensor — the paper's MPI_Allreduce per gradient."""
    name = "flat"
    collective = "flat"


class BucketedStrategy(ReplicatedStrategy):
    """Pytree fused into ~bucket_bytes 1-D buckets (tensor fusion)."""
    name = "bucketed"
    collective = "bucketed"


class HierarchicalStrategy(ReplicatedStrategy):
    """Two-stage pod-aware allreduce: reduce-scatter over intra-pod
    ICI, all-reduce the 1/n shard over DCN, all-gather intra-pod."""
    name = "hierarchical"
    collective = "hierarchical"

    def bucket_comm_time(self, v_bytes, *, p, fabric=TPU_V5E_ICI):
        raise ValueError(
            "hierarchical per-bucket wire time needs the pod split — "
            "model it with perf_model.hierarchical_comm_time, not the "
            "single-fabric bucket scheduler formula")

    def comm_time(self, v_bytes, *, p=None, n_intra=None, n_pods=None,
                  microbatches=1, fabric=TPU_V5E_ICI, inter=TPU_DCN):
        if n_intra is None:
            return allreduce_comm_time(v_bytes, p=p or 1, fabric=fabric)
        return hierarchical_comm_time(v_bytes, n_intra=n_intra,
                                      n_pods=n_pods or 1, intra=fabric,
                                      inter=inter)


# --------------------------------------------------------------------------
# sharded strategies: the ZeRO ladder (+ multi-pod hierarchical zero1)
# --------------------------------------------------------------------------

class ShardedStrategy(Strategy):
    """State sharded 1/p per worker over the flattened parameter
    vector.  The generic worker asks two hooks:

      * ``grad_sync(loss_fn, pstate, batch, axes, dp, layout, plan)``
        -> ``(loss, gshard)`` — this worker's shard of the averaged
        gradient (layout-matching: contiguous, or bucket-major under
        `plan`);
      * ``step_transform(optimizer, gshard, pstate, opt_state, axes,
        dp, layout, plan)`` -> ``(params_out, new_opt, gshard)`` — the
        sharded optimizer update plus whatever parameter resync the
        strategy's layout needs (the all-gather rides the overlap
        scheduler when the layout is bucket-major).
    """
    sharded = True
    params_sharded = False

    def validate(self, dp, mesh):
        if dp.sync != "grads":
            raise ValueError(f"strategy={self.name!r} requires sync='grads'")

    def bucket_layout(self, dp) -> Optional[int]:
        return dp.bucket_bytes if dp.overlap else None

    def _init_sharded(self, optimizer, params, mesh, dp, layout):
        """zero1/zero2(/zero1_hier): params stay replicated state; the
        optimizer state is built over this worker's 1/p flat shard
        inside shard_map, so the moments never materialise in full."""
        params = concrete_params(params)
        leaves = jax.tree_util.tree_leaves(params)
        if not leaves:
            raise ValueError("init_train_state: empty param tree")
        rep = NamedSharding(mesh, P())
        params = jax.device_put(params, rep)
        step0 = jax.device_put(jnp.zeros((), jnp.int32), rep)
        axes, n = layout.axes, layout.num_shards
        sspec = _axes_spec(axes)
        plan = layout.plan()
        flat_dtype = jnp.result_type(*[l.dtype for l in leaves])

        def initw(params):
            flat, _ = flatten_padded(params, n)
            pshard = (plan_local_shard(flat, axes, plan)
                      if plan is not None else local_shard(flat, axes))
            return optimizer.init({"flat": pshard})

        opt_shape = jax.eval_shape(
            optimizer.init,
            {"flat": jax.ShapeDtypeStruct((layout.shard_len,), flat_dtype)})
        ospecs = opt_state_specs(opt_shape, sspec)
        wrapped = shard_map(
            initw, mesh=mesh, in_specs=(P(),), out_specs=ospecs,
            **shard_map_kwargs(check_vma=False))
        opt_state = jax.jit(wrapped)(params)
        return TrainState(params, opt_state, step0, layout)

    # ---- step hooks ------------------------------------------------------
    def grad_sync(self, loss_fn, pstate, batch, axes, dp, layout, plan):
        raise NotImplementedError

    def param_gather(self, shard, axes, pspec):
        """Reassemble the full param pytree from updated 1/p shards
        (the non-bucketed path; the hier strategy stages this)."""
        return all_gather_tree(shard, axes, pspec)

    def bucket_param_gather(self, shard, axes, pspec, plan, serialize):
        """Bucketed param reassembly (overlap path): hook so the hier
        strategies can stage their two-level gather per bucket."""
        return overlapped_all_gather(shard, axes, pspec, plan,
                                     serialize=serialize)

    def step_transform(self, optimizer, gshard, pstate, opt_state, axes,
                       dp, layout, plan):
        """Default (replicated-params layouts): update only the owned
        param shard — moments never materialise beyond 1/p per device —
        then all-gather the updated *params* back to replicated."""
        serialize = dp.overlap == "serial"
        flat_p, pspec = flatten_padded(pstate, layout.num_shards)
        pshard = (plan_local_shard(flat_p, axes, plan)
                  if plan is not None else local_shard(flat_p, axes))
        new_shard, new_opt = optimizer.update(
            {"flat": gshard}, opt_state, {"flat": pshard})
        if plan is not None:
            gathered = self.bucket_param_gather(
                new_shard["flat"], axes, pspec, plan, serialize)
        else:
            gathered = self.param_gather(new_shard["flat"], axes, pspec)
        if serialize:
            # the no-overlap baseline also orders the metric reductions
            # behind the param all-gather, so nothing hides behind it
            gshard, gathered = jax.lax.optimization_barrier(
                (gshard, gathered))
        params_out = jax.tree_util.tree_map(
            lambda new, old: new.astype(old.dtype), gathered, pstate)
        return params_out, new_opt, gshard

    def make_inner(self, loss_fn, optimizer, mesh, dp):
        axes = self.dp_axes(mesh)
        replicated = P()
        sspec = _axes_spec(axes)          # flat shards
        # the batch keeps the MESH axis order (how shard_batch_spec /
        # the loaders commit it): synchronous DP is invariant to which
        # worker gets which slice, so an axis-reordering strategy
        # (zero1_hier) must not force a cross-device batch reshard
        bspec = _axes_spec(dp_batch_axes(mesh))

        def make_worker(layout):
            plan = layout.plan()

            def worker(pstate, opt_state, batch):
                loss, gshard = self.grad_sync(loss_fn, pstate, batch,
                                              axes, dp, layout, plan)
                params_out, new_opt, gshard = self.step_transform(
                    optimizer, gshard, pstate, opt_state, axes, dp,
                    layout, plan)
                loss_avg = jax.lax.pmean(loss, axes)
                gnorm = jnp.sqrt(jax.lax.psum(
                    jnp.sum(jnp.square(gshard.astype(jnp.float32))), axes))
                metrics = {"loss": loss_avg, "grad_norm": gnorm}
                return params_out, new_opt, metrics

            return worker

        def inner(pstate, opt_state, step_idx, batch, layout):
            ospecs = opt_state_specs(opt_state, sspec)
            pspec_inout = sspec if self.params_sharded else replicated
            wrapped = shard_map(
                make_worker(layout), mesh=mesh,
                in_specs=(pspec_inout, ospecs, bspec),
                out_specs=(pspec_inout, ospecs, replicated),
                **shard_map_kwargs(check_vma=False))
            params, opt_state, metrics = wrapped(pstate, opt_state, batch)
            return params, opt_state, step_idx + 1, metrics

        return inner

    # ---- shared zero1-style gradient path --------------------------------
    def _accumulate_then_scatter(self, loss_fn, pstate, batch, axes, dp,
                                 plan):
        """Classic ZeRO-1 (and the degenerate single-microbatch zero2
        case): accumulate the full gradient, reduce-scatter ONCE."""
        serialize = dp.overlap == "serial"
        loss, grads = _accumulate(loss_fn, pstate, batch, dp.microbatches)
        if plan is not None:
            gshard, _, _ = overlapped_reduce_scatter(
                grads, axes, compress=dp.compress, serialize=serialize,
                plan=plan)
        else:
            gshard, _ = reduce_scatter_mean(grads, axes,
                                            compress=dp.compress)
        return loss, gshard


class Zero1Strategy(ShardedStrategy):
    """Sharded optimizer state: the allreduce splits into its
    reduce-scatter and all-gather halves, the optimizer updates only
    the owned 1/p shard between them.  Same wire volume as a ring
    allreduce; optimizer memory drops to 1/p."""
    name = "zero1"
    kind = "zero1"
    memory_key = "zero1"

    def grad_sync(self, loss_fn, pstate, batch, axes, dp, layout, plan):
        return self._accumulate_then_scatter(loss_fn, pstate, batch, axes,
                                             dp, plan)

    def comm_time(self, v_bytes, *, p=None, n_intra=None, n_pods=None,
                  microbatches=1, fabric=TPU_V5E_ICI, inter=TPU_DCN):
        p = p if p is not None else (n_intra or 1) * (n_pods or 1)
        return zero1_comm_time(
            v_bytes, p=p, fabric=self._ring_fabric(n_pods, fabric, inter))

    def bucket_comm_time(self, v_bytes, *, p, fabric=TPU_V5E_ICI):
        return zero1_comm_time(v_bytes, p=p, fabric=fabric)

    def _persistent_elems(self, n_params, shard):
        return n_params, n_params, shard


class Zero2Strategy(Zero1Strategy):
    """Additionally, the gradient SHARD is the only gradient state that
    persists: each microbatch's gradient is reduce-scattered as soon as
    it exists and only the 1/p shard accumulates across the scan."""
    name = "zero2"
    kind = "zero2"
    memory_key = "zero2"

    def bucket_layout(self, dp) -> Optional[int]:
        # zero2's per-microbatch reduce-scatters stay contiguous; its
        # shards only go bucket-major in the degenerate microbatches==1
        # case, which shares zero1's accumulate-then-one-RS tail
        if dp.microbatches > 1:
            return None
        return super().bucket_layout(dp)

    def grad_sync(self, loss_fn, pstate, batch, axes, dp, layout, plan):
        if dp.microbatches == 1:
            return self._accumulate_then_scatter(loss_fn, pstate, batch,
                                                 axes, dp, plan)
        n = layout.num_shards
        micro = _split_micro(batch, dp.microbatches)
        zeros = jnp.zeros((_shard_len(pstate, n),), jnp.float32)
        if dp.overlap is True:
            # software-pipelined accumulation: carry the *unreduced*
            # gradient of the previous microbatch through the scan, so
            # its reduce-scatter is dataflow-independent of the current
            # microbatch's backward and rides behind it on the wire.
            loss, pending = jax.value_and_grad(loss_fn)(
                pstate, jax.tree_util.tree_map(lambda x: x[0], micro))
            rest = jax.tree_util.tree_map(lambda x: x[1:], micro)

            def acc(carry, mb):
                g_pend, g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(pstate, mb)
                sh, _ = reduce_scatter_mean(g_pend, axes,
                                            compress=dp.compress)
                g, sh = jax.lax.optimization_barrier((g, sh))
                return (g, g_acc + sh.astype(jnp.float32), l_acc + l), None

            (pending, gshard, loss), _ = jax.lax.scan(
                acc, (pending, zeros, loss), rest)
            sh, _ = reduce_scatter_mean(pending, axes, compress=dp.compress)
            inv = 1.0 / dp.microbatches
            return loss * inv, (gshard + sh.astype(jnp.float32)) * inv
        # plain eager accumulation: reduce-scatter each microbatch's
        # grads as they are produced; only the 1/p shard accumulates
        def acc(carry, mb):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(loss_fn)(pstate, mb)
            sh, _ = reduce_scatter_mean(g, axes, compress=dp.compress)
            return (g_acc + sh.astype(jnp.float32), l_acc + l), None

        (gshard, loss), _ = jax.lax.scan(
            acc, (zeros, jnp.zeros((), jnp.float32)), micro)
        inv = 1.0 / dp.microbatches
        return loss * inv, gshard * inv

    def comm_time(self, v_bytes, *, p=None, n_intra=None, n_pods=None,
                  microbatches=1, fabric=TPU_V5E_ICI, inter=TPU_DCN):
        p = p if p is not None else (n_intra or 1) * (n_pods or 1)
        return zero2_comm_time(
            v_bytes, p=p, microbatches=microbatches,
            fabric=self._ring_fabric(n_pods, fabric, inter))

    def _persistent_elems(self, n_params, shard):
        return n_params, shard, shard


def _make_flat_gather(axes, plan, serialize, compress):
    """The zero3 parameter gather as a ``custom_vjp``: forward
    all-gathers the flat shard into the full padded vector (bucket-
    pipelined under ``plan``), backward reduce-scatters the cotangent
    straight back onto the shard — the canonical ZeRO-3 dataflow, with
    the same bucket schedule on both wires.  ``compress="bf16"`` puts
    both directions on a bfloat16 wire while the shard itself stays
    the fp32 master copy."""

    def ag(shard):
        wire = shard.astype(jnp.bfloat16) if compress == "bf16" else shard
        if plan is None:
            flat = jax.lax.all_gather(wire, axes, axis=0, tiled=True)
        else:
            flat = overlapped_all_gather_flat(wire, axes, plan,
                                              serialize=serialize)
        return flat.astype(shard.dtype)

    def rs_sum(ct):
        if plan is None:
            wire = ct.astype(jnp.bfloat16) if compress == "bf16" else ct
            sh = jax.lax.psum_scatter(wire, axes, scatter_dimension=0,
                                      tiled=True)
            return sh.astype(jnp.float32)
        return overlapped_reduce_scatter_flat(
            ct, axes, plan, mean=False, compress=compress,
            serialize=serialize).astype(jnp.float32)

    @jax.custom_vjp
    def gather(shard):
        return ag(shard)

    def fwd(shard):
        return ag(shard), None

    def bwd(_, ct):
        return (rs_sum(ct),)

    gather.defvjp(fwd, bwd)
    return gather


class Zero3Strategy(ShardedStrategy):
    """Params themselves live sharded between steps: the forward
    all-gathers parameter buckets on demand (dropped after use — the
    backward re-gathers via remat) and the backward's cotangent
    reduce-scatters straight onto the shard, so params, grads and
    optimizer state are all 1/p per device."""
    name = "zero3"
    kind = "zero3"
    memory_key = "zero3"
    params_sharded = True

    def layout(self, mesh, dp, params) -> Layout:
        base = super().layout(mesh, dp, params)
        if base.kind == "replicated":
            return base
        spec = _param_spec_of(params)
        dtypes = tuple(str(l.dtype)
                       for l in jax.tree_util.tree_leaves(params))
        return Layout(base.kind, base.axes, base.num_shards, base.total,
                      base.padded_total, base.bucket_bytes,
                      param_spec=spec, param_dtypes=dtypes,
                      strategy=self.name)

    def _init_sharded(self, optimizer, params, mesh, dp, layout):
        """Per-shard init from shape structs: the flat 1/p param shards
        are placed directly per device (host-sliced, no device gather)
        and the optimizer state is built over the shard inside
        shard_map — the full parameter pytree never lands on ANY device
        (and, for ShapeDtypeStruct templates, never exists at all)."""
        leaves = jax.tree_util.tree_leaves(params)
        if not leaves:
            raise ValueError("init_train_state: empty param tree")
        axes, n = layout.axes, layout.num_shards
        sspec = _axes_spec(axes)
        flat_dtype = jnp.result_type(*[l.dtype for l in leaves])
        per = layout.shard_len
        if all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves):
            # pure shape-struct template (restore target): the values
            # never exist anywhere — each device's shard is born zero
            def shard_of(idx, per=per):
                return np.zeros(per, dtype=flat_dtype)
        else:
            # canonical host flat master vector; any ShapeDtypeStruct
            # leaves stay zero
            host_flat = np.zeros(layout.padded_total, dtype=flat_dtype)
            off = 0
            for leaf in leaves:
                size = int(np.prod(np.shape(leaf)))
                if not isinstance(leaf, jax.ShapeDtypeStruct):
                    host_flat[off:off + size] = \
                        np.asarray(leaf, dtype=flat_dtype).ravel()
                off += size
            shards = split_flat_shards(host_flat, layout)  # honours plan

            def shard_of(idx, per=per):
                return shards[shard_worker_index(idx, per)]

        pshard = jax.make_array_from_callback(
            (layout.padded_total,), NamedSharding(mesh, sspec), shard_of)

        def initw(pshard):
            return optimizer.init({"flat": pshard})

        opt_shape = jax.eval_shape(
            optimizer.init,
            {"flat": jax.ShapeDtypeStruct((per,), flat_dtype)})
        ospecs = opt_state_specs(opt_shape, sspec)
        wrapped = shard_map(
            initw, mesh=mesh, in_specs=(sspec,), out_specs=ospecs,
            **shard_map_kwargs(check_vma=False))
        opt_state = jax.jit(wrapped)(pshard)
        rep = NamedSharding(mesh, P())
        step0 = jax.device_put(jnp.zeros((), jnp.int32), rep)
        return TrainState(pshard, opt_state, step0, layout)

    def grad_sync(self, loss_fn, pstate, batch, axes, dp, layout, plan):
        """loss, mean-gradient shard: params are gathered on demand
        (and re-gathered in the backward via remat, so the full pytree
        is dropped after its forward use), the cotangent reduce-scatters
        onto the shard through the gather's vjp."""
        n = layout.num_shards
        serialize = dp.overlap == "serial"
        pspec = layout.param_spec
        treedef = pspec[0]
        gather = self._flat_gather(axes, plan, serialize, dp.compress)

        def reconstruct(shard):
            tree = unflatten_padded(gather(shard), pspec)
            leaves = jax.tree_util.tree_leaves(tree)
            return jax.tree_util.tree_unflatten(
                treedef, [l.astype(dt) for l, dt
                          in zip(leaves, layout.param_dtypes)])

        reconstruct = jax.checkpoint(reconstruct)

        def shard_loss(shard, mb):
            return loss_fn(reconstruct(shard), mb)

        if dp.microbatches == 1:
            loss, g = jax.value_and_grad(shard_loss)(pstate, batch)
            return loss, g.astype(jnp.float32) / n
        micro = _split_micro(batch, dp.microbatches)
        zeros = jnp.zeros(pstate.shape, jnp.float32)

        def acc(carry, mb):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(shard_loss)(pstate, mb)
            return (g_acc + g.astype(jnp.float32), l_acc + l), None

        (g, loss), _ = jax.lax.scan(
            acc, (zeros, jnp.zeros((), jnp.float32)), micro)
        inv = 1.0 / dp.microbatches
        return loss * inv, g * inv / n

    def step_transform(self, optimizer, gshard, pstate, opt_state, axes,
                       dp, layout, plan):
        new_shard, new_opt = optimizer.update(
            {"flat": gshard}, opt_state, {"flat": pstate})
        return new_shard["flat"].astype(pstate.dtype), new_opt, gshard

    def comm_time(self, v_bytes, *, p=None, n_intra=None, n_pods=None,
                  microbatches=1, fabric=TPU_V5E_ICI, inter=TPU_DCN):
        p = p if p is not None else (n_intra or 1) * (n_pods or 1)
        return zero3_comm_time(
            v_bytes, p=p, microbatches=microbatches,
            fabric=self._ring_fabric(n_pods, fabric, inter))

    def bucket_comm_time(self, v_bytes, *, p, fabric=TPU_V5E_ICI):
        return zero3_comm_time(v_bytes, p=p, fabric=fabric)

    def _flat_gather(self, axes, plan, serialize, compress):
        """Hook: the parameter-gather custom_vjp for this layout —
        zero3_hier swaps in the two-level staged version."""
        return _make_flat_gather(axes, plan, serialize, compress)

    def _persistent_elems(self, n_params, shard):
        return shard, shard, shard


class Zero1HierStrategy(Zero1Strategy):
    """Multi-pod hierarchical ZeRO-1 (the ROADMAP multi-pod item),
    registered purely through the public Strategy API.

    On a (pod, data) mesh the gradient reduce-scatter runs in two
    levels — over the fast intra-pod ``data`` axis (ICI) first, then
    the 1/n_intra shard over the ``pod`` axis (DCN); with the updated
    params the inverse: the small cross-pod gather first, then the big
    all-gather intra-pod.  The DCN reduce-scatter + all-gather pair IS
    an all-reduce of the 1/n_intra shard, split around the optimizer
    update — which runs on the 1/(n_intra·n_pods) shard each worker
    owns, i.e. the optimizer state is sharded over the *global*
    pod×data axes.  The DCN link never carries more than 1/n_intra of
    the gradient volume (``perf_model.zero1_hier_comm_time``); on a
    single-axis mesh the strategy degenerates to plain zero1.

    Shard-ownership note: the worker linearisation is **intra-major**
    (``dp_axes`` returns ``("data", "pod")``), which makes the nested
    scatter land each worker exactly on its contiguous ``local_shard``
    slice — so optimizer state, checkpoints and cross-layout restores
    need no special casing.
    """
    name = "zero1_hier"
    kind = "zero1_hier"
    memory_key = "zero1_hier"

    def dp_axes(self, mesh) -> tuple:
        axes = dp_batch_axes(mesh)
        if len(axes) == 2:
            return (axes[1], axes[0])       # (intra, inter) linearisation
        return axes

    def bucket_comm_time(self, v_bytes, *, p=None, fabric=TPU_V5E_ICI,
                         n_intra=None, n_pods=None, inter=TPU_DCN):
        if n_intra is None:
            return zero1_comm_time(v_bytes, p=p or 1, fabric=fabric)
        return zero1_hier_comm_time(v_bytes, n_intra=n_intra,
                                    n_pods=n_pods or 1, intra=fabric,
                                    inter=inter)

    def grad_sync(self, loss_fn, pstate, batch, axes, dp, layout, plan):
        if len(axes) == 1:                  # single pod: plain zero1
            return self._accumulate_then_scatter(loss_fn, pstate, batch,
                                                 axes, dp, plan)
        loss, grads = _accumulate(loss_fn, pstate, batch, dp.microbatches)
        intra, inter = axes
        if plan is not None:                # bucket overlap scheduler
            flat, _ = flatten_padded(grads, layout.num_shards)
            gshard = overlapped_hier_reduce_scatter_flat(
                flat, intra, inter, plan, mean=True, compress=dp.compress,
                serialize=dp.overlap == "serial")
            return loss, gshard
        gshard, _ = hier_reduce_scatter_mean(grads, intra, inter,
                                             compress=dp.compress)
        return loss, gshard

    def param_gather(self, shard, axes, pspec):
        if len(axes) == 1:
            return all_gather_tree(shard, axes, pspec)
        intra, inter = axes
        return hier_all_gather_tree(shard, intra, inter, pspec)

    def bucket_param_gather(self, shard, axes, pspec, plan, serialize):
        if len(axes) == 1:
            return overlapped_all_gather(shard, axes, pspec, plan,
                                         serialize=serialize)
        intra, inter = axes
        flat = overlapped_hier_all_gather_flat(shard, intra, inter, plan,
                                               serialize=serialize)
        return unflatten_padded(flat, pspec)

    def comm_time(self, v_bytes, *, p=None, n_intra=None, n_pods=None,
                  microbatches=1, fabric=TPU_V5E_ICI, inter=TPU_DCN):
        if n_intra is None:
            return zero1_comm_time(v_bytes, p=p or 1, fabric=fabric)
        return zero1_hier_comm_time(v_bytes, n_intra=n_intra,
                                    n_pods=n_pods or 1, intra=fabric,
                                    inter=inter)


def _make_hier_flat_gather(intra, inter, plan, serialize, compress):
    """zero3_hier's parameter gather as a ``custom_vjp``: forward
    gathers the flat shard in two stages — the small cross-pod gather
    over DCN first (1/n_intra of the volume), then the big intra-pod
    gather over ICI; backward reduce-scatters the cotangent intra-pod
    first, so DCN again carries only the 1/n_intra piece.  The
    hierarchical analogue of :func:`_make_flat_gather`, with the same
    bucket schedule on both wires when ``plan`` is set."""

    def ag(shard):
        wire = shard.astype(jnp.bfloat16) if compress == "bf16" else shard
        if plan is None:
            piece = jax.lax.all_gather(wire, inter, axis=0, tiled=True)
            flat = jax.lax.all_gather(piece, intra, axis=0, tiled=True)
        else:
            flat = overlapped_hier_all_gather_flat(
                wire, intra, inter, plan, serialize=serialize)
        return flat.astype(shard.dtype)

    def rs_sum(ct):
        if plan is None:
            wire = ct.astype(jnp.bfloat16) if compress == "bf16" else ct
            sh = jax.lax.psum_scatter(wire, intra, scatter_dimension=0,
                                      tiled=True)
            sh = jax.lax.psum_scatter(sh, inter, scatter_dimension=0,
                                      tiled=True)
            return sh.astype(jnp.float32)
        return overlapped_hier_reduce_scatter_flat(
            ct, intra, inter, plan, mean=False, compress=compress,
            serialize=serialize).astype(jnp.float32)

    @jax.custom_vjp
    def gather(shard):
        return ag(shard)

    def fwd(shard):
        return ag(shard), None

    def bwd(_, ct):
        return (rs_sum(ct),)

    gather.defvjp(fwd, bwd)
    return gather


class Zero3HierStrategy(Zero3Strategy):
    """Multi-pod hierarchical ZeRO-3: params, grads and optimizer state
    all live as 1/(n_intra·n_pods) shards, and BOTH wires of the
    on-demand parameter gather are staged — forward, the small
    cross-pod gather over DCN first (1/n_intra of the volume) then the
    big intra-pod gather over ICI; backward, the cotangent
    reduce-scatters intra-pod first so DCN again moves only the
    1/n_intra piece (``perf_model.zero3_hier_comm_time``).

    Shard ownership is zero1_hier's intra-major linearisation, so
    checkpoints, cross-layout restores and the bucket-major plan
    permutation all reuse the existing machinery unchanged; on a
    single-axis mesh the strategy degenerates to plain zero3."""
    name = "zero3_hier"
    kind = "zero3_hier"
    memory_key = "zero3"                    # same 1/p residency as zero3

    def dp_axes(self, mesh) -> tuple:
        axes = dp_batch_axes(mesh)
        if len(axes) == 2:
            return (axes[1], axes[0])       # (intra, inter) linearisation
        return axes

    def _flat_gather(self, axes, plan, serialize, compress):
        if len(axes) == 1:                  # single pod: plain zero3
            return _make_flat_gather(axes, plan, serialize, compress)
        intra, inter = axes
        return _make_hier_flat_gather(intra, inter, plan, serialize,
                                      compress)

    def comm_time(self, v_bytes, *, p=None, n_intra=None, n_pods=None,
                  microbatches=1, fabric=TPU_V5E_ICI, inter=TPU_DCN):
        if n_intra is None:
            return zero3_comm_time(v_bytes, p=p or 1,
                                   microbatches=microbatches, fabric=fabric)
        return zero3_hier_comm_time(v_bytes, n_intra=n_intra,
                                    n_pods=n_pods or 1,
                                    microbatches=microbatches,
                                    intra=fabric, inter=inter)

    def bucket_comm_time(self, v_bytes, *, p=None, fabric=TPU_V5E_ICI,
                         n_intra=None, n_pods=None, inter=TPU_DCN):
        if n_intra is None:
            return zero3_comm_time(v_bytes, p=p or 1, fabric=fabric)
        return zero3_hier_comm_time(v_bytes, n_intra=n_intra,
                                    n_pods=n_pods or 1, intra=fabric,
                                    inter=inter)


# --------------------------------------------------------------------------
# the registry
# --------------------------------------------------------------------------

_REGISTRY: "dict[str, Strategy]" = {}

# pre-registry spellings accepted by earlier launchers/notebooks; the
# deprecation shim below resolves them with a loud migration hint
_LEGACY_ALIASES = {
    "allreduce": "flat", "pmean": "flat",
    "fused": "bucketed", "two_level": "hierarchical",
    "zero-1": "zero1", "zero_1": "zero1",
    "zero-2": "zero2", "zero_2": "zero2",
    "zero-3": "zero3", "zero_3": "zero3",
    "zero1-hier": "zero1_hier", "hier_zero1": "zero1_hier",
}


def register_strategy(strategy: Strategy, *, overwrite: bool = False):
    """Register a Strategy instance under ``strategy.name``.  Duplicate
    names raise unless ``overwrite=True`` (protects against two plugins
    silently shadowing each other).  Returns the strategy, so it can be
    used as a decorator-ish one-liner on an instance."""
    if not isinstance(strategy, Strategy):
        raise TypeError(f"register_strategy takes a Strategy instance, "
                        f"got {type(strategy).__name__}")
    name = strategy.name
    if not name or not isinstance(name, str):
        raise ValueError(f"strategy name must be a non-empty str, "
                         f"got {name!r}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(
            f"strategy {name!r} is already registered "
            f"({type(_REGISTRY[name]).__name__}); pass overwrite=True to "
            "replace it")
    register_layout_kind(strategy.kind, sharded=strategy.sharded)
    _REGISTRY[name] = strategy
    return strategy


def available_strategies() -> tuple:
    """Registered strategy names, registration order."""
    return tuple(_REGISTRY)


def get_strategy(name) -> Strategy:
    """Resolve a strategy by registry name (or pass an instance
    through).  This is the deprecation shim for the pre-registry
    string-dispatch era: legacy spellings (``dp.strategy == "zero-1"``
    and friends) still resolve, with a DeprecationWarning naming the
    canonical registration; unknown names raise, listing every
    registered name."""
    if isinstance(name, Strategy):
        return name
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name in _LEGACY_ALIASES:
        canonical = _LEGACY_ALIASES[name]
        warnings.warn(
            f"strategy name {name!r} is a deprecated pre-registry "
            f"spelling; use DPConfig(strategy={canonical!r}) — strategies "
            "are first-class registered objects now (see "
            "repro.core.strategy / docs/data_parallel.md §Migrating)",
            DeprecationWarning, stacklevel=2)
        return _REGISTRY[canonical]
    raise ValueError(
        f"unknown strategy {name!r}; registered strategies: "
        f"{list(available_strategies())}.  Register custom strategies via "
        "repro.core.strategy.register_strategy(...)")


def memory_rows(n_params, state_factor, n_workers, *, param_bytes=4,
                grad_bytes=4):
    """(memory_key, entry) rows for ``perf_model.dp_memory_report`` —
    one row per distinct ``memory_key`` across the registry (the
    replicated strategies share one row), registration order."""
    seen = set()
    rows = []
    for strategy in _REGISTRY.values():
        key = strategy.memory_key
        if key in seen:
            continue
        seen.add(key)
        rows.append((key, strategy.memory_entry(
            n_params, state_factor, n_workers, param_bytes=param_bytes,
            grad_bytes=grad_bytes)))
    return rows


# built-ins — registered through the same public API a plugin would use
register_strategy(FlatStrategy())
register_strategy(BucketedStrategy())
register_strategy(HierarchicalStrategy())
register_strategy(Zero1Strategy())
register_strategy(Zero2Strategy())
register_strategy(Zero3Strategy())
register_strategy(Zero1HierStrategy())
register_strategy(Zero3HierStrategy())
