"""DistBelief-style asynchronous parameter server — the paper's REJECTED
alternative (§3.3.2), implemented so the comparison is reproducible.

The paper argues a parameter server "suffers from bottleneck at
parameter server, especially at scale" and that async updates make it
"difficult to reason about the correctness of the algorithm".  We
emulate the async dynamics deterministically on one host:

  * ``p`` workers hold stale snapshots of the server parameters.
  * Round-robin ticks: at tick t, worker (t mod p) pushes the gradient
    it computed on its snapshot (staleness ≈ p ticks), the server
    applies it, and the worker pulls fresh parameters.

This reproduces async SGD's gradient-staleness dynamics (Recht et al.'s
hogwild regime with bounded staleness) without multiprocess plumbing,
and lets benchmarks/ps_vs_allreduce.py show the convergence gap the
paper used to justify synchronous allreduce.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def make_ps_trainer(loss_fn: Callable, optimizer, num_workers: int):
    """Returns run(params, opt_state, batches, key) -> (params, losses).

    batches: pytree with leading axis (ticks, per_tick_batch, ...) —
    one microbatch per tick, consumed round-robin by workers.
    """

    def run(params, opt_state, batches):
        # every worker starts from the server's params
        snapshots = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (num_workers,) + p.shape),
            params)

        def tick(carry, batch_t):
            server, opt_state, snapshots, t = carry
            w = t % num_workers
            snap_w = jax.tree_util.tree_map(lambda s: s[w], snapshots)
            # gradient computed at the STALE snapshot
            loss, grads = jax.value_and_grad(loss_fn)(snap_w, batch_t)
            server, opt_state = optimizer.update(grads, opt_state, server)
            # worker pulls fresh params
            snapshots = jax.tree_util.tree_map(
                lambda s, p: s.at[w].set(p), snapshots, server)
            return (server, opt_state, snapshots, t + 1), loss

        (server, opt_state, _, _), losses = jax.lax.scan(
            tick, (params, opt_state, snapshots, jnp.zeros((), jnp.int32)),
            batches)
        return server, opt_state, losses

    return jax.jit(run)
