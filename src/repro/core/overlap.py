"""Bucket-level overlap scheduler for gradient collectives.

The paper hides the MPI allreduce behind backward compute ("the
communication ... is overlapped with the computation of the next
batch", §3.3.3); Awan et al. 2018 show the chunked, overlapped
reduction is the difference between linear and sub-linear scaling.
This module generalises the zero1 per-microbatch reduce-scatter into a
double-buffered, bucket-level scheduler for every strategy:

  1. the flattened gradient pytree is partitioned into size-bounded
     buckets (``plan_buckets`` — same flatten/pad layout as
     ``collectives.flatten_padded``);
  2. the collective for bucket *k* is issued while bucket *k±1* is
     still being produced/consumed (``run_pipeline``): at most one
     collective in flight plus one bucket in its epilogue — the classic
     double buffer;
  3. ``jax.lax.optimization_barrier`` pins the pipeline shape into the
     lowered HLO, so XLA's latency-hiding scheduler on TPU/GPU can
     split each collective into ``-start``/``-done`` pairs and hide it
     behind the neighbouring bucket's compute.

The CPU backend never asyncifies collectives, so proving overlap needs
HLO inspection rather than wall clock: ``async_overlap_report`` walks
the *lowered* (pre-optimisation) HLO, where the barriers are still
visible, and finds every collective with concurrent work to hide
behind — exactly the test XLA's ``AsyncCollectiveCreator`` applies.
``asyncify_hlo`` then performs that rewrite at text level, emitting the
``all-reduce-start``/``all-reduce-done`` (or ``reduce-scatter-start``,
…) pairs the real async backends would, which the dry-run reports and
``tests/test_overlap.py`` asserts on.

Serialized mode (``serialize=True``) runs the same buckets but chains
each collective behind the previous bucket's epilogue through the
barrier — the no-overlap baseline ``benchmarks/run.py`` compares
against, and the negative control for the HLO test.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp

from repro.compat import axis_size
from repro.core.collectives import (
    _axis_size as _axes_size, _flatten_concat, _maybe_compress, _restore,
    _unflatten, flatten_padded, unflatten_padded,
)


# --------------------------------------------------------------------------
# bucket partitioning
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static partition of a padded flat vector into aligned buckets.

    ``starts[k]``/``lengths[k]`` tile ``[0, padded_total)`` exactly;
    every length is a multiple of ``align`` (so a per-bucket
    reduce-scatter over ``align`` workers needs no further padding, and
    the concatenated per-bucket shards have total length
    ``padded_total // align`` — identical to the unbucketed shard, so
    zero1 optimizer state is layout-compatible in size).  ``total`` is
    the unpadded element count of the source pytree."""
    starts: tuple
    lengths: tuple
    align: int
    total: int
    padded_total: int

    @property
    def n_buckets(self) -> int:
        return len(self.starts)

    def shard_offsets(self, n_workers: int):
        """Offset of each bucket's shard piece in the concatenated
        per-worker shard (bucket-major layout)."""
        offs, off = [], 0
        for ln in self.lengths:
            offs.append(off)
            off += ln // n_workers
        return tuple(offs), off


def plan_buckets(total: int, *, bucket_bytes: int, itemsize: int = 4,
                 align: int = 1, leaf_sizes=None) -> BucketPlan:
    """Partition a ``total``-element flat vector (padded up to a
    multiple of ``align``) into ~``bucket_bytes`` buckets whose lengths
    are multiples of ``align``.  With ``leaf_sizes`` the buckets follow
    the pytree's leaf boundaries instead (the ``flat`` per-tensor
    strategy); ``align`` must be 1 in that mode."""
    if total <= 0:
        raise ValueError("plan_buckets: empty vector")
    if leaf_sizes is not None:
        if align != 1:
            raise ValueError("per-leaf buckets cannot be aligned")
        starts, off = [], 0
        for sz in leaf_sizes:
            starts.append(off)
            off += sz
        return BucketPlan(tuple(starts), tuple(leaf_sizes), 1, total, off)
    padded = total + (-total) % align
    per = max(align, (max(1, bucket_bytes // itemsize) // align) * align)
    starts, lengths, off = [], [], 0
    while off < padded:
        ln = min(per, padded - off)
        starts.append(off)
        lengths.append(ln)
        off += ln
    return BucketPlan(tuple(starts), tuple(lengths), align, total, padded)


# --------------------------------------------------------------------------
# the double-buffered pipeline
# --------------------------------------------------------------------------

def run_pipeline(n_buckets, issue, finish, src, out, *, serialize=False):
    """Run ``n_buckets`` (issue → finish) stages double-buffered.

    ``issue(k, src)`` starts bucket *k*'s collective from the source
    value(s); ``finish(k, value, out)`` folds the finished bucket into
    the accumulator(s).  In overlapped mode bucket *k*'s collective is
    issued *before* bucket *k-1*'s epilogue runs, and an
    ``optimization_barrier`` over (in-flight, src, out) closes each
    stage — so at most one collective is in flight while one bucket
    finalises, and the two are dataflow-independent (the window the
    async scheduler hides communication in).  ``serialize=True`` chains
    each collective behind the previous epilogue instead: same buckets,
    zero overlap — the baseline schedule."""
    barrier = jax.lax.optimization_barrier
    if serialize:
        # gate the first issue on the COMPLETE source: slicing can fold
        # a leaf-aligned bucket straight onto one gradient leaf, which
        # would let bucket 0's collective ride the backward tail even
        # here — the barrier restores "no collective before the full
        # backward", the definition of the serialized baseline
        src = barrier(src)
        for k in range(n_buckets):
            out = finish(k, issue(k, src), out)
            if k + 1 < n_buckets:
                src, out = barrier((src, out))
        return out
    pending = issue(0, src)
    for k in range(1, n_buckets):
        nxt = issue(k, src)
        out = finish(k - 1, pending, out)
        nxt, src, out = barrier((nxt, src, out))
        pending = nxt
    return finish(n_buckets - 1, pending, out)


def _pad_to(flat, size):
    return jnp.pad(flat, (0, size - flat.size)) if flat.size < size else flat


def overlapped_allreduce(tree, axis_names, *, strategy="bucketed",
                         bucket_bytes=64 * 2 ** 20, compress="none",
                         serialize=False):
    """Bucket-pipelined gradient averaging for the replicated
    strategies.  Numerically identical to ``allreduce_mean`` with the
    same strategy (same per-element reduction), but scheduled so bucket
    *k*'s collective overlaps bucket *k-1*'s write-back."""
    if not jax.tree_util.tree_leaves(tree):
        return tree
    if strategy == "zero1":
        shard, spec, plan = overlapped_reduce_scatter(
            tree, axis_names, bucket_bytes=bucket_bytes, compress=compress,
            serialize=serialize)
        return overlapped_all_gather(shard, axis_names, spec, plan,
                                     serialize=serialize)
    ref = tree
    tree = _maybe_compress(tree, compress)
    flat, spec = _flatten_concat(tree)
    hier = strategy == "hierarchical" and len(axis_names) > 1
    if hier:
        inter, intra = axis_names[0], axis_names[1]
        n_intra = axis_size(intra)
    if strategy == "flat":
        leaf_sizes = [l.size for l in jax.tree_util.tree_leaves(tree)]
        plan = plan_buckets(flat.size, bucket_bytes=bucket_bytes,
                            leaf_sizes=leaf_sizes)
    else:
        plan = plan_buckets(flat.size, bucket_bytes=bucket_bytes,
                            itemsize=flat.dtype.itemsize,
                            align=n_intra if hier else 1)
    flat = _pad_to(flat, plan.padded_total)

    def issue(k, src):
        (f,) = src
        b = f[plan.starts[k]:plan.starts[k] + plan.lengths[k]]
        if hier:
            sh = jax.lax.psum_scatter(b, intra, scatter_dimension=0,
                                      tiled=True)
            sh = jax.lax.pmean(sh, inter)
            return jax.lax.all_gather(sh, intra, axis=0, tiled=True) / n_intra
        return jax.lax.pmean(b, axis_names)

    def finish(k, val, out):
        (o,) = out
        return (jax.lax.dynamic_update_slice_in_dim(
            o, val, plan.starts[k], 0),)

    (out,) = run_pipeline(plan.n_buckets, issue, finish, (flat,),
                          (jnp.zeros(plan.padded_total, flat.dtype),),
                          serialize=serialize)
    return _restore(_unflatten(out[:plan.total], spec), ref, compress)


# --------------------------------------------------------------------------
# zero1: bucket-pipelined reduce-scatter / all-gather halves
# --------------------------------------------------------------------------

def overlapped_reduce_scatter_flat(flat, axis_names, plan: BucketPlan, *,
                                   mean=True, compress="none",
                                   serialize=False):
    """Bucket-pipelined reduce-scatter of an already-padded flat vector
    (``flat.size == plan.padded_total``) into this worker's
    *bucket-major* shard.  ``mean=False`` returns the plain sum — the
    transpose/cotangent form the zero3 parameter gather needs."""
    n = _axes_size(axis_names)
    offs, shard_len = plan.shard_offsets(n)
    out_dtype = jnp.float32 if compress == "bf16" else flat.dtype
    if compress == "bf16":
        flat = flat.astype(jnp.bfloat16)

    def issue(k, src):
        (f,) = src
        b = f[plan.starts[k]:plan.starts[k] + plan.lengths[k]]
        sh = jax.lax.psum_scatter(b, axis_names, scatter_dimension=0,
                                  tiled=True)
        sh = sh.astype(out_dtype)
        return sh / n if mean else sh

    def finish(k, val, out):
        (o,) = out
        return (jax.lax.dynamic_update_slice_in_dim(o, val, offs[k], 0),)

    (shard,) = run_pipeline(plan.n_buckets, issue, finish, (flat,),
                            (jnp.zeros(shard_len, out_dtype),),
                            serialize=serialize)
    return shard


def overlapped_reduce_scatter(tree, axis_names, *, bucket_bytes=64 * 2 ** 20,
                              compress="none", serialize=False, plan=None):
    """Bucket-pipelined ``reduce_scatter_mean``.  Each worker ends with
    the *bucket-major* concatenation of its per-bucket shard slices —
    a fixed permutation of the contiguous unbucketed shard, with the
    same length, so elementwise optimizer state (the flat moment
    vectors ``init_train_state`` builds) is layout-compatible.
    Reconstruct the replicated tree with ``overlapped_all_gather``
    under the same plan.  ``compress="bf16"`` reduces each bucket in
    bfloat16 on the wire but accumulates the shard in float32 (the
    fp32 master shard).  Pass ``plan`` to pin the bucket partition
    (e.g. a TrainState ``layout.plan()``) instead of re-deriving it."""
    if not jax.tree_util.tree_leaves(tree):
        raise ValueError("overlapped_reduce_scatter: empty pytree")
    n = _axes_size(axis_names)
    flat, spec = flatten_padded(tree, n)
    if plan is None:
        plan = plan_buckets(flat.size, bucket_bytes=bucket_bytes,
                            itemsize=flat.dtype.itemsize, align=n)
    shard = overlapped_reduce_scatter_flat(
        flat, axis_names, plan, mean=True, compress=compress,
        serialize=serialize)
    return shard, spec, plan


def plan_local_shard(flat, axis_names, plan: BucketPlan):
    """This worker's bucket-major shard of a replicated padded vector —
    the slice layout ``overlapped_reduce_scatter`` produces (the
    bucketed analogue of ``collectives.local_shard``)."""
    n = _axes_size(axis_names)
    idx = jax.lax.axis_index(axis_names)
    pieces = []
    for k in range(plan.n_buckets):
        b = flat[plan.starts[k]:plan.starts[k] + plan.lengths[k]]
        pieces.append(jax.lax.dynamic_slice_in_dim(
            b, idx * (plan.lengths[k] // n), plan.lengths[k] // n))
    return jnp.concatenate(pieces) if len(pieces) > 1 else pieces[0]


def overlapped_all_gather_flat(shard, axis_names, plan: BucketPlan, *,
                               serialize=False):
    """Bucket-pipelined all-gather of a bucket-major shard back into
    the full *padded* flat vector (each bucket's gather overlapping the
    previous bucket's write-back)."""
    n = _axes_size(axis_names)
    offs, _ = plan.shard_offsets(n)

    def issue(k, src):
        (sh,) = src
        piece = sh[offs[k]:offs[k] + plan.lengths[k] // n]
        return jax.lax.all_gather(piece, axis_names, axis=0, tiled=True)

    def finish(k, val, out):
        (o,) = out
        return (jax.lax.dynamic_update_slice_in_dim(
            o, val, plan.starts[k], 0),)

    (flat,) = run_pipeline(plan.n_buckets, issue, finish, (shard,),
                           (jnp.zeros(plan.padded_total, shard.dtype),),
                           serialize=serialize)
    return flat


def overlapped_all_gather(shard, axis_names, spec, plan: BucketPlan, *,
                          serialize=False):
    """Bucket-pipelined inverse of ``overlapped_reduce_scatter`` /
    ``plan_local_shard``: gather every bucket's shard piece and rebuild
    the full unpadded pytree."""
    flat = overlapped_all_gather_flat(shard, axis_names, plan,
                                      serialize=serialize)
    return unflatten_padded(flat, spec)


# --------------------------------------------------------------------------
# hierarchical (two-level) bucket pipelines — zero1_hier / zero3_hier.
# Per bucket the collective is STAGED: reduce-scatter over the fast
# intra-pod axis (ICI) then reduce-scatter of the 1/n_intra piece over
# the pod axis (DCN carries only 1/n_intra of the bucket); the gather
# runs the inverse (small DCN gather first, big ICI gather second).
# Ownership matches collectives.hier_reduce_scatter_mean under the
# intra-major linearisation (axis order (intra, inter)), so the
# bucket-major shard layout is plan_local_shard's with axes=(intra,
# inter) — the same Layout/plan contract the single-level pipelines use.
# --------------------------------------------------------------------------

def overlapped_hier_reduce_scatter_flat(flat, intra_axis, inter_axis,
                                        plan: BucketPlan, *, mean=True,
                                        compress="none", serialize=False):
    """Two-level bucket-pipelined reduce-scatter of an already-padded
    flat vector (``flat.size == plan.padded_total``, plan aligned to
    n_intra·n_pods) into this worker's bucket-major shard.  Bucket
    *k*'s ICI+DCN stage pair is issued while bucket *k-1*'s shard piece
    is still being written back — the DCN stage of one bucket hides
    behind the ICI stage of the next.  ``mean=False`` returns the plain
    sum (the cotangent form zero3_hier's parameter gather needs)."""
    n_intra = axis_size(intra_axis)
    n = n_intra * axis_size(inter_axis)
    offs, shard_len = plan.shard_offsets(n)
    out_dtype = jnp.float32 if compress == "bf16" else flat.dtype
    if compress == "bf16":
        flat = flat.astype(jnp.bfloat16)

    def issue(k, src):
        (f,) = src
        b = f[plan.starts[k]:plan.starts[k] + plan.lengths[k]]
        sh = jax.lax.psum_scatter(b, intra_axis, scatter_dimension=0,
                                  tiled=True)
        sh = jax.lax.psum_scatter(sh, inter_axis, scatter_dimension=0,
                                  tiled=True)
        sh = sh.astype(out_dtype)
        return sh / n if mean else sh

    def finish(k, val, out):
        (o,) = out
        return (jax.lax.dynamic_update_slice_in_dim(o, val, offs[k], 0),)

    (shard,) = run_pipeline(plan.n_buckets, issue, finish, (flat,),
                            (jnp.zeros(shard_len, out_dtype),),
                            serialize=serialize)
    return shard


def overlapped_hier_all_gather_flat(shard, intra_axis, inter_axis,
                                    plan: BucketPlan, *, serialize=False):
    """Two-level bucket-pipelined all-gather of a bucket-major shard
    back into the full padded flat vector: per bucket, the small
    cross-pod gather first (DCN moves 1/n_intra of the bucket), then
    the big intra-pod gather over ICI — the inverse staging of
    :func:`overlapped_hier_reduce_scatter_flat`."""
    n = axis_size(intra_axis) * axis_size(inter_axis)
    offs, _ = plan.shard_offsets(n)

    def issue(k, src):
        (sh,) = src
        piece = sh[offs[k]:offs[k] + plan.lengths[k] // n]
        piece = jax.lax.all_gather(piece, inter_axis, axis=0, tiled=True)
        return jax.lax.all_gather(piece, intra_axis, axis=0, tiled=True)

    def finish(k, val, out):
        (o,) = out
        return (jax.lax.dynamic_update_slice_in_dim(
            o, val, plan.starts[k], 0),)

    (flat,) = run_pipeline(plan.n_buckets, issue, finish, (shard,),
                           (jnp.zeros(plan.padded_total, shard.dtype),),
                           serialize=serialize)
    return flat


# --------------------------------------------------------------------------
# HLO inspection: find (and textually perform) the async split
# --------------------------------------------------------------------------

_COLLECTIVE_OPS = ("all-reduce", "reduce-scatter", "all-gather",
                   "collective-permute", "all-to-all")
_HEAVY_OPS = ("dot", "convolution", "fusion")
_SKIP_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
             "opt-barrier", "bitcast", "reshape", "broadcast", "copy",
             "iota")
# computation headers print either with a full signature
# ("%name (args) -> type {") or bare ("region_0.28 {")
_COMP_HEAD_RE = re.compile(
    r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{\s*$")
_NAME_RE = re.compile(r"%?([\w.\-]+)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(type_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_text):
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        total += size * _DTYPE_BYTES.get(dt, 4)
    return total


def _split_instruction(line: str):
    """Parse one HLO instruction line -> (name, type, opcode, operand
    text, line) or None.  Handles tuple-typed results and both the
    typed-operand (compiled) and bare-operand (unoptimized) printers."""
    m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", line)
    if not m:
        return None
    name, rest = m.groups()
    rest = rest.lstrip()
    type_text = ""
    if rest.startswith("("):                      # tuple-typed result
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                type_text, rest = rest[:i + 1], rest[i + 1:].lstrip()
                break
    else:
        parts = rest.split(None, 1)
        if len(parts) != 2:
            return None
        type_text, rest = parts
    m2 = re.match(r"([\w\-]+)\(", rest)
    if not m2:
        return None
    opcode = m2.group(1)
    depth, i = 0, m2.end() - 1
    for j in range(i, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            return name, type_text, opcode, rest[i + 1:j], line
    return name, type_text, opcode, rest[i + 1:], line


def parse_hlo_computations(hlo_text: str) -> dict:
    """{computation name: [(name, type, opcode, operand_text, line)]}"""
    comps, cur = {}, None
    for line in hlo_text.splitlines():
        if "=" not in line:
            hm = _COMP_HEAD_RE.match(line)
            if hm:
                cur = hm.group(2)
                comps[cur] = []
            continue
        if cur is None:
            continue
        instr = _split_instruction(line)
        if instr:
            comps[cur].append(instr)
    return comps


def _reachable(adj, roots):
    seen, stack = set(), list(roots)
    while stack:
        node = stack.pop()
        for nxt in adj.get(node, ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return seen


def async_overlap_report(hlo_text: str, *, min_bytes: int = 1024) -> dict:
    """Which collectives admit latency hiding, straight from dataflow.

    A collective C (moving ≥ ``min_bytes``) is *overlappable* when some
    instruction is concurrent with it (neither ancestor nor descendant)
    AND is real work: heavy compute (dot/convolution/fusion) or a
    descendant of another big collective (a neighbouring bucket's
    epilogue).  That is precisely the window XLA's async collective
    creator + latency-hiding scheduler exploit; the serialized schedule
    chains every bucket through an optimization_barrier, so its windows
    are empty and nothing is overlappable."""
    per_comp = {}
    total_pairs = total_coll = 0
    by_kind = {}
    for comp, instrs in parse_hlo_computations(hlo_text).items():
        defined = {i[0] for i in instrs}
        opcode = {i[0]: i[2] for i in instrs}
        deps = {}
        for name, _t, _op, operands, _l in instrs:
            deps[name] = {tok for tok in _NAME_RE.findall(operands)
                          if tok in defined and tok != name}
        users = {}
        for name, ds in deps.items():
            for d in ds:
                users.setdefault(d, set()).add(name)
        colls = [i for i in instrs if i[2] in _COLLECTIVE_OPS
                 and _shape_bytes(i[1]) >= min_bytes]
        total_coll += len(colls)
        if not colls:
            continue
        desc = {i[0]: _reachable(users, [i[0]]) for i in colls}
        entries = []
        for name, type_text, op, _operands, _line in colls:
            anc = _reachable(deps, [name])
            concurrent = defined - anc - desc[name] - {name}
            window = [
                o for o in concurrent
                if opcode[o] not in _SKIP_OPS
                and (opcode[o] in _HEAVY_OPS
                     or any(o in d for c, d in desc.items() if c != name))]
            entries.append({"name": name, "kind": op,
                            "bytes": _shape_bytes(type_text),
                            "window_ops": len(window),
                            "overlappable": bool(window)})
            if window:
                total_pairs += 1
                by_kind[op] = by_kind.get(op, 0) + 1
        per_comp[comp] = entries
    return {"pairs": total_pairs, "collectives": total_coll,
            "by_kind": by_kind, "computations": per_comp}


def asyncify_hlo(hlo_text: str, *, min_bytes: int = 1024):
    """Perform, at text level, the rewrite XLA's AsyncCollectiveCreator
    applies on async-capable backends: every overlappable collective
    ``X = all-reduce(...)`` becomes an ``all-reduce-start`` at its
    issue point plus an ``X = all-reduce-done(...)`` immediately before
    its first consumer, leaving the hidden window between the two.
    Returns ``(rewritten_text, report)`` — the CPU backend never emits
    these pairs itself, so this is how the dry-run (and the tests)
    surface what a TPU/GPU latency-hiding schedule would do."""
    report = async_overlap_report(hlo_text, min_bytes=min_bytes)
    overlappable = {e["name"]: e for comp in report["computations"].values()
                    for e in comp if e["overlappable"]}
    if not overlappable:
        return hlo_text, report
    lines = hlo_text.splitlines()
    out = []
    pending_done = []                       # (collective name, done_line)
    for line in lines:
        instr = _split_instruction(line) if "=" in line else None
        if instr and pending_done:
            # flush a -done immediately before its first textual user
            used = set(_NAME_RE.findall(instr[3]))
            for entry in [e for e in pending_done if e[0] in used]:
                pending_done.remove(entry)
                out.append(entry[1])
        name = instr[0] if instr else None
        if name in overlappable:
            kind = overlappable[name]["kind"]
            type_text = instr[1]
            start_name = name.replace(kind, f"{kind}-start", 1) \
                if name.startswith(kind) else f"{kind}-start.{name}"
            indent = line[:len(line) - len(line.lstrip())]
            start_line = line.replace("ROOT ", "", 1) \
                             .replace(f"{name} = ", f"{start_name} = ", 1) \
                             .replace(f" {kind}(", f" {kind}-start(", 1)
            out.append(start_line)
            root = "ROOT " if "ROOT " in line else ""
            done = (f"{indent}{root}{name} = {type_text} {kind}-done("
                    f"{start_name})")
            pending_done.append((name, done))
        else:
            out.append(line)
        if line.strip() == "}" and pending_done:
            # collective with no textual consumer in this computation
            for _, done in pending_done:
                out.insert(len(out) - 1, done)
            pending_done = []
    return "\n".join(out), report


def lowered_hlo_text(lowered) -> str:
    """Pre-optimisation HLO of a ``jax.jit(...).lower(...)`` result —
    the dialect where explicit shard_map collectives and
    optimization_barriers are both still visible."""
    return lowered.compiler_ir("hlo").as_hlo_text()
