"""First-class train-state contract for data-parallel training.

The paper keeps a full model replica and full optimizer state on every
MPI rank (§3.3.3) — which caps model size at single-device memory.  The
ZeRO family removes that wall by sharding, per rank, first the
optimizer state (zero1), then the gradients (zero2), then the
parameters themselves (zero3).  What all of those need is a *contract*:
a single object that says what each worker physically holds, so the
train step, the collectives, and the checkpoint store all agree.

``TrainState`` is that object — a dataclass pytree carrying

  * ``params``     — the replicated parameter pytree (``replicated`` /
                     ``zero1`` / ``zero2``), or this worker's flat 1-D
                     parameter shard (``zero3``);
  * ``opt_state``  — ``optimizer.init(params)`` (replicated) or the
                     optimizer state over the flat 1/p shard (zero*);
  * ``step``       — replicated int32 global step counter;
  * ``layout``     — a static :class:`Layout` descriptor (pytree *aux
                     data*, so jit specialises on it).

``Layout`` pins down everything needed to interpret the leaves without
looking at the arrays: the sharding kind, the mesh axes the shards
span, the shard count, the flattened/padded element counts, and —
because the overlap scheduler stores shards *bucket-major* — the bucket
size that generated the permutation.  ``checkpoint.store`` keys saved
shards by ``(worker, layout)`` and reshards between any two layouts on
restore, so no all-gather is needed on either side.

``init_train_state(optimizer, params, mesh, dp)`` replaces PR 1's
``init_zero1_opt_state`` and generalises it to every strategy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.collectives import dp_world_size as _world
from repro.core.overlap import BucketPlan, plan_buckets

SHARDED_KINDS = {"zero1", "zero2", "zero3"}
LAYOUT_KINDS = {"replicated"} | SHARDED_KINDS


def register_layout_kind(kind: str, *, sharded: bool):
    """Make a new layout kind legal (strategy registration calls this,
    so custom strategies registered through repro.core.strategy can
    carry their own kind through the TrainState/checkpoint machinery).
    A kind's shardedness is process-global state shared by every layout
    of that kind, so re-registering an existing kind the other way is
    rejected — in particular a sharded strategy that forgets to set its
    own ``kind`` (and so inherits "replicated") fails HERE, loudly, not
    by silently marking every replicated layout sharded."""
    if not kind or not isinstance(kind, str):
        raise ValueError(f"layout kind must be a non-empty str, got {kind!r}")
    if kind in LAYOUT_KINDS and (kind in SHARDED_KINDS) != sharded:
        raise ValueError(
            f"layout kind {kind!r} is already registered as "
            f"{'sharded' if kind in SHARDED_KINDS else 'replicated'}; a "
            "sharded strategy must declare its own kind (set the `kind` "
            "class attribute) instead of re-flagging an existing one")
    LAYOUT_KINDS.add(kind)
    if sharded:
        SHARDED_KINDS.add(kind)


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static descriptor of how a TrainState's leaves are laid out.

    kind          — "replicated" | "zero1" | "zero2" | "zero3".
    axes          — mesh axis names the shards span (the batch axes).
    num_shards    — p, the data-parallel world size (1 for replicated).
    total         — unpadded element count of the flattened param tree.
    padded_total  — total padded up to a multiple of num_shards; every
                    flat sharded leaf has this global length.
    bucket_bytes  — None: shards are *contiguous* slices of the
                    flattened vector (``local_shard``).  Set: shards
                    are *bucket-major* under ``plan_buckets(...,
                    align=num_shards)`` (``plan_local_shard``) — the
                    layout the overlap scheduler produces.
    param_spec    — zero3 only: the ``(treedef, shapes, sizes, total)``
                    spec ``unflatten_padded`` needs to rebuild the
                    param pytree from the gathered flat vector.
    param_dtypes  — zero3 only: per-leaf dtype names, to cast the
                    rebuilt pytree back (flatten promotes dtypes).
    strategy      — registry name of the Strategy that built this
                    layout (None for bare replicated states built
                    without one).  Checkpoints record it so a restore
                    can resolve the strategy — and fail loudly, listing
                    the registered names, when it is unknown.
    """
    kind: str = "replicated"
    axes: tuple = ()
    num_shards: int = 1
    total: int = 0
    padded_total: int = 0
    bucket_bytes: Optional[int] = None
    param_spec: Any = None
    param_dtypes: tuple = ()
    strategy: Optional[str] = None

    def __post_init__(self):
        if self.kind not in LAYOUT_KINDS:
            raise ValueError(f"unknown layout kind {self.kind!r}")

    @property
    def sharded(self) -> bool:
        return self.kind in SHARDED_KINDS

    @property
    def params_flat(self) -> bool:
        """True when ``params`` is the flat 1/p shard vector (zero3 and
        any custom params-sharded strategy) — signalled by the presence
        of ``param_spec``, which every such layout must carry so the
        pytree can be rebuilt."""
        return self.param_spec is not None

    @property
    def shard_len(self) -> int:
        return self.padded_total // max(self.num_shards, 1)

    def plan(self) -> Optional[BucketPlan]:
        """The bucket plan generating the shard permutation, or None for
        the contiguous layout.  Deterministic given the layout alone
        (itemsize 4: flat master vectors are fp32)."""
        if self.bucket_bytes is None:
            return None
        return plan_buckets(self.padded_total, bucket_bytes=self.bucket_bytes,
                            itemsize=4, align=self.num_shards)

    def to_json(self) -> dict:
        """The portable identity of this layout (checkpoint meta)."""
        return {"kind": self.kind, "axes": list(self.axes),
                "num_shards": self.num_shards, "total": self.total,
                "padded_total": self.padded_total,
                "bucket_bytes": self.bucket_bytes,
                "strategy": self.strategy}

    @staticmethod
    def from_json(d: dict) -> "Layout":
        return Layout(kind=d["kind"], axes=tuple(d["axes"]),
                      num_shards=int(d["num_shards"]), total=int(d["total"]),
                      padded_total=int(d["padded_total"]),
                      bucket_bytes=d.get("bucket_bytes"),
                      strategy=d.get("strategy"))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    """The train-step contract: ``step(state, batch) -> (state, metrics)``.

    ``layout`` is pytree metadata — two TrainStates with different
    layouts have different treedefs, so a jitted step retraces rather
    than silently misreading shards."""
    params: Any
    opt_state: Any
    step: Any
    layout: Layout = dataclasses.field(
        default=Layout(), metadata=dict(static=True))


def _tree_total(params) -> int:
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(params))


def _param_spec_of(params):
    """(treedef, shapes, sizes, total) — host-side, no tracing; the
    exact spec ``flatten_padded`` would return."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(int(np.prod(s)) for s in shapes)
    return (treedef, shapes, sizes, int(sum(sizes)))


def expected_bucket_bytes(dp) -> Optional[int]:
    """Whether (and at what granularity) a strategy's persistent shards
    are bucket-major — a thin driver over the registered strategy's
    ``bucket_layout`` hook.  The permutation only arises where the step
    runs the bucket scheduler against the shards: zero1 pipelines its
    single post-accumulation reduce-scatter/all-gather pair at any
    microbatch count, zero3 bucket-pipelines its per-step parameter
    gathers, but zero2's per-microbatch reduce-scatters stay contiguous
    (its shards only go bucket-major in the degenerate
    microbatches == 1 case, which shares zero1's tail)."""
    from repro.core.strategy import get_strategy  # local: no cycle
    return get_strategy(dp.strategy).bucket_layout(dp)


def state_layout(dp, mesh, params) -> Layout:
    """The Layout ``make_dp_train_step(dp)`` requires of its input
    state — asked of the registered strategy."""
    from repro.core.strategy import get_strategy  # local: no cycle
    return get_strategy(dp.strategy).layout(mesh, dp, params)


def opt_state_specs(opt_state_shape, shard_spec):
    """Spec tree for a sharded-strategy opt_state: scalars (step
    counters) replicated, flat moment vectors sharded on dim 0."""
    return jax.tree_util.tree_map(
        lambda l: P() if getattr(l, "ndim", 0) == 0 else shard_spec,
        opt_state_shape)


def init_train_state(optimizer, params, mesh=None, dp=None) -> TrainState:
    """Materialise the TrainState ``make_dp_train_step(..., dp)``
    consumes — a thin driver over the registered strategy's ``init``
    hook.  ``mesh=None`` yields the plain replicated state —
    ``make_sequential_step`` uses that form.

    ``params`` leaves may be ``jax.ShapeDtypeStruct``s: the state is
    then built from shape structs alone (zero-filled values — a restore
    template), which for zero3 means the full parameter pytree is
    NEVER materialised anywhere, keeping 1/p residency end to end."""
    from repro.core.data_parallel import DPConfig  # cycle-free at runtime
    from repro.core.strategy import get_strategy
    dp = dp if dp is not None else DPConfig()
    if mesh is None:
        params = concrete_params(params)
        layout = Layout("replicated", (), 1, _tree_total(params),
                        _tree_total(params))
        return TrainState(params, optimizer.init(params),
                          jnp.zeros((), jnp.int32), layout)
    return get_strategy(dp.strategy).init(optimizer, params, mesh, dp)


def concrete_params(params):
    """Zero-fill any ``ShapeDtypeStruct`` leaves (restore templates)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, l.dtype)
        if isinstance(l, jax.ShapeDtypeStruct) else l, params)


def shard_worker_index(index, per: int) -> int:
    """Which worker owns the shard at `index` (a tuple of slices into
    the global flat leaf).  THE shard-ownership convention — every
    flat sharded leaf is split into `num_shards` contiguous
    `per`-element slices in worker order; the checkpoint store and
    host_params both key worker files/shards through this."""
    start = index[0].start if index else None
    return 0 if start is None else int(start) // per


def assemble_full_flat(shards, layout: Layout) -> np.ndarray:
    """Worker shards (layout order) -> full padded contiguous vector,
    undoing the bucket-major permutation where the layout has one.
    Host-side numpy — this is the resharding primitive the checkpoint
    store uses; no device collective is involved."""
    n = layout.num_shards
    plan = layout.plan()
    if plan is None:
        return np.concatenate(shards)
    full = np.empty(sum(s.size for s in shards), shards[0].dtype)
    offs, _ = plan.shard_offsets(n)
    for k in range(plan.n_buckets):
        pk = plan.lengths[k] // n
        for w in range(n):
            full[plan.starts[k] + w * pk:plan.starts[k] + (w + 1) * pk] = \
                shards[w][offs[k]:offs[k] + pk]
    return full


def split_flat_shards(full_padded, layout: Layout) -> list:
    """Full padded contiguous vector -> worker shards (layout order);
    inverse of :func:`assemble_full_flat`."""
    n = layout.num_shards
    plan = layout.plan()
    if plan is None:
        per = full_padded.size // n
        return [full_padded[w * per:(w + 1) * per] for w in range(n)]
    shards = [np.empty(full_padded.size // n, full_padded.dtype)
              for _ in range(n)]
    offs, _ = plan.shard_offsets(n)
    for k in range(plan.n_buckets):
        pk = plan.lengths[k] // n
        for w in range(n):
            shards[w][offs[k]:offs[k] + pk] = \
                full_padded[plan.starts[k] + w * pk:
                            plan.starts[k] + (w + 1) * pk]
    return shards


def host_params(state: TrainState):
    """Host copy of the FULL parameter pytree, whatever the layout —
    an eval/debug utility.  For flat-params layouts (zero3, or any
    custom params-sharded strategy whose layout carries ``param_spec``)
    this reassembles the flat shards on host (numpy, per-shard reads;
    no device all-gather)."""
    if not state.layout.params_flat:
        return state.params
    layout = state.layout
    per = layout.shard_len
    shards = [None] * layout.num_shards
    for sh in state.params.addressable_shards:
        shards[shard_worker_index(sh.index, per)] = np.asarray(sh.data)
    if any(s is None for s in shards):
        raise ValueError("host_params: not all shards addressable")
    flat = assemble_full_flat(shards, layout)[:layout.total]
    treedef, shapes, sizes, _ = layout.param_spec
    leaves, off = [], 0
    for shp, sz, dt in zip(shapes, sizes, layout.param_dtypes):
        leaves.append(flat[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, leaves)


def check_layout(layout: Layout, expected_kind: str, dp, mesh):
    """Loud contract check — the migration path from the old loose
    ``(params, opt_state)`` tuples lands here when states and configs
    drift apart."""
    if not isinstance(layout, Layout):
        raise TypeError(
            "make_dp_train_step now takes a TrainState "
            "(see docs/data_parallel.md §Migrating): build one with "
            "init_train_state(optimizer, params, mesh, dp)")
    if layout.kind != expected_kind:
        raise ValueError(
            f"TrainState layout kind {layout.kind!r} does not match "
            f"DPConfig strategy {dp.strategy!r} (expected "
            f"{expected_kind!r}); rebuild with init_train_state(...) or "
            "reshard via checkpoint.restore_sharded_checkpoint")
    if layout.sharded and layout.num_shards != _world(mesh):
        raise ValueError(
            f"TrainState sharded over {layout.num_shards} workers but "
            f"mesh has {_world(mesh)}; reshard via the checkpoint store")
    if layout.sharded and layout.bucket_bytes != expected_bucket_bytes(dp):
        raise ValueError(
            f"TrainState shard layout is "
            f"{'bucket-major' if layout.bucket_bytes else 'contiguous'} "
            f"(bucket_bytes={layout.bucket_bytes}) but DPConfig("
            f"overlap={dp.overlap!r}, bucket_bytes={dp.bucket_bytes}, "
            f"microbatches={dp.microbatches}) expects "
            f"bucket_bytes={expected_bucket_bytes(dp)}; rebuild with "
            "init_train_state(...) or reshard via the checkpoint store")
