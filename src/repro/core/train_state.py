"""First-class train-state contract for data-parallel training.

The paper keeps a full model replica and full optimizer state on every
MPI rank (§3.3.3) — which caps model size at single-device memory.  The
ZeRO family removes that wall by sharding, per rank, first the
optimizer state (zero1), then the gradients (zero2), then the
parameters themselves (zero3).  What all of those need is a *contract*:
a single object that says what each worker physically holds, so the
train step, the collectives, and the checkpoint store all agree.

``TrainState`` is that object — a dataclass pytree carrying

  * ``params``     — the replicated parameter pytree (``replicated`` /
                     ``zero1`` / ``zero2``), or this worker's flat 1-D
                     parameter shard (``zero3``);
  * ``opt_state``  — ``optimizer.init(params)`` (replicated) or the
                     optimizer state over the flat 1/p shard (zero*);
  * ``step``       — replicated int32 global step counter;
  * ``layout``     — a static :class:`Layout` descriptor (pytree *aux
                     data*, so jit specialises on it).

``Layout`` pins down everything needed to interpret the leaves without
looking at the arrays: the sharding kind, the mesh axes the shards
span, the shard count, the flattened/padded element counts, and —
because the overlap scheduler stores shards *bucket-major* — the bucket
size that generated the permutation.  ``checkpoint.store`` keys saved
shards by ``(worker, layout)`` and reshards between any two layouts on
restore, so no all-gather is needed on either side.

``init_train_state(optimizer, params, mesh, dp)`` replaces PR 1's
``init_zero1_opt_state`` and generalises it to every strategy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, shard_map_kwargs
from repro.core.collectives import (
    axes_spec as _axes_spec, dp_batch_axes as _dp_axes,
    dp_world_size as _world, flatten_padded, local_shard,
)
from repro.core.overlap import BucketPlan, plan_buckets, plan_local_shard

SHARDED_KINDS = ("zero1", "zero2", "zero3")
LAYOUT_KINDS = ("replicated",) + SHARDED_KINDS


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static descriptor of how a TrainState's leaves are laid out.

    kind          — "replicated" | "zero1" | "zero2" | "zero3".
    axes          — mesh axis names the shards span (the batch axes).
    num_shards    — p, the data-parallel world size (1 for replicated).
    total         — unpadded element count of the flattened param tree.
    padded_total  — total padded up to a multiple of num_shards; every
                    flat sharded leaf has this global length.
    bucket_bytes  — None: shards are *contiguous* slices of the
                    flattened vector (``local_shard``).  Set: shards
                    are *bucket-major* under ``plan_buckets(...,
                    align=num_shards)`` (``plan_local_shard``) — the
                    layout the overlap scheduler produces.
    param_spec    — zero3 only: the ``(treedef, shapes, sizes, total)``
                    spec ``unflatten_padded`` needs to rebuild the
                    param pytree from the gathered flat vector.
    param_dtypes  — zero3 only: per-leaf dtype names, to cast the
                    rebuilt pytree back (flatten promotes dtypes).
    """
    kind: str = "replicated"
    axes: tuple = ()
    num_shards: int = 1
    total: int = 0
    padded_total: int = 0
    bucket_bytes: Optional[int] = None
    param_spec: Any = None
    param_dtypes: tuple = ()

    def __post_init__(self):
        if self.kind not in LAYOUT_KINDS:
            raise ValueError(f"unknown layout kind {self.kind!r}")

    @property
    def sharded(self) -> bool:
        return self.kind in SHARDED_KINDS

    @property
    def shard_len(self) -> int:
        return self.padded_total // max(self.num_shards, 1)

    def plan(self) -> Optional[BucketPlan]:
        """The bucket plan generating the shard permutation, or None for
        the contiguous layout.  Deterministic given the layout alone
        (itemsize 4: flat master vectors are fp32)."""
        if self.bucket_bytes is None:
            return None
        return plan_buckets(self.padded_total, bucket_bytes=self.bucket_bytes,
                            itemsize=4, align=self.num_shards)

    def to_json(self) -> dict:
        """The portable identity of this layout (checkpoint meta)."""
        return {"kind": self.kind, "axes": list(self.axes),
                "num_shards": self.num_shards, "total": self.total,
                "padded_total": self.padded_total,
                "bucket_bytes": self.bucket_bytes}

    @staticmethod
    def from_json(d: dict) -> "Layout":
        return Layout(kind=d["kind"], axes=tuple(d["axes"]),
                      num_shards=int(d["num_shards"]), total=int(d["total"]),
                      padded_total=int(d["padded_total"]),
                      bucket_bytes=d.get("bucket_bytes"))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    """The train-step contract: ``step(state, batch) -> (state, metrics)``.

    ``layout`` is pytree metadata — two TrainStates with different
    layouts have different treedefs, so a jitted step retraces rather
    than silently misreading shards."""
    params: Any
    opt_state: Any
    step: Any
    layout: Layout = dataclasses.field(
        default=Layout(), metadata=dict(static=True))


def _tree_total(params) -> int:
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(params))


def _param_spec_of(params):
    """(treedef, shapes, sizes, total) — host-side, no tracing; the
    exact spec ``flatten_padded`` would return."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(int(np.prod(s)) for s in shapes)
    return (treedef, shapes, sizes, int(sum(sizes)))


def expected_bucket_bytes(dp) -> Optional[int]:
    """Whether (and at what granularity) a strategy's persistent shards
    are bucket-major.  The permutation only arises where the step runs
    the bucket scheduler against the shards: zero1 pipelines its single
    post-accumulation reduce-scatter/all-gather pair at any microbatch
    count, zero3 bucket-pipelines its per-step parameter gathers, but
    zero2's per-microbatch reduce-scatters stay contiguous (its shards
    only go bucket-major in the degenerate microbatches == 1 case,
    which shares zero1's tail)."""
    if dp.strategy not in SHARDED_KINDS or not dp.overlap:
        return None
    if dp.strategy == "zero2" and dp.microbatches > 1:
        return None
    return dp.bucket_bytes


def state_layout(dp, mesh, params) -> Layout:
    """The Layout ``make_dp_train_step(dp)`` requires of its input
    state."""
    axes = _dp_axes(mesh)
    n = _world(mesh)
    total = _tree_total(params)
    padded = total + (-total) % n
    kind = dp.strategy if (dp.strategy in SHARDED_KINDS
                           and dp.sync == "grads") else "replicated"
    if kind == "replicated":
        return Layout("replicated", axes, n, total, total)
    if kind == "zero3":
        treedef, shapes, sizes, _ = spec = _param_spec_of(params)
        dtypes = tuple(str(l.dtype)
                       for l in jax.tree_util.tree_leaves(params))
        return Layout(kind, axes, n, total, padded,
                      expected_bucket_bytes(dp),
                      param_spec=spec, param_dtypes=dtypes)
    return Layout(kind, axes, n, total, padded, expected_bucket_bytes(dp))


def opt_state_specs(opt_state_shape, shard_spec):
    """Spec tree for a sharded-strategy opt_state: scalars (step
    counters) replicated, flat moment vectors sharded on dim 0."""
    return jax.tree_util.tree_map(
        lambda l: P() if getattr(l, "ndim", 0) == 0 else shard_spec,
        opt_state_shape)


def init_train_state(optimizer, params, mesh=None, dp=None) -> TrainState:
    """Materialise the TrainState ``make_dp_train_step(..., dp)``
    consumes.  ``mesh=None`` (or a replicated strategy) yields the
    plain replicated state — ``make_sequential_step`` uses that form.

    For zero1/zero2 the params stay replicated and the optimizer state
    is built over this worker's 1/p flat param shard; for zero3 the
    params themselves are scattered to flat shards and the full pytree
    never lands on any single device."""
    from repro.core.data_parallel import DPConfig  # cycle-free at runtime
    dp = dp if dp is not None else DPConfig()
    step0 = jnp.zeros((), jnp.int32)
    if mesh is None:
        layout = Layout("replicated", (), 1, _tree_total(params),
                        _tree_total(params))
        return TrainState(params, optimizer.init(params), step0, layout)
    # commit every leaf to the mesh so shardings are explicit — that is
    # what lets the checkpoint store save/restore per-shard and the
    # jitted step take donated, committed inputs without transfers
    rep = jax.sharding.NamedSharding(mesh, P())
    step0 = jax.device_put(step0, rep)
    layout = state_layout(dp, mesh, params)
    if not layout.sharded:
        params = jax.device_put(params, rep)
        opt_state = jax.device_put(optimizer.init(params), rep)
        return TrainState(params, opt_state, step0, layout)
    if layout.kind != "zero3":
        # zero1/zero2 keep replicated params as state; zero3's params
        # come back sharded from the init below, so the full input
        # pytree is consumed once and never committed to the devices.
        # (Construction still materialises the full pytree transiently
        # — per-shard init from shape structs is the multi-pod-era
        # follow-on; the 1/p residency contract holds between steps.)
        params = jax.device_put(params, rep)

    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        raise ValueError("init_train_state: empty param tree")
    axes, n = layout.axes, layout.num_shards
    sspec = _axes_spec(axes)
    plan = layout.plan()
    flat_dtype = jnp.result_type(*[l.dtype for l in leaves])

    def initw(params):
        flat, _ = flatten_padded(params, n)
        pshard = (plan_local_shard(flat, axes, plan) if plan is not None
                  else local_shard(flat, axes))
        opt = optimizer.init({"flat": pshard})
        if layout.kind == "zero3":
            return pshard, opt
        return opt

    opt_shape = jax.eval_shape(
        optimizer.init,
        {"flat": jax.ShapeDtypeStruct((layout.shard_len,), flat_dtype)})
    ospecs = opt_state_specs(opt_shape, sspec)
    out_specs = (sspec, ospecs) if layout.kind == "zero3" else ospecs
    wrapped = shard_map(
        initw, mesh=mesh, in_specs=(P(),), out_specs=out_specs,
        **shard_map_kwargs(check_vma=False))
    out = jax.jit(wrapped)(params)
    if layout.kind == "zero3":
        pshard, opt_state = out
        return TrainState(pshard, opt_state, step0, layout)
    return TrainState(params, out, step0, layout)


def shard_worker_index(index, per: int) -> int:
    """Which worker owns the shard at `index` (a tuple of slices into
    the global flat leaf).  THE shard-ownership convention — every
    flat sharded leaf is split into `num_shards` contiguous
    `per`-element slices in worker order; the checkpoint store and
    host_params both key worker files/shards through this."""
    start = index[0].start if index else None
    return 0 if start is None else int(start) // per


def assemble_full_flat(shards, layout: Layout) -> np.ndarray:
    """Worker shards (layout order) -> full padded contiguous vector,
    undoing the bucket-major permutation where the layout has one.
    Host-side numpy — this is the resharding primitive the checkpoint
    store uses; no device collective is involved."""
    n = layout.num_shards
    plan = layout.plan()
    if plan is None:
        return np.concatenate(shards)
    full = np.empty(sum(s.size for s in shards), shards[0].dtype)
    offs, _ = plan.shard_offsets(n)
    for k in range(plan.n_buckets):
        pk = plan.lengths[k] // n
        for w in range(n):
            full[plan.starts[k] + w * pk:plan.starts[k] + (w + 1) * pk] = \
                shards[w][offs[k]:offs[k] + pk]
    return full


def split_flat_shards(full_padded, layout: Layout) -> list:
    """Full padded contiguous vector -> worker shards (layout order);
    inverse of :func:`assemble_full_flat`."""
    n = layout.num_shards
    plan = layout.plan()
    if plan is None:
        per = full_padded.size // n
        return [full_padded[w * per:(w + 1) * per] for w in range(n)]
    shards = [np.empty(full_padded.size // n, full_padded.dtype)
              for _ in range(n)]
    offs, _ = plan.shard_offsets(n)
    for k in range(plan.n_buckets):
        pk = plan.lengths[k] // n
        for w in range(n):
            shards[w][offs[k]:offs[k] + pk] = \
                full_padded[plan.starts[k] + w * pk:
                            plan.starts[k] + (w + 1) * pk]
    return shards


def host_params(state: TrainState):
    """Host copy of the FULL parameter pytree, whatever the layout —
    an eval/debug utility.  For zero3 this reassembles the flat shards
    on host (numpy, per-shard reads; no device all-gather)."""
    if state.layout.kind != "zero3":
        return state.params
    layout = state.layout
    per = layout.shard_len
    shards = [None] * layout.num_shards
    for sh in state.params.addressable_shards:
        shards[shard_worker_index(sh.index, per)] = np.asarray(sh.data)
    if any(s is None for s in shards):
        raise ValueError("host_params: not all shards addressable")
    flat = assemble_full_flat(shards, layout)[:layout.total]
    treedef, shapes, sizes, _ = layout.param_spec
    leaves, off = [], 0
    for shp, sz, dt in zip(shapes, sizes, layout.param_dtypes):
        leaves.append(flat[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, leaves)


def check_layout(layout: Layout, expected_kind: str, dp, mesh):
    """Loud contract check — the migration path from the old loose
    ``(params, opt_state)`` tuples lands here when states and configs
    drift apart."""
    if not isinstance(layout, Layout):
        raise TypeError(
            "make_dp_train_step now takes a TrainState "
            "(see docs/data_parallel.md §Migrating): build one with "
            "init_train_state(optimizer, params, mesh, dp)")
    if layout.kind != expected_kind:
        raise ValueError(
            f"TrainState layout kind {layout.kind!r} does not match "
            f"DPConfig strategy {dp.strategy!r} (expected "
            f"{expected_kind!r}); rebuild with init_train_state(...) or "
            "reshard via checkpoint.restore_sharded_checkpoint")
    if layout.sharded and layout.num_shards != _world(mesh):
        raise ValueError(
            f"TrainState sharded over {layout.num_shards} workers but "
            f"mesh has {_world(mesh)}; reshard via the checkpoint store")
    if layout.sharded and layout.bucket_bytes != expected_bucket_bytes(dp):
        raise ValueError(
            f"TrainState shard layout is "
            f"{'bucket-major' if layout.bucket_bytes else 'contiguous'} "
            f"(bucket_bytes={layout.bucket_bytes}) but DPConfig("
            f"overlap={dp.overlap!r}, bucket_bytes={dp.bucket_bytes}, "
            f"microbatches={dp.microbatches}) expects "
            f"bucket_bytes={expected_bucket_bytes(dp)}; rebuild with "
            "init_train_state(...) or reshard via the checkpoint store")
