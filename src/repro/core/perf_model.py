"""The paper's §3.3.2 performance model, made quantitative.

Paper: per epoch, compute = (m/p)·n²·l FLOP-ish units, communication =
n²·l words, with log(p)-depth allreduce.  Generalised here:

    T(p) = T_compute(p) + T_comm(p)
    T_compute(p) = (m/p) · F_sample / F_rate
    T_comm(p)    = n_sync · ( alpha·ceil(log2 p) + 2·(p-1)/p · V / BW )

where V = parameter bytes (weight averaging) or gradient bytes
(per-step averaging), n_sync = syncs per epoch, alpha = per-message
latency, BW = per-link bandwidth (ring-allreduce volume term).

Calibration: F_sample and F_rate come from a measured single-device run
(benchmarks measure wall time per step), V from the actual parameter
count, so the model's speedup curves are *predictions* that the paper's
figures can be checked against.  Hardware presets: the paper's FDR
InfiniBand cluster and a TPU v5e pod.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Fabric:
    name: str
    bw_bytes: float          # per-link bandwidth, B/s
    alpha: float             # per-collective latency, s


# The paper's cluster: Haswell + FDR InfiniBand (~6.8 GB/s, ~1.5 us MPI lat)
INFINIBAND_FDR = Fabric("infiniband-fdr", 6.8e9, 1.5e-6)
# TPU v5e: ~50 GB/s/link ICI, ~1 us
TPU_V5E_ICI = Fabric("tpu-v5e-ici", 50e9, 1.0e-6)
# cross-pod DCN (multi-pod axis)
TPU_DCN = Fabric("tpu-dcn", 6.25e9, 10e-6)

# TPU v5e per-chip: HBM bandwidth and bf16 peak (serving roofline)
TPU_V5E_HBM_BW = 819e9
TPU_V5E_FLOPS = 197e12


def dnn_flops_per_sample(layer_sizes) -> float:
    """fwd+bwd multiply-accumulate FLOPs for an MLP (paper's n²·l term)."""
    fwd = sum(2.0 * a * b for a, b in zip(layer_sizes[:-1], layer_sizes[1:]))
    return 3.0 * fwd                       # bwd ≈ 2x fwd


def dnn_comm_bytes(layer_sizes, dtype_bytes=4) -> float:
    n = sum(a * b + b for a, b in zip(layer_sizes[:-1], layer_sizes[1:]))
    return dtype_bytes * n


def epoch_time(p, *, samples, flops_per_sample, flops_rate, comm_bytes,
               fabric: Fabric, syncs_per_epoch=1.0):
    """Paper model: strong-scaling epoch time at p workers."""
    t_comp = (samples / p) * flops_per_sample / flops_rate
    t_comm = 0.0
    if p > 1:
        t_comm = syncs_per_epoch * (
            fabric.alpha * math.ceil(math.log2(p))
            + 2.0 * (p - 1) / p * comm_bytes / fabric.bw_bytes)
    return t_comp, t_comm


def speedup_curve(ps, **kw):
    t1_comp, t1_comm = epoch_time(1, **kw)
    t1 = t1_comp + t1_comm
    out = {}
    for p in ps:
        tc, tm = epoch_time(p, **kw)
        out[p] = {"t_compute": tc, "t_comm": tm, "speedup": t1 / (tc + tm),
                  "efficiency": t1 / (tc + tm) / p}
    return out


def allreduce_comm_time(v_bytes, *, p, fabric: Fabric = TPU_V5E_ICI):
    """Ring-allreduce step wire time: 2·(p-1)/p·V behind one log(p)
    latency tree — the per-step cost of the replicated strategies."""
    if p <= 1:
        return 0.0
    return (fabric.alpha * math.ceil(math.log2(p))
            + 2.0 * (p - 1) / p * v_bytes / fabric.bw_bytes)


def hierarchical_comm_time(v_bytes, *, n_intra, n_pods,
                           intra: Fabric = TPU_V5E_ICI,
                           inter: Fabric = TPU_DCN):
    """Two-stage reduce (core.collectives.allreduce_hierarchical):
    reduce-scatter+all-gather intra (2·(n-1)/n·V over ICI) plus
    all-reduce of V/n over DCN."""
    t_intra = 2.0 * (n_intra - 1) / n_intra * v_bytes / intra.bw_bytes
    t_inter = 0.0
    if n_pods > 1:
        t_inter = (2.0 * (n_pods - 1) / n_pods * (v_bytes / n_intra)
                   / inter.bw_bytes + inter.alpha * math.ceil(
                       math.log2(n_pods)))
    return t_intra + t_inter


def flat_multipod_comm_time(v_bytes, *, n_intra, n_pods,
                            inter: Fabric = TPU_DCN):
    """Flat allreduce over pod×data treats the slowest link as the ring
    bottleneck: full V over DCN."""
    n = n_intra * n_pods
    return 2.0 * (n - 1) / n * v_bytes / inter.bw_bytes


# --------------------------------------------------------------------------
# zero1/zero2/zero3 (sharded state) cost/memory model
# --------------------------------------------------------------------------

def zero1_comm_time(v_bytes, *, p, fabric: Fabric = TPU_V5E_ICI):
    """zero1 step wire time: reduce-scatter of grads ((p-1)/p·V) plus
    all-gather of updated params ((p-1)/p·V) — the same 2·(p-1)/p·V a
    ring allreduce moves, so zero1's memory win costs no extra wire."""
    if p <= 1:
        return 0.0
    return (2.0 * (p - 1) / p * v_bytes / fabric.bw_bytes
            + 2.0 * fabric.alpha * math.ceil(math.log2(p)))


def zero1_hier_comm_time(v_bytes, *, n_intra, n_pods, microbatches=1,
                         intra: Fabric = TPU_V5E_ICI,
                         inter: Fabric = TPU_DCN):
    """zero1_hier step wire time: the two-level split keeps zero1's
    total volume but stages it — reduce-scatter + all-gather of V over
    the intra-pod ICI axis (2·(n_intra-1)/n_intra·V), and only the
    1/n_intra shard crosses the DCN pod link
    (2·(n_pods-1)/n_pods·V/n_intra) — vs. a flat zero1 ring over
    pod×data whose slowest link (DCN) carries the full
    2·(p-1)/p·V.  ``microbatches`` is accepted for signature parity
    (zero1-style accumulate-then-one-RS: wire cost is per step)."""
    del microbatches
    if n_intra * n_pods <= 1:
        return 0.0
    t = 0.0
    if n_intra > 1:
        t += (2.0 * (n_intra - 1) / n_intra * v_bytes / intra.bw_bytes
              + 2.0 * intra.alpha * math.ceil(math.log2(n_intra)))
    if n_pods > 1:
        t += (2.0 * (n_pods - 1) / n_pods * (v_bytes / n_intra)
              / inter.bw_bytes
              + 2.0 * inter.alpha * math.ceil(math.log2(n_pods)))
    return t


def zero1_flat_multipod_comm_time(v_bytes, *, n_intra, n_pods,
                                  inter: Fabric = TPU_DCN):
    """The baseline zero1_hier beats: a single-level zero1
    reduce-scatter/all-gather ring spanning pod×data is bottlenecked by
    its slowest link, so the DCN carries the full ring volume."""
    n = n_intra * n_pods
    if n <= 1:
        return 0.0
    return (2.0 * (n - 1) / n * v_bytes / inter.bw_bytes
            + 2.0 * inter.alpha * math.ceil(math.log2(n)))


def zero2_comm_time(v_bytes, *, p, microbatches=1,
                    fabric: Fabric = TPU_V5E_ICI):
    """zero2 step wire time: one reduce-scatter per MICROBATCH (the
    price of never materialising the full gradient accumulator) plus
    the param all-gather — (m+1)·(p-1)/p·V vs zero1's 2·(p-1)/p·V."""
    if p <= 1:
        return 0.0
    return ((microbatches + 1.0) * (p - 1) / p * v_bytes / fabric.bw_bytes
            + (microbatches + 1.0) * fabric.alpha * math.ceil(math.log2(p)))


def zero3_comm_time(v_bytes, *, p, microbatches=1,
                    fabric: Fabric = TPU_V5E_ICI):
    """zero3 step wire time: per microbatch, params are all-gathered
    for the forward, re-gathered (remat) for the backward, and the
    gradient cotangent is reduce-scattered — 3·m·(p-1)/p·V.  No
    post-update all-gather: params stay sharded between steps."""
    if p <= 1:
        return 0.0
    return (3.0 * microbatches * (p - 1) / p * v_bytes / fabric.bw_bytes
            + 3.0 * microbatches * fabric.alpha * math.ceil(math.log2(p)))


def zero3_hier_comm_time(v_bytes, *, n_intra, n_pods, microbatches=1,
                         intra: Fabric = TPU_V5E_ICI,
                         inter: Fabric = TPU_DCN):
    """zero3_hier step wire time: zero3's 3·m gather/scatter passes,
    each staged over the two-level mesh — the intra-pod (ICI) stage
    carries (n_intra-1)/n_intra·V per pass, the pod link (DCN) only the
    1/n_intra piece (2·(n_pods-1)/n_pods·V/n_intra per pass from the
    1/(n_intra·n_pods) shards).  A flat zero3 ring over pod×data would
    put the full 3·m·(p-1)/p·V on the slowest (DCN) link instead."""
    if n_intra * n_pods <= 1:
        return 0.0
    passes = 3.0 * microbatches
    t = 0.0
    if n_intra > 1:
        t += passes * ((n_intra - 1) / n_intra * v_bytes / intra.bw_bytes
                       + intra.alpha * math.ceil(math.log2(n_intra)))
    if n_pods > 1:
        t += passes * ((n_pods - 1) / n_pods * (v_bytes / n_intra)
                       / inter.bw_bytes
                       + inter.alpha * math.ceil(math.log2(n_pods)))
    return t


# --------------------------------------------------------------------------
# checkpointing: step-path overhead and publish lag
# --------------------------------------------------------------------------

#: effective device→host bandwidth of one PCIe Gen3 x16 link — the
#: snapshot (device→host copy) half of a checkpoint save rides this
PCIE_D2H = Fabric("pcie-gen3-x16", 12.0e9, 5e-6)
#: sustained sequential write bandwidth of the checkpoint volume (one
#: local NVMe-class disk / its network-FS equivalent)
CKPT_DISK = Fabric("ckpt-disk", 2.0e9, 100e-6)


def ckpt_overhead(state_bytes, *, step_time_s, every=1,
                  d2h: Fabric = PCIE_D2H, disk: Fabric = CKPT_DISK) -> dict:
    """Sync vs async checkpoint cost for ``state_bytes`` of per-host
    state saved every ``every`` steps.

    A synchronous save blocks the step path for copy + write
    (``sync_s``); the async checkpointer blocks only for the
    device→host copy (``async_s``) and publishes in the background,
    trailing the run by ``publish_lag_s`` = write time (in steps:
    ``publish_lag_steps`` — the ``steps_behind`` a preemption right
    after a save would lose).  ``*_overhead`` are the fractional
    step-time taxes, amortised over ``every``."""
    copy_s = d2h.alpha + state_bytes / d2h.bw_bytes
    write_s = disk.alpha + state_bytes / disk.bw_bytes
    return {
        "sync_s": copy_s + write_s,
        "async_s": copy_s,
        "publish_lag_s": write_s,
        "publish_lag_steps": write_s / step_time_s,
        "sync_overhead": (copy_s + write_s) / (every * step_time_s),
        "async_overhead": copy_s / (every * step_time_s),
    }


# --------------------------------------------------------------------------
# serving (decode) roofline
# --------------------------------------------------------------------------

def kv_bytes_per_token(cfg, dtype_bytes=2) -> float:
    """Per-token KV-cache bytes across the stack: K+V per attention
    layer (MLA: the compressed latent + rope key), O(1) recurrent state
    excluded (it does not grow with context)."""
    per = 0.0
    for (mixer, _ffn) in cfg.layer_pattern():
        if mixer != "attn":
            continue
        if cfg.attention == "mla":
            per += cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        else:
            per += 2.0 * cfg.num_kv_heads * cfg.head_dim
    return dtype_bytes * per


def decode_step_time(param_bytes, kv_bytes_per_seq, *, batch,
                     flops_per_token=0.0, hbm_bw=TPU_V5E_HBM_BW,
                     flops_rate=TPU_V5E_FLOPS, kernel_time_s=0.0):
    """One fused decode step: batched single-token decode streams every
    live parameter byte ONCE (shared across the batch — why batching
    decode is nearly free) plus each slot's KV pages; compute is
    2·N_active FLOPs per token.  Decode is HBM-bound until the batch is
    large, so the step costs max(memory, compute).

    ``kernel_time_s`` is a MEASURED floor on the step (dispatch +
    kernel-launch overhead the roofline cannot see — tiny models are
    overhead-bound, not byte-bound).  Calibrate it from a
    ``BENCH_decode.json`` ar-step row via ``calibrate_kernel_time``."""
    t_mem = (param_bytes + batch * kv_bytes_per_seq) / hbm_bw
    t_comp = batch * flops_per_token / flops_rate
    return max(t_mem, t_comp, kernel_time_s)


def calibrate_kernel_time(bench_rows, *, arch, phase="ar_step",
                          batch=None, per_token=True):
    """Measured kernel-time floor from decode-microbenchmark rows
    (``benchmarks/decode_microbench.py`` → ``BENCH_decode.json``
    ``rows``): the fastest matching ``phase`` row for ``arch`` across
    kernels/flag configs/block sizes.  ``per_token=True`` divides the
    fused ar-step chunk time down to one decode step (rows time a whole
    ``decode_chunk``); pass ``batch`` to also match the lane count."""
    times = [r["time_s"] / (r.get("tokens", 1) if per_token else 1)
             for r in bench_rows
             if r.get("arch") == arch and r.get("phase") == phase
             and (batch is None or r.get("batch") == batch)]
    if not times:
        raise ValueError(f"no {phase!r} rows for arch={arch!r}")
    return min(times)


def spec_expected_tokens(acceptance, k) -> float:
    """Expected tokens EMITTED per verify step of k-token speculative
    decode: the carried token's target always emits, and draft ``i``
    (of the k-1 drafts) emits iff the first ``i`` drafts all matched —
    with per-draft acceptance ``a``, the geometric partial sum
    ``1 + a + a² + ... + a^(k-1)``.  k=4 at a=0.6 gives 2.176×; this is
    exactly the modeled drop in dispatches+syncs per emitted token,
    since the verify step costs the same ONE dispatch a plain decode
    step does."""
    a = min(max(float(acceptance), 0.0), 1.0)
    k = int(k)
    if k <= 1:
        return 1.0
    return float(sum(a ** i for i in range(k)))


def decode_tokens_per_s(param_bytes, kv_bytes_per_seq, *, batch,
                        flops_per_token=0.0, hbm_bw=TPU_V5E_HBM_BW,
                        flops_rate=TPU_V5E_FLOPS,
                        host_sync_s=0.0, tokens_per_sync=1,
                        kernel_time_s=0.0, acceptance=0.0, spec_k=0):
    """Serving-roofline decode throughput for the whole batch.

    ``host_sync_s``/``tokens_per_sync`` model the dispatch discipline:
    the legacy lockstep engine pays one blocking host round-trip per
    token (tokens_per_sync=1); the fused device loop amortises it over
    ``decode_chunk`` steps — the modeled version of the measured
    `serve_throughput` benchmark gap.

    ``spec_k``/``acceptance`` add the speculative-decode term: each
    scan step verifies a k-token MTP draft chunk, so its compute scales
    ×k while the weight-streaming bytes are unchanged (the verify chunk
    re-uses the same streamed parameters — why spec decode wins exactly
    where decode is HBM-bound), and each step emits
    ``spec_expected_tokens(acceptance, k)`` tokens instead of 1.
    ``tokens_per_sync`` keeps meaning SCAN STEPS per sync
    (``decode_chunk``) so the non-speculative call is unchanged."""
    per_step = decode_step_time(
        param_bytes, kv_bytes_per_seq, batch=batch,
        flops_per_token=flops_per_token * (spec_k if spec_k else 1),
        hbm_bw=hbm_bw, flops_rate=flops_rate,
        kernel_time_s=kernel_time_s)
    per_step = per_step + host_sync_s / max(1, tokens_per_sync)
    e = spec_expected_tokens(acceptance, spec_k) if spec_k else 1.0
    return batch * e / per_step


def prefill_time(n_tokens, *, flops_per_token, param_bytes=0.0,
                 flops_rate=TPU_V5E_FLOPS, hbm_bw=TPU_V5E_HBM_BW):
    """One request's prefill: compute-bound at 2·N FLOPs per prompt
    token once the chunk is large enough to re-use the streamed
    weights, weight-streaming-bound below that — so the cost is
    max(compute, one pass over the params)."""
    return max(n_tokens * flops_per_token / flops_rate,
               param_bytes / hbm_bw)


def ttft_model(prompt_tokens, *, flops_per_token, prefix_hit_rate=0.0,
               queue_s=0.0, param_bytes=0.0,
               flops_rate=TPU_V5E_FLOPS, hbm_bw=TPU_V5E_HBM_BW):
    """Time-to-first-token = queueing + prefill over the MISSED prompt
    tokens only.  A radix prefix cache aliases every hit page into the
    slot's table, so prefill work scales with ``(1 - hit_rate)·S`` —
    floored at one token, because the final prompt position is always
    recomputed to seed the first sampled token (the COW-fork path).
    The measured counterpart is ``traffic_replay``'s p50 TTFT split."""
    miss = max(1.0, (1.0 - prefix_hit_rate) * prompt_tokens)
    return queue_s + prefill_time(miss, flops_per_token=flops_per_token,
                                  param_bytes=param_bytes,
                                  flops_rate=flops_rate, hbm_bw=hbm_bw)


def paged_pool_bytes(contexts, page_size, kv_tok_bytes) -> float:
    """Resident KV bytes with paged allocation: each live sequence
    holds ceil(ctx/page)·page tokens of pages — vs the static slab's
    slots·max_len (``n_slots * max_len * kv_tok_bytes``)."""
    return float(sum(
        -(-int(c) // page_size) * page_size * kv_tok_bytes
        for c in contexts))


def moe_expert_bytes(cfg, dtype_bytes=2) -> float:
    """Resident ROUTED-expert weight bytes across the stack (shared
    experts and the router are part of the dense-resident set — every
    replica streams them regardless of dispatch)."""
    m = getattr(cfg, "moe", None)
    if m is None:
        return 0.0
    n_moe = sum(1 for (_mix, ffn) in cfg.layer_pattern() if ffn == "moe")
    per_layer = m.num_experts * cfg._mlp_mats * cfg.d_model * m.d_expert
    return float(dtype_bytes) * n_moe * per_layer


def mesh_decode_bytes_per_device(cfg, contexts, page_size, *,
                                 model_parallel, expert_parallel=True,
                                 dtype_bytes=2) -> float:
    """HBM bytes ONE device streams per fused decode step under a serve
    mesh: dense weights and the paged KV pool are model-sharded (1/mp
    each — pool feature axes over "model", ``sharding.rules.
    pool_spec``), while the routed expert slab divides by mp ONLY under
    expert-parallel dispatch — replicated dispatch leaves every expert
    resident on every device, which at 671B scale dwarfs everything
    else.  Feed ``decode_step_time`` with this instead of the
    single-device ``param_bytes + pool`` to model the mesh engine."""
    total = float(dtype_bytes) * cfg.param_count()
    experts = moe_expert_bytes(cfg, dtype_bytes)
    dense = total - experts
    pool = paged_pool_bytes(contexts, page_size,
                            kv_bytes_per_token(cfg, dtype_bytes))
    mp = max(1, int(model_parallel))
    return (dense / mp + (experts / mp if expert_parallel else experts)
            + pool / mp)


# --------------------------------------------------------------------------
# bucket-level overlap scheduler (core.overlap) cost model
# --------------------------------------------------------------------------

def bucket_comm_time(v_bytes, *, p, fabric: Fabric = TPU_V5E_ICI,
                     strategy="flat"):
    """Wire time for ONE bucket of ``v_bytes`` under `strategy` — a
    thin driver that asks the registered strategy
    (``Strategy.bucket_comm_time``): flat/bucketed move the
    ring-allreduce volume 2·(p-1)/p·V behind one log(p) latency tree;
    zero1/zero2 move the same volume split into reduce-scatter and
    all-gather halves (two latency terms); zero3 moves three halves per
    bucket (forward gather, backward re-gather, grad scatter)."""
    from repro.core.strategy import get_strategy  # local: no cycle
    return get_strategy(strategy).bucket_comm_time(v_bytes, p=p,
                                                   fabric=fabric)


def serial_step_time(t_compute, v_bytes, *, p, n_buckets=1,
                     fabric: Fabric = TPU_V5E_ICI, strategy="flat"):
    """No-overlap schedule: the full backward, then every bucket's
    collective back-to-back (what ``DPConfig(overlap=False)`` and the
    ``overlap="serial"`` baseline execute)."""
    if n_buckets < 1:
        raise ValueError("n_buckets must be >= 1")
    per = bucket_comm_time(v_bytes / n_buckets, p=p, fabric=fabric,
                           strategy=strategy)
    return t_compute + n_buckets * per


def overlapped_step_time(t_compute, v_bytes, *, p, n_buckets=1,
                         fabric: Fabric = TPU_V5E_ICI, strategy="flat"):
    """Double-buffered bucket schedule (core.overlap.run_pipeline):
    compute splits into n_buckets chunks; bucket k's collective runs
    while chunk k+1 computes, so the steady state costs
    max(compute, comm) per bucket, plus the pipeline fill (first chunk's
    compute) and drain (last bucket's collective).  With n_buckets=1
    this degenerates to the serial time exactly; it is never slower
    than serial for the same bucketing (max(a,b) <= a+b)."""
    if n_buckets < 1:
        raise ValueError("n_buckets must be >= 1")
    per_comm = bucket_comm_time(v_bytes / n_buckets, p=p, fabric=fabric,
                                strategy=strategy)
    per_comp = t_compute / n_buckets
    return (per_comp                                    # pipeline fill
            + (n_buckets - 1) * max(per_comp, per_comm)  # steady state
            + per_comm)                                  # drain


def overlap_speedup(t_compute, v_bytes, *, p, n_buckets,
                    fabric: Fabric = TPU_V5E_ICI, strategy="flat"):
    """serial / overlapped step time for the same bucketing (>= 1)."""
    kw = dict(p=p, n_buckets=n_buckets, fabric=fabric, strategy=strategy)
    t_o = overlapped_step_time(t_compute, v_bytes, **kw)
    return serial_step_time(t_compute, v_bytes, **kw) / t_o if t_o else 1.0


def opt_state_bytes_per_device(n_params, state_factor, *, n_workers=1,
                               strategy="replicated"):
    """Per-device optimizer-state bytes (state is always fp32; see
    repro.optim).  Replicated strategies hold the full state on every
    worker; every ZeRO stage (incl. zero1_hier, which shards over the
    global pod×data axes) holds only the 1/n_workers shard (padded to
    equal shards)."""
    if strategy != "replicated" and n_workers > 1:
        from repro.core.strategy import get_strategy  # local: no cycle
        if get_strategy(strategy).sharded:
            padded = n_params + (-n_params) % n_workers
            return 4.0 * state_factor * (padded // n_workers)
    return 4.0 * state_factor * n_params


def dp_memory_report(n_params, state_factor, n_workers, *,
                     param_bytes=4, grad_bytes=4):
    """Per-device training-state memory across the ZeRO ladder — a thin
    driver over the strategy registry: every registered strategy
    contributes its ``memory_entry`` row (replicated strategies share
    the ``replicated`` row via ``memory_key``).

    Per row: params / persistent-gradient / optimizer-state bytes per
    device, and the total's ratio to the fully replicated layout.
    Transient buffers (a microbatch's local gradient, a gathered
    parameter bucket) are not counted: they are bounded by
    bucket/microbatch sizing, not by model size.  Legacy
    ``*_replicated``/``*_zero1``/``opt_state_ratio`` keys are kept for
    older reports."""
    if n_workers < 1:
        raise ValueError("n_workers must be >= 1")
    from repro.core.strategy import memory_rows  # local: no cycle
    rows = {}
    sharded_keys = []
    for key, entry in memory_rows(n_params, state_factor, n_workers,
                                  param_bytes=param_bytes,
                                  grad_bytes=grad_bytes):
        if key != "replicated":
            sharded_keys.append(key)
        rows[f"params_{key}"] = float(entry["params"])
        rows[f"grads_{key}"] = float(entry["grads"])
        rows[f"opt_state_{key}"] = float(entry["opt_state"])
        rows[f"total_{key}"] = float(entry["params"] + entry["grads"]
                                     + entry["opt_state"])
    total_rep = rows["total_replicated"]
    for key in sharded_keys:
        rows[f"ratio_{key}"] = (rows[f"total_{key}"] / total_rep
                                if total_rep else 1.0)
    rows["opt_state_ratio"] = (rows["opt_state_zero1"]
                               / rows["opt_state_replicated"]
                               if rows["opt_state_replicated"] else 1.0)
    return rows
