"""The paper's primary contribution: synchronous data-parallel training
with MPI-style all-to-all reduction, plus its rejected alternatives
(async parameter server), the §3.3.2 performance model, and the
beyond-paper ZeRO ladder (zero1/zero2/zero3) on the TrainState/Layout
contract."""
from repro.core.collectives import (
    allreduce_mean, allreduce_flat, allreduce_bucketed,
    allreduce_hierarchical, reduce_scatter_mean, all_gather_tree,
    flatten_padded, unflatten_padded, local_shard,
    hier_reduce_scatter_mean, hier_all_gather_tree,
)
from repro.core.data_parallel import (
    DPConfig, make_dp_train_step, make_sequential_step, batch_axes,
    dp_world_size, shard_batch_spec,
)
from repro.core.overlap import (
    BucketPlan, async_overlap_report, asyncify_hlo, lowered_hlo_text,
    overlapped_all_gather, overlapped_all_gather_flat, overlapped_allreduce,
    overlapped_reduce_scatter, overlapped_reduce_scatter_flat,
    plan_buckets, plan_local_shard, run_pipeline,
)
from repro.core.train_state import (
    Layout, TrainState, assemble_full_flat, check_layout, host_params,
    init_train_state, register_layout_kind, split_flat_shards, state_layout,
)
from repro.core.strategy import (
    ReplicatedStrategy, ShardedStrategy, Strategy, available_strategies,
    get_strategy, register_strategy,
)
from repro.core.param_server import make_ps_trainer
from repro.core import perf_model

__all__ = [
    "allreduce_mean", "allreduce_flat", "allreduce_bucketed",
    "allreduce_hierarchical", "reduce_scatter_mean", "all_gather_tree",
    "flatten_padded", "unflatten_padded", "local_shard",
    "hier_reduce_scatter_mean", "hier_all_gather_tree",
    "DPConfig", "make_dp_train_step", "make_sequential_step", "batch_axes",
    "dp_world_size", "shard_batch_spec",
    "Layout", "TrainState", "assemble_full_flat", "check_layout",
    "host_params", "init_train_state", "register_layout_kind",
    "split_flat_shards", "state_layout",
    "Strategy", "ReplicatedStrategy", "ShardedStrategy",
    "available_strategies", "get_strategy", "register_strategy",
    "BucketPlan", "plan_buckets", "run_pipeline", "overlapped_allreduce",
    "overlapped_reduce_scatter", "overlapped_reduce_scatter_flat",
    "overlapped_all_gather", "overlapped_all_gather_flat",
    "plan_local_shard",
    "async_overlap_report", "asyncify_hlo", "lowered_hlo_text",
    "make_ps_trainer", "perf_model",
]
