"""The paper's primary contribution: synchronous data-parallel training
with MPI-style all-to-all reduction, plus its rejected alternatives
(async parameter server) and the §3.3.2 performance model."""
from repro.core.collectives import (
    allreduce_mean, allreduce_flat, allreduce_bucketed,
    allreduce_hierarchical,
)
from repro.core.data_parallel import (
    DPConfig, make_dp_train_step, make_sequential_step, batch_axes,
    shard_batch_spec,
)
from repro.core.param_server import make_ps_trainer
from repro.core import perf_model

__all__ = [
    "allreduce_mean", "allreduce_flat", "allreduce_bucketed",
    "allreduce_hierarchical", "DPConfig", "make_dp_train_step",
    "make_sequential_step", "batch_axes", "shard_batch_spec",
    "make_ps_trainer", "perf_model",
]
