"""The paper's contribution: synchronous data-parallel training with an
all-to-all reduction — as a first-class JAX module.

Synchronisation modes:

* ``sync="grads"``   — average GRADIENTS every step (the §3.3.3
  synchronous method; mathematically ≡ sequential SGD on the
  concatenated batch, which tests/test_data_parallel.py asserts).
* ``sync="weights"`` — each worker runs locally and WEIGHTS are averaged
  every ``sync_period`` steps (the §3.3.2 communication model:
  "each device learns the model independently ... total communication
  volume is n²·l per epoch" — i.e. local SGD / periodic model
  averaging).  ``sync_period=1`` recovers per-step averaging.

Gradient strategies (``sync="grads"``) are first-class pluggable
objects resolved through the :mod:`repro.core.strategy` registry —
``flat`` / ``bucketed`` / ``hierarchical`` keep params and optimizer
state replicated, exactly like the paper's per-rank model copies; the
ZeRO ladder (``zero1`` / ``zero2`` / ``zero3``) shards optimizer state,
then gradients, then params 1/p per device; ``zero1_hier`` /
``zero3_hier`` stage their collectives over a pod×data mesh so the
cross-pod DCN link only ever carries 1/n_intra of the volume.  Each strategy owns its layout,
init, grad-sync dataflow, perf-model entries and checkpoint identity —
``make_dp_train_step`` is a thin driver that asks the registered
strategy.  Register your own with
``repro.core.strategy.register_strategy`` (docs/data_parallel.md shows
a worked example), or drive everything through the
:class:`repro.api.Trainer` facade.

All state flows through the :class:`repro.core.train_state.TrainState`
contract: ``step(state, batch) -> (state, metrics)``, with
``init_train_state(optimizer, params, mesh, dp)`` building the state
for any strategy (see docs/data_parallel.md §Migrating for the old
``(params, opt_state)`` signature).

``overlap=True`` schedules the collectives through the bucket-level
double-buffered scheduler in ``repro.core.overlap`` (zero3 pipelines
its per-step parameter gathers the same way); ``overlap="serial"``
runs the same buckets barrier-chained — the no-overlap baseline.

The explicit path uses ``shard_map`` so the collective is visible —
exactly where MPI_Allreduce sat in the paper's design.  The batch is
sharded over the ``data`` (× ``pod``) axes (the paper's rank-0
scatter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core.collectives import (
    axes_spec as _axes_spec, dp_batch_axes as batch_axes, dp_world_size,
)
from repro.core.strategy import (  # noqa: F401  (re-exported: tests import
    _global_norm,                  # _global_norm from here)
    available_strategies, get_strategy,
)
from repro.core.train_state import TrainState, check_layout

# legacy groupings of the built-in registry names (pre-registry API;
# prefer get_strategy(name).sharded)
SHARDED_STRATEGIES = ("zero1", "zero2", "zero3", "zero1_hier",
                      "zero3_hier")
REPLICATED_STRATEGIES = ("flat", "bucketed", "hierarchical")


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Synchronisation policy for data-parallel training.

    sync          — "grads" | "weights" | "none" (divergence baseline).
    strategy      — registry name of the gradient-sync strategy
                    (built-ins: "flat" | "bucketed" | "hierarchical" |
                    "zero1" | "zero2" | "zero3" | "zero1_hier" |
                    "zero3_hier"; see
                    repro.core.strategy.available_strategies()).
    sync_period   — weights mode: steps between weight averages.
    compress      — "none" | "bf16" (wire compression; the sharded
                    strategies reduce/gather in bf16 but keep the fp32
                    master shard).
    bucket_bytes  — bucketed/overlap: target fused-bucket size.
    microbatches  — gradient-accumulation factor; the per-worker batch
                    is split into this many sequential microbatches.
    overlap       — False (one collective per phase, the paper's serial
                    schedule), True (bucket-level double-buffered
                    scheduler from repro.core.overlap; with zero2 +
                    microbatches the reduce-scatter of microbatch k
                    overlaps microbatch k+1's backward; zero3 pipelines
                    its per-step parameter gathers), or "serial" (same
                    buckets, barrier-chained — the no-overlap baseline).
    """
    sync: str = "grads"
    sync_period: int = 1
    strategy: str = "flat"
    compress: str = "none"
    bucket_bytes: int = 64 * 2 ** 20
    microbatches: int = 1
    overlap: Any = False


def make_dp_train_step(loss_fn: Callable, optimizer, mesh,
                       dp: DPConfig = DPConfig(),
                       donate: bool = True):
    """Build a jitted data-parallel train step — a thin driver over the
    registered strategy (``repro.core.strategy.get_strategy``).

    loss_fn(params, batch) -> scalar loss (per-worker mean).
    Returns ``step(state, batch) -> (state, metrics)`` where ``state``
    is a :class:`TrainState` built by ``init_train_state(optimizer,
    params, mesh, dp)`` — the strategy decides what each worker
    physically holds.  The returned step exposes
    ``.lower(state, batch)`` for HLO inspection."""
    if dp.overlap not in (False, True, "serial"):
        raise ValueError(f"overlap must be False, True or 'serial', "
                         f"got {dp.overlap!r}")
    strategy = get_strategy(dp.strategy)
    strategy.validate(dp, mesh)
    inner = strategy.make_inner(loss_fn, optimizer, mesh, dp)
    expected_kind = strategy.state_kind(dp)

    jitted = jax.jit(inner, static_argnums=(4,),
                     donate_argnums=(0, 1) if donate else ())

    def step(state: TrainState, batch):
        check_layout(getattr(state, "layout", None), expected_kind, dp, mesh)
        params, opt_state, new_step, metrics = jitted(
            state.params, state.opt_state, state.step, batch, state.layout)
        return TrainState(params, opt_state, new_step, state.layout), metrics

    step.lower = lambda state, batch: jitted.lower(
        state.params, state.opt_state, state.step, batch, state.layout)
    return step


def shard_batch_spec(mesh):
    """NamedSharding for host batches: shard dim 0 over pod+data."""
    axes = batch_axes(mesh)
    return jax.sharding.NamedSharding(mesh, _axes_spec(axes))


# --------------------------------------------------------------------------
# sequential-equivalence reference (the paper's correctness claim)
# --------------------------------------------------------------------------

def make_sequential_step(loss_fn: Callable, optimizer):
    """Single-device large-batch step — the ground truth that
    sync="grads" DP must match bit-for-bit (up to reduction order).
    Same ``step(state, batch) -> (state, metrics)`` contract, on a
    replicated-layout TrainState (``init_train_state(optimizer,
    params)``)."""
    @jax.jit
    def inner(params, opt_state, step_idx, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, step_idx + 1, {"loss": loss}

    def step(state: TrainState, batch):
        params, opt_state, new_step, metrics = inner(
            state.params, state.opt_state, state.step, batch)
        return TrainState(params, opt_state, new_step, state.layout), metrics

    return step
