"""The paper's contribution: synchronous data-parallel training with an
all-to-all reduction — as a first-class JAX module.

Two synchronisation modes, both present in the paper:

* ``sync="grads"``   — average GRADIENTS every step (the §3.3.3
  synchronous method; mathematically ≡ sequential SGD on the
  concatenated batch, which tests/test_data_parallel.py asserts).
* ``sync="weights"`` — each worker runs locally and WEIGHTS are averaged
  every ``sync_period`` steps (the §3.3.2 communication model:
  "each device learns the model independently ... total communication
  volume is n²·l per epoch" — i.e. local SGD / periodic model
  averaging).  ``sync_period=1`` recovers per-step averaging.

The explicit path uses ``shard_map`` so the collective is visible —
exactly where MPI_Allreduce sat in the paper's design.  Params are
replicated (the paper replicates the model per rank); the batch is
sharded over the ``data`` (× ``pod``) axes (the paper's rank-0
scatter).  The strategy/compression knobs come from
``repro.core.collectives``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax import shard_map

from repro.core.collectives import allreduce_mean


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Synchronisation policy for data-parallel training."""
    sync: str = "grads"              # grads | weights | none (baseline)
    sync_period: int = 1             # weights mode: steps between averages
    strategy: str = "flat"           # flat | bucketed | hierarchical
    compress: str = "none"           # none | bf16
    bucket_bytes: int = 64 * 2 ** 20


def batch_axes(mesh) -> tuple:
    """The mesh axes the batch (and the paper's allreduce) span."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def make_dp_train_step(loss_fn: Callable, optimizer, mesh,
                       dp: DPConfig = DPConfig(),
                       donate: bool = True):
    """Build a jitted data-parallel train step.

    loss_fn(params, batch) -> scalar loss (per-worker mean).
    Returns step(params, opt_state, batch, step_idx) ->
        (params, opt_state, metrics).
    Params/opt_state are replicated; batch is sharded on axis 0.
    """
    axes = batch_axes(mesh)

    def worker(params, opt_state, batch, step_idx):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        gnorm_local = _global_norm(grads)
        if dp.sync == "grads":
            grads = allreduce_mean(grads, axes, strategy=dp.strategy,
                                   compress=dp.compress,
                                   bucket_bytes=dp.bucket_bytes)
            params, opt_state = optimizer.update(grads, opt_state, params)
        elif dp.sync == "weights":
            params, opt_state = optimizer.update(grads, opt_state, params)
            due = (step_idx + 1) % dp.sync_period == 0
            params = jax.lax.cond(
                due,
                lambda p: allreduce_mean(p, axes, strategy=dp.strategy,
                                         compress=dp.compress,
                                         bucket_bytes=dp.bucket_bytes),
                lambda p: p,
                params)
        else:  # "none": fully independent workers (divergence baseline)
            params, opt_state = optimizer.update(grads, opt_state, params)
        loss_avg = jax.lax.pmean(loss, axes)
        metrics = {"loss": loss_avg, "grad_norm_local": gnorm_local}
        return params, opt_state, metrics

    replicated = P()
    bspec = P(axes if len(axes) > 1 else axes[0])
    wrapped = shard_map(
        worker, mesh=mesh,
        in_specs=(replicated, replicated, bspec, replicated),
        out_specs=(replicated, replicated, replicated),
        check_vma=False)
    return jax.jit(wrapped, donate_argnums=(0, 1) if donate else ())


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def shard_batch_spec(mesh):
    """NamedSharding for host batches: shard dim 0 over pod+data."""
    axes = batch_axes(mesh)
    return jax.sharding.NamedSharding(
        mesh, P(axes if len(axes) > 1 else axes[0]))


# --------------------------------------------------------------------------
# sequential-equivalence reference (the paper's correctness claim)
# --------------------------------------------------------------------------

def make_sequential_step(loss_fn: Callable, optimizer):
    """Single-device large-batch step — the ground truth that
    sync="grads" DP must match bit-for-bit (up to reduction order)."""
    def step(params, opt_state, batch, step_idx):
        del step_idx
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}
    return jax.jit(step)
