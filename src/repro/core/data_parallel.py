"""The paper's contribution: synchronous data-parallel training with an
all-to-all reduction — as a first-class JAX module.

Synchronisation modes:

* ``sync="grads"``   — average GRADIENTS every step (the §3.3.3
  synchronous method; mathematically ≡ sequential SGD on the
  concatenated batch, which tests/test_data_parallel.py asserts).
* ``sync="weights"`` — each worker runs locally and WEIGHTS are averaged
  every ``sync_period`` steps (the §3.3.2 communication model:
  "each device learns the model independently ... total communication
  volume is n²·l per epoch" — i.e. local SGD / periodic model
  averaging).  ``sync_period=1`` recovers per-step averaging.

Gradient strategies (``sync="grads"``) from ``repro.core.collectives``:
``flat`` / ``bucketed`` / ``hierarchical`` keep params and optimizer
state replicated, exactly like the paper's per-rank model copies.
``zero1`` goes beyond the paper: the allreduce is split into its
reduce-scatter and all-gather halves, the optimizer updates only the
contiguous 1/p parameter shard each worker owns, and the all-gather
moves updated *params* instead of grads.  Wire volume matches a ring
allreduce; optimizer-state memory drops to 1/p (ZeRO-1).  The
``opt_state`` for that path is created by ``init_zero1_opt_state`` and
STAYS SHARDED across steps — it is not interchangeable with the
replicated ``optimizer.init(params)`` state.

``microbatches > 1`` enables gradient accumulation.  For the replicated
strategies the accumulated gradient is reduced once per step; for
``zero1`` each microbatch's gradient is reduce-scattered as soon as it
exists (per-bucket reduction), so communication overlaps the remaining
microbatches' compute and the full gradient never needs to be resident.

``overlap=True`` swaps the single post-backward collective for the
bucket-level double-buffered scheduler in ``repro.core.overlap`` (and,
for zero1 with microbatches, software-pipelines the scan so microbatch
k's reduce-scatter rides behind microbatch k+1's backward);
``overlap="serial"`` runs the same buckets barrier-chained — the
no-overlap baseline.  See docs/data_parallel.md §"Overlapping
communication with compute".

The explicit path uses ``shard_map`` so the collective is visible —
exactly where MPI_Allreduce sat in the paper's design.  The batch is
sharded over the ``data`` (× ``pod``) axes (the paper's rank-0
scatter).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, shard_map_kwargs
from repro.core.collectives import (
    all_gather_tree, allreduce_mean, flatten_padded, local_shard,
    reduce_scatter_mean,
)
from repro.core.overlap import (
    overlapped_all_gather, overlapped_allreduce, overlapped_reduce_scatter,
    plan_local_shard,
)


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Synchronisation policy for data-parallel training.

    sync          — "grads" | "weights" | "none" (divergence baseline).
    sync_period   — weights mode: steps between weight averages.
    strategy      — "flat" | "bucketed" | "hierarchical" | "zero1".
    compress      — "none" | "bf16" (wire compression; zero1 reduces in
                    bf16 but keeps the fp32 master shard).
    bucket_bytes  — bucketed/overlap: target fused-bucket size.
    microbatches  — gradient-accumulation factor; the per-worker batch
                    is split into this many sequential microbatches.
    overlap       — False (one collective after the full backward, the
                    paper's serial schedule), True (bucket-level
                    double-buffered scheduler from repro.core.overlap:
                    the collective for bucket k is in flight while
                    bucket k±1 is produced/consumed; with zero1 +
                    microbatches the reduce-scatter of microbatch k
                    overlaps microbatch k+1's backward), or "serial"
                    (same buckets, barrier-chained — the no-overlap
                    baseline benchmarks compare against).
    """
    sync: str = "grads"
    sync_period: int = 1
    strategy: str = "flat"
    compress: str = "none"
    bucket_bytes: int = 64 * 2 ** 20
    microbatches: int = 1
    overlap: Any = False


def batch_axes(mesh) -> tuple:
    """The mesh axes the batch (and the paper's allreduce) span."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def dp_world_size(mesh) -> int:
    """Number of data-parallel workers (the paper's p)."""
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def _axes_spec(axes):
    return P(axes if len(axes) > 1 else axes[0])


def _split_micro(batch, n):
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_dp_train_step(loss_fn: Callable, optimizer, mesh,
                       dp: DPConfig = DPConfig(),
                       donate: bool = True):
    """Build a jitted data-parallel train step.

    loss_fn(params, batch) -> scalar loss (per-worker mean).
    Returns step(params, opt_state, batch, step_idx) ->
        (params, opt_state, metrics).
    Params are replicated; batch is sharded on axis 0.  opt_state is
    replicated (``optimizer.init(params)``) for the replicated
    strategies, sharded (``init_zero1_opt_state``) for strategy="zero1".
    """
    if dp.overlap not in (False, True, "serial"):
        raise ValueError(f"overlap must be False, True or 'serial', "
                         f"got {dp.overlap!r}")
    if dp.strategy == "zero1":
        if dp.sync != "grads":
            raise ValueError("strategy='zero1' requires sync='grads'")
        return _make_zero1_train_step(loss_fn, optimizer, mesh, dp, donate)
    axes = batch_axes(mesh)

    def accumulate(params, batch):
        """loss, grads for the worker's batch, scanning microbatches."""
        if dp.microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = _split_micro(batch, dp.microbatches)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, mb):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + l), None

        (grads, loss), _ = jax.lax.scan(
            acc, (zeros, jnp.zeros((), jnp.float32)), micro)
        inv = 1.0 / dp.microbatches
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        return loss * inv, grads

    def worker(params, opt_state, batch, step_idx):
        loss, grads = accumulate(params, batch)
        gnorm_local = _global_norm(grads)
        gnorm = None
        if dp.sync == "grads":
            if dp.overlap:
                grads = overlapped_allreduce(
                    grads, axes, strategy=dp.strategy,
                    bucket_bytes=dp.bucket_bytes, compress=dp.compress,
                    serialize=(dp.overlap == "serial"))
            else:
                grads = allreduce_mean(grads, axes, strategy=dp.strategy,
                                       compress=dp.compress,
                                       bucket_bytes=dp.bucket_bytes)
            gnorm = _global_norm(grads)     # norm of the averaged grad
            params, opt_state = optimizer.update(grads, opt_state, params)
        elif dp.sync == "weights":
            params, opt_state = optimizer.update(grads, opt_state, params)
            due = (step_idx + 1) % dp.sync_period == 0
            params = jax.lax.cond(
                due,
                lambda p: allreduce_mean(p, axes, strategy=dp.strategy,
                                         compress=dp.compress,
                                         bucket_bytes=dp.bucket_bytes),
                lambda p: p,
                params)
        else:  # "none": fully independent workers (divergence baseline)
            params, opt_state = optimizer.update(grads, opt_state, params)
        loss_avg = jax.lax.pmean(loss, axes)
        metrics = {"loss": loss_avg, "grad_norm_local": gnorm_local,
                   "grad_norm": gnorm if gnorm is not None else gnorm_local}
        return params, opt_state, metrics

    replicated = P()
    bspec = _axes_spec(axes)
    wrapped = shard_map(
        worker, mesh=mesh,
        in_specs=(replicated, replicated, bspec, replicated),
        out_specs=(replicated, replicated, replicated),
        **shard_map_kwargs(check_vma=False))
    return jax.jit(wrapped, donate_argnums=(0, 1) if donate else ())


# --------------------------------------------------------------------------
# zero1: sharded-optimizer data parallelism (beyond-paper)
# --------------------------------------------------------------------------

def _shard_len(tree, n):
    """Per-worker shard length of `tree` flattened and padded to a
    multiple of n — must agree with ``flatten_padded``'s layout."""
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(tree))
    return (total + (-total) % n) // n


def _zero1_state_specs(opt_state, shard_spec):
    """Spec tree for a zero1 opt_state: scalars (step counters) are
    replicated, moment vectors are sharded on dim 0."""
    return jax.tree_util.tree_map(
        lambda l: P() if getattr(l, "ndim", 0) == 0 else shard_spec,
        opt_state)


def init_zero1_opt_state(optimizer, params, mesh):
    """Optimizer state over this worker's 1/p slice of the flattened
    param vector — the ZeRO-1 sharded state ``make_dp_train_step(...,
    strategy="zero1")`` consumes and returns.  Layout (treedef order,
    zero padding to a multiple of p) matches ``flatten_padded``."""
    axes = batch_axes(mesh)
    n = dp_world_size(mesh)
    sspec = _axes_spec(axes)

    def initw(params):
        flat, _ = flatten_padded(params, n)
        return optimizer.init({"flat": local_shard(flat, axes)})

    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        raise ValueError("init_zero1_opt_state: empty param tree")
    per = _shard_len(params, n)
    dtype = jnp.result_type(*[l.dtype for l in leaves])
    state_shape = jax.eval_shape(
        optimizer.init, {"flat": jax.ShapeDtypeStruct((per,), dtype)})
    out_specs = _zero1_state_specs(state_shape, sspec)
    wrapped = shard_map(
        initw, mesh=mesh, in_specs=(P(),), out_specs=out_specs,
        **shard_map_kwargs(check_vma=False))
    return jax.jit(wrapped)(params)


def _make_zero1_train_step(loss_fn, optimizer, mesh, dp: DPConfig,
                           donate: bool):
    axes = batch_axes(mesh)
    n = dp_world_size(mesh)
    replicated = P()
    sspec = _axes_spec(axes)

    def worker(params, opt_state, batch, step_idx):
        del step_idx
        plan = None                     # set => bucket-major shard layout
        serialize = dp.overlap == "serial"
        if dp.microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if dp.overlap:
                gshard, _, plan = overlapped_reduce_scatter(
                    grads, axes, bucket_bytes=dp.bucket_bytes,
                    compress=dp.compress, serialize=serialize)
            else:
                gshard, _ = reduce_scatter_mean(grads, axes,
                                                compress=dp.compress)
        elif dp.overlap is True:
            # software-pipelined accumulation: carry the *unreduced*
            # gradient of the previous microbatch through the scan, so
            # its reduce-scatter is dataflow-independent of the current
            # microbatch's backward and rides behind it on the wire.
            micro = _split_micro(batch, dp.microbatches)
            loss, pending = jax.value_and_grad(loss_fn)(
                params, jax.tree_util.tree_map(lambda x: x[0], micro))
            rest = jax.tree_util.tree_map(lambda x: x[1:], micro)
            zeros = jnp.zeros((_shard_len(params, n),), jnp.float32)

            def acc(carry, mb):
                g_pend, g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                sh, _ = reduce_scatter_mean(g_pend, axes,
                                            compress=dp.compress)
                g, sh = jax.lax.optimization_barrier((g, sh))
                return (g, g_acc + sh.astype(jnp.float32), l_acc + l), None

            (pending, gshard, loss), _ = jax.lax.scan(
                acc, (pending, zeros, loss), rest)
            sh, _ = reduce_scatter_mean(pending, axes, compress=dp.compress)
            inv = 1.0 / dp.microbatches
            gshard = (gshard + sh.astype(jnp.float32)) * inv
            loss = loss * inv
        else:
            # reduce-scatter each microbatch's grads as they are
            # produced: the wire sees p buckets per step and overlaps
            # the next microbatch's backward pass; only the 1/p shard
            # accumulates.
            micro = _split_micro(batch, dp.microbatches)
            zeros = jnp.zeros((_shard_len(params, n),), jnp.float32)

            def acc(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                sh, _ = reduce_scatter_mean(g, axes, compress=dp.compress)
                return (g_acc + sh.astype(jnp.float32), l_acc + l), None

            (gshard, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            inv = 1.0 / dp.microbatches
            gshard = gshard * inv
            loss = loss * inv

        # update only the owned param shard; moments never materialise
        # beyond 1/p per device
        flat_p, pspec = flatten_padded(params, n)
        pshard = (plan_local_shard(flat_p, axes, plan) if plan is not None
                  else local_shard(flat_p, axes))
        new_shard, opt_state = optimizer.update(
            {"flat": gshard}, opt_state, {"flat": pshard})
        if plan is not None:
            gathered = overlapped_all_gather(new_shard["flat"], axes,
                                             pspec, plan,
                                             serialize=serialize)
        else:
            gathered = all_gather_tree(new_shard["flat"], axes, pspec)
        if serialize:
            # the no-overlap baseline also orders the metric reductions
            # behind the param all-gather, so nothing hides behind it
            gshard, gathered = jax.lax.optimization_barrier(
                (gshard, gathered))
        params = jax.tree_util.tree_map(
            lambda new, old: new.astype(old.dtype), gathered, params)

        loss_avg = jax.lax.pmean(loss, axes)
        gnorm = jnp.sqrt(jax.lax.psum(
            jnp.sum(jnp.square(gshard.astype(jnp.float32))), axes))
        metrics = {"loss": loss_avg, "grad_norm": gnorm}
        return params, opt_state, metrics

    bspec = _axes_spec(axes)

    def step(params, opt_state, batch, step_idx):
        state_specs = _zero1_state_specs(opt_state, sspec)
        wrapped = shard_map(
            worker, mesh=mesh,
            in_specs=(replicated, state_specs, bspec, replicated),
            out_specs=(replicated, state_specs, replicated),
            **shard_map_kwargs(check_vma=False))
        return wrapped(params, opt_state, batch, step_idx)

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def shard_batch_spec(mesh):
    """NamedSharding for host batches: shard dim 0 over pod+data."""
    axes = batch_axes(mesh)
    return jax.sharding.NamedSharding(mesh, _axes_spec(axes))


# --------------------------------------------------------------------------
# sequential-equivalence reference (the paper's correctness claim)
# --------------------------------------------------------------------------

def make_sequential_step(loss_fn: Callable, optimizer):
    """Single-device large-batch step — the ground truth that
    sync="grads" DP must match bit-for-bit (up to reduction order)."""
    def step(params, opt_state, batch, step_idx):
        del step_idx
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}
    return jax.jit(step)
