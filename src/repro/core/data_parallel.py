"""The paper's contribution: synchronous data-parallel training with an
all-to-all reduction — as a first-class JAX module.

Synchronisation modes:

* ``sync="grads"``   — average GRADIENTS every step (the §3.3.3
  synchronous method; mathematically ≡ sequential SGD on the
  concatenated batch, which tests/test_data_parallel.py asserts).
* ``sync="weights"`` — each worker runs locally and WEIGHTS are averaged
  every ``sync_period`` steps (the §3.3.2 communication model:
  "each device learns the model independently ... total communication
  volume is n²·l per epoch" — i.e. local SGD / periodic model
  averaging).  ``sync_period=1`` recovers per-step averaging.

Gradient strategies (``sync="grads"``) from ``repro.core.collectives``:
``flat`` / ``bucketed`` / ``hierarchical`` keep params and optimizer
state replicated, exactly like the paper's per-rank model copies.  The
ZeRO ladder goes beyond the paper, removing the single-device memory
wall one state class at a time:

* ``zero1`` — the allreduce splits into its reduce-scatter and
  all-gather halves; the optimizer updates only the contiguous 1/p
  parameter shard each worker owns, and the all-gather moves updated
  *params* instead of grads.  Wire volume matches a ring allreduce;
  optimizer-state memory drops to 1/p.  Gradients are accumulated in
  full (the classic ZeRO-1 trade: one reduce-scatter per step).
* ``zero2`` — additionally, the *gradient shard* is the only gradient
  state that persists: each microbatch's gradient is reduce-scattered
  as soon as it exists and only the 1/p shard accumulates across the
  scan, so the full averaged gradient never materialises.  Costs one
  reduce-scatter per microbatch instead of one per step.
* ``zero3`` — the parameters themselves live sharded between steps:
  ``TrainState.params`` is this worker's flat 1/p shard, the forward
  all-gathers parameter buckets on demand through the overlap
  scheduler (and drops them after use — the backward re-gathers via
  rematerialisation), and the backward's cotangent reduce-scatters
  straight onto the shard, so params, grads and optimizer state are
  all 1/p per device.

All state flows through the :class:`repro.core.train_state.TrainState`
contract: ``step(state, batch) -> (state, metrics)``, with
``init_train_state(optimizer, params, mesh, dp)`` building the state
for any strategy (see docs/data_parallel.md §Migrating for the old
``(params, opt_state)`` signature).

``overlap=True`` schedules the collectives through the bucket-level
double-buffered scheduler in ``repro.core.overlap`` (zero3 pipelines
its per-step parameter gathers the same way); ``overlap="serial"``
runs the same buckets barrier-chained — the no-overlap baseline.

The explicit path uses ``shard_map`` so the collective is visible —
exactly where MPI_Allreduce sat in the paper's design.  The batch is
sharded over the ``data`` (× ``pod``) axes (the paper's rank-0
scatter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, shard_map_kwargs
from repro.core.collectives import (
    all_gather_tree, allreduce_mean, axes_spec as _axes_spec,
    dp_batch_axes as batch_axes, dp_world_size, flatten_padded,
    local_shard, reduce_scatter_mean, unflatten_padded,
)
from repro.core.overlap import (
    overlapped_all_gather, overlapped_all_gather_flat, overlapped_allreduce,
    overlapped_reduce_scatter, overlapped_reduce_scatter_flat,
    plan_local_shard,
)
from repro.core.train_state import (
    TrainState, check_layout, opt_state_specs,
)

SHARDED_STRATEGIES = ("zero1", "zero2", "zero3")
REPLICATED_STRATEGIES = ("flat", "bucketed", "hierarchical")


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Synchronisation policy for data-parallel training.

    sync          — "grads" | "weights" | "none" (divergence baseline).
    sync_period   — weights mode: steps between weight averages.
    strategy      — "flat" | "bucketed" | "hierarchical" | "zero1" |
                    "zero2" | "zero3".
    compress      — "none" | "bf16" (wire compression; the sharded
                    strategies reduce/gather in bf16 but keep the fp32
                    master shard).
    bucket_bytes  — bucketed/overlap: target fused-bucket size.
    microbatches  — gradient-accumulation factor; the per-worker batch
                    is split into this many sequential microbatches.
    overlap       — False (one collective per phase, the paper's serial
                    schedule), True (bucket-level double-buffered
                    scheduler from repro.core.overlap; with zero2 +
                    microbatches the reduce-scatter of microbatch k
                    overlaps microbatch k+1's backward; zero3 pipelines
                    its per-step parameter gathers), or "serial" (same
                    buckets, barrier-chained — the no-overlap baseline).
    """
    sync: str = "grads"
    sync_period: int = 1
    strategy: str = "flat"
    compress: str = "none"
    bucket_bytes: int = 64 * 2 ** 20
    microbatches: int = 1
    overlap: Any = False


def _split_micro(batch, n):
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _accumulate(loss_fn, params, batch, n_micro):
    """loss, grads for the worker's batch, scanning microbatches; the
    full (replicated) gradient accumulates in fp32."""
    if n_micro == 1:
        return jax.value_and_grad(loss_fn)(params, batch)
    micro = _split_micro(batch, n_micro)
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def acc(carry, mb):
        g_acc, l_acc = carry
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, l_acc + l), None

    (grads, loss), _ = jax.lax.scan(
        acc, (zeros, jnp.zeros((), jnp.float32)), micro)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    return loss * inv, grads


def make_dp_train_step(loss_fn: Callable, optimizer, mesh,
                       dp: DPConfig = DPConfig(),
                       donate: bool = True):
    """Build a jitted data-parallel train step.

    loss_fn(params, batch) -> scalar loss (per-worker mean).
    Returns ``step(state, batch) -> (state, metrics)`` where ``state``
    is a :class:`TrainState` built by ``init_train_state(optimizer,
    params, mesh, dp)`` — replicated params/opt_state for the
    replicated strategies, sharded flat opt_state (zero1/zero2) or
    sharded flat params + opt_state (zero3) otherwise.  The returned
    step exposes ``.lower(state, batch)`` for HLO inspection."""
    if dp.overlap not in (False, True, "serial"):
        raise ValueError(f"overlap must be False, True or 'serial', "
                         f"got {dp.overlap!r}")
    if dp.strategy in SHARDED_STRATEGIES:
        if dp.sync != "grads":
            raise ValueError(
                f"strategy={dp.strategy!r} requires sync='grads'")
        inner = _make_sharded_inner(loss_fn, optimizer, mesh, dp)
        expected_kind = dp.strategy
    elif dp.strategy in REPLICATED_STRATEGIES:
        inner = _make_replicated_inner(loss_fn, optimizer, mesh, dp)
        expected_kind = "replicated"
    else:
        raise ValueError(dp.strategy)

    jitted = jax.jit(inner, static_argnums=(4,),
                     donate_argnums=(0, 1) if donate else ())

    def step(state: TrainState, batch):
        check_layout(getattr(state, "layout", None), expected_kind, dp, mesh)
        params, opt_state, new_step, metrics = jitted(
            state.params, state.opt_state, state.step, batch, state.layout)
        return TrainState(params, opt_state, new_step, state.layout), metrics

    step.lower = lambda state, batch: jitted.lower(
        state.params, state.opt_state, state.step, batch, state.layout)
    return step


def _make_replicated_inner(loss_fn, optimizer, mesh, dp: DPConfig):
    axes = batch_axes(mesh)

    def worker(params, opt_state, batch, step_idx):
        loss, grads = _accumulate(loss_fn, params, batch, dp.microbatches)
        gnorm_local = _global_norm(grads)
        gnorm = None
        if dp.sync == "grads":
            if dp.overlap:
                grads = overlapped_allreduce(
                    grads, axes, strategy=dp.strategy,
                    bucket_bytes=dp.bucket_bytes, compress=dp.compress,
                    serialize=(dp.overlap == "serial"))
            else:
                grads = allreduce_mean(grads, axes, strategy=dp.strategy,
                                       compress=dp.compress,
                                       bucket_bytes=dp.bucket_bytes)
            gnorm = _global_norm(grads)     # norm of the averaged grad
            params, opt_state = optimizer.update(grads, opt_state, params)
        elif dp.sync == "weights":
            params, opt_state = optimizer.update(grads, opt_state, params)
            due = (step_idx + 1) % dp.sync_period == 0
            params = jax.lax.cond(
                due,
                lambda p: allreduce_mean(p, axes, strategy=dp.strategy,
                                         compress=dp.compress,
                                         bucket_bytes=dp.bucket_bytes),
                lambda p: p,
                params)
        else:  # "none": fully independent workers (divergence baseline)
            params, opt_state = optimizer.update(grads, opt_state, params)
        loss_avg = jax.lax.pmean(loss, axes)
        metrics = {"loss": loss_avg, "grad_norm_local": gnorm_local,
                   "grad_norm": gnorm if gnorm is not None else gnorm_local}
        return params, opt_state, metrics

    replicated = P()
    bspec = _axes_spec(axes)

    def inner(params, opt_state, step_idx, batch, layout):
        del layout
        wrapped = shard_map(
            worker, mesh=mesh,
            in_specs=(replicated, replicated, bspec, replicated),
            out_specs=(replicated, replicated, replicated),
            **shard_map_kwargs(check_vma=False))
        params, opt_state, metrics = wrapped(params, opt_state, batch,
                                             step_idx)
        return params, opt_state, step_idx + 1, metrics

    return inner


# --------------------------------------------------------------------------
# zero1/zero2/zero3: sharded-state data parallelism (beyond-paper)
# --------------------------------------------------------------------------

def _shard_len(tree, n):
    """Per-worker shard length of `tree` flattened and padded to a
    multiple of n — must agree with ``flatten_padded``'s layout."""
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(tree))
    return (total + (-total) % n) // n


def _make_flat_gather(axes, plan, serialize, compress):
    """The zero3 parameter gather as a ``custom_vjp``: forward
    all-gathers the flat shard into the full padded vector (bucket-
    pipelined under ``plan``), backward reduce-scatters the cotangent
    straight back onto the shard — the canonical ZeRO-3 dataflow, with
    the same bucket schedule on both wires.  ``compress="bf16"`` puts
    both directions on a bfloat16 wire while the shard itself stays
    the fp32 master copy."""

    def ag(shard):
        wire = shard.astype(jnp.bfloat16) if compress == "bf16" else shard
        if plan is None:
            flat = jax.lax.all_gather(wire, axes, axis=0, tiled=True)
        else:
            flat = overlapped_all_gather_flat(wire, axes, plan,
                                              serialize=serialize)
        return flat.astype(shard.dtype)

    def rs_sum(ct):
        if plan is None:
            wire = ct.astype(jnp.bfloat16) if compress == "bf16" else ct
            sh = jax.lax.psum_scatter(wire, axes, scatter_dimension=0,
                                      tiled=True)
            return sh.astype(jnp.float32)
        return overlapped_reduce_scatter_flat(
            ct, axes, plan, mean=False, compress=compress,
            serialize=serialize).astype(jnp.float32)

    @jax.custom_vjp
    def gather(shard):
        return ag(shard)

    def fwd(shard):
        return ag(shard), None

    def bwd(_, ct):
        return (rs_sum(ct),)

    gather.defvjp(fwd, bwd)
    return gather


def _make_sharded_inner(loss_fn, optimizer, mesh, dp: DPConfig):
    axes = batch_axes(mesh)
    n = dp_world_size(mesh)
    kind = dp.strategy
    serialize = dp.overlap == "serial"
    replicated = P()
    sspec = _axes_spec(axes)          # flat shards AND the batch

    def zero12_grads(params, batch, plan):
        """loss, mean-gradient shard (layout-matching) for zero1/zero2."""
        if kind == "zero1" or dp.microbatches == 1:
            # classic ZeRO-1 (and the degenerate single-microbatch
            # case): accumulate the full gradient, reduce-scatter ONCE
            loss, grads = _accumulate(loss_fn, params, batch,
                                      dp.microbatches)
            if plan is not None:
                gshard, _, _ = overlapped_reduce_scatter(
                    grads, axes, compress=dp.compress, serialize=serialize,
                    plan=plan)
            else:
                gshard, _ = reduce_scatter_mean(grads, axes,
                                                compress=dp.compress)
            return loss, gshard
        # zero2, microbatches > 1: the grad SHARD is the only gradient
        # state that persists across the scan
        micro = _split_micro(batch, dp.microbatches)
        zeros = jnp.zeros((_shard_len(params, n),), jnp.float32)
        if dp.overlap is True:
            # software-pipelined accumulation: carry the *unreduced*
            # gradient of the previous microbatch through the scan, so
            # its reduce-scatter is dataflow-independent of the current
            # microbatch's backward and rides behind it on the wire.
            loss, pending = jax.value_and_grad(loss_fn)(
                params, jax.tree_util.tree_map(lambda x: x[0], micro))
            rest = jax.tree_util.tree_map(lambda x: x[1:], micro)

            def acc(carry, mb):
                g_pend, g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                sh, _ = reduce_scatter_mean(g_pend, axes,
                                            compress=dp.compress)
                g, sh = jax.lax.optimization_barrier((g, sh))
                return (g, g_acc + sh.astype(jnp.float32), l_acc + l), None

            (pending, gshard, loss), _ = jax.lax.scan(
                acc, (pending, zeros, loss), rest)
            sh, _ = reduce_scatter_mean(pending, axes, compress=dp.compress)
            inv = 1.0 / dp.microbatches
            return loss * inv, (gshard + sh.astype(jnp.float32)) * inv
        # plain eager accumulation: reduce-scatter each microbatch's
        # grads as they are produced; only the 1/p shard accumulates
        def acc(carry, mb):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            sh, _ = reduce_scatter_mean(g, axes, compress=dp.compress)
            return (g_acc + sh.astype(jnp.float32), l_acc + l), None

        (gshard, loss), _ = jax.lax.scan(
            acc, (zeros, jnp.zeros((), jnp.float32)), micro)
        inv = 1.0 / dp.microbatches
        return loss * inv, gshard * inv

    def zero3_grads(pshard, batch, layout, plan):
        """loss, mean-gradient shard for zero3: params are gathered on
        demand (and re-gathered in the backward via remat, so the full
        pytree is dropped after its forward use), the cotangent
        reduce-scatters onto the shard through the gather's vjp."""
        pspec = layout.param_spec
        treedef = pspec[0]
        gather = _make_flat_gather(axes, plan, serialize, dp.compress)

        def reconstruct(shard):
            tree = unflatten_padded(gather(shard), pspec)
            leaves = jax.tree_util.tree_leaves(tree)
            return jax.tree_util.tree_unflatten(
                treedef, [l.astype(dt) for l, dt
                          in zip(leaves, layout.param_dtypes)])

        reconstruct = jax.checkpoint(reconstruct)

        def shard_loss(shard, mb):
            return loss_fn(reconstruct(shard), mb)

        if dp.microbatches == 1:
            loss, g = jax.value_and_grad(shard_loss)(pshard, batch)
            return loss, g.astype(jnp.float32) / n
        micro = _split_micro(batch, dp.microbatches)
        zeros = jnp.zeros(pshard.shape, jnp.float32)

        def acc(carry, mb):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(shard_loss)(pshard, mb)
            return (g_acc + g.astype(jnp.float32), l_acc + l), None

        (g, loss), _ = jax.lax.scan(
            acc, (zeros, jnp.zeros((), jnp.float32)), micro)
        inv = 1.0 / dp.microbatches
        return loss * inv, g * inv / n

    def make_worker(layout):
        plan = layout.plan()

        def worker(pstate, opt_state, batch):
            if kind == "zero3":
                loss, gshard = zero3_grads(pstate, batch, layout, plan)
                pshard = pstate
            else:
                loss, gshard = zero12_grads(pstate, batch, plan)
                # update only the owned param shard; moments never
                # materialise beyond 1/p per device
                flat_p, pspec = flatten_padded(pstate, n)
                pshard = (plan_local_shard(flat_p, axes, plan)
                          if plan is not None else local_shard(flat_p, axes))
            new_shard, new_opt = optimizer.update(
                {"flat": gshard}, opt_state, {"flat": pshard})
            if kind == "zero3":
                params_out = new_shard["flat"].astype(pstate.dtype)
            else:
                if plan is not None:
                    gathered = overlapped_all_gather(
                        new_shard["flat"], axes, pspec, plan,
                        serialize=serialize)
                else:
                    gathered = all_gather_tree(new_shard["flat"], axes,
                                               pspec)
                if serialize:
                    # the no-overlap baseline also orders the metric
                    # reductions behind the param all-gather, so
                    # nothing hides behind it
                    gshard, gathered = jax.lax.optimization_barrier(
                        (gshard, gathered))
                params_out = jax.tree_util.tree_map(
                    lambda new, old: new.astype(old.dtype), gathered,
                    pstate)
            loss_avg = jax.lax.pmean(loss, axes)
            gnorm = jnp.sqrt(jax.lax.psum(
                jnp.sum(jnp.square(gshard.astype(jnp.float32))), axes))
            metrics = {"loss": loss_avg, "grad_norm": gnorm}
            return params_out, new_opt, metrics

        return worker

    def inner(pstate, opt_state, step_idx, batch, layout):
        ospecs = opt_state_specs(opt_state, sspec)
        pspec_inout = sspec if kind == "zero3" else replicated
        wrapped = shard_map(
            make_worker(layout), mesh=mesh,
            in_specs=(pspec_inout, ospecs, sspec),
            out_specs=(pspec_inout, ospecs, replicated),
            **shard_map_kwargs(check_vma=False))
        params, opt_state, metrics = wrapped(pstate, opt_state, batch)
        return params, opt_state, step_idx + 1, metrics

    return inner


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def shard_batch_spec(mesh):
    """NamedSharding for host batches: shard dim 0 over pod+data."""
    axes = batch_axes(mesh)
    return jax.sharding.NamedSharding(mesh, _axes_spec(axes))


# --------------------------------------------------------------------------
# sequential-equivalence reference (the paper's correctness claim)
# --------------------------------------------------------------------------

def make_sequential_step(loss_fn: Callable, optimizer):
    """Single-device large-batch step — the ground truth that
    sync="grads" DP must match bit-for-bit (up to reduction order).
    Same ``step(state, batch) -> (state, metrics)`` contract, on a
    replicated-layout TrainState (``init_train_state(optimizer,
    params)``)."""
    @jax.jit
    def inner(params, opt_state, step_idx, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, step_idx + 1, {"loss": loss}

    def step(state: TrainState, batch):
        params, opt_state, new_step, metrics = inner(
            state.params, state.opt_state, state.step, batch)
        return TrainState(params, opt_state, new_step, state.layout), metrics

    return step
