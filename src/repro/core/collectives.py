"""Allreduce strategies — the paper's MPI collective, TPU-native.

The paper's §3.3.3 argument: synchronous averaging scales because MPI's
all-to-all reduction runs in log(p) time on high-performance
interconnects.  On TPU the equivalents are:

  * ``flat``         — one ``lax.pmean`` per tensor (what MPI_Allreduce
                       per-tensor does; GSPMD emits an ICI all-reduce).
  * ``bucketed``     — flatten the whole gradient pytree into a few
                       large 1-D buckets, one collective per bucket.
                       Amortises per-collective latency (the MPI-world
                       trick Horovod later called "tensor fusion").
  * ``hierarchical`` — two-stage pod-aware reduction: reduce-scatter
                       over the intra-pod ``data`` axis (fast ICI),
                       all-reduce of the 1/|data| shard over the ``pod``
                       axis (slow DCN), all-gather back over ``data``.
                       Moves only 1/|data| of the volume over the
                       cross-pod link — the MPI hierarchical-collective
                       analogue, and the beyond-paper multi-pod default.
  * ``zero1``        — ``reduce_scatter_mean``: stop after the
                       reduce-scatter half of the ring so each worker
                       holds a contiguous 1/p shard of the averaged
                       gradient.  The optimizer then updates only that
                       shard (ZeRO-1 sharded optimizer state) and the
                       all-gather moves updated *params*, not grads —
                       same wire volume as a ring allreduce, 1/p the
                       optimizer memory (see core.data_parallel).

All functions must run inside ``shard_map`` (they use named axes).
``compress="bf16"`` halves wire volume (grads are reduced in bf16 and
restored to fp32) — a beyond-paper lever measured in §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.compat import axis_size


def _axis_size(axis_names):
    return int(np.prod([axis_size(a) for a in axis_names]))


# --------------------------------------------------------------------------
# the data-parallel axis convention — defined ONCE, used by the step
# layer (data_parallel), the state layer (train_state) and the launchers
# --------------------------------------------------------------------------

def dp_batch_axes(mesh) -> tuple:
    """The mesh axes the batch (and the paper's allreduce) span."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_world_size(mesh) -> int:
    """Number of data-parallel workers (the paper's p)."""
    return int(np.prod([mesh.shape[a] for a in dp_batch_axes(mesh)]))


def axes_spec(axes) -> P:
    """PartitionSpec sharding dim 0 over the given mesh axes."""
    return P(axes if len(axes) > 1 else axes[0])


def _maybe_compress(tree, compress):
    if compress == "bf16":
        return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), tree)
    return tree


def _restore(tree, ref_tree, compress):
    if compress == "bf16":
        return jax.tree_util.tree_map(
            lambda g, r: g.astype(r.dtype), tree, ref_tree)
    return tree


# --------------------------------------------------------------------------
# strategies
# --------------------------------------------------------------------------

def allreduce_flat(tree, axis_names):
    return jax.tree_util.tree_map(
        lambda g: jax.lax.pmean(g, axis_names), tree)


def _flatten_concat(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    return flat, (treedef, shapes, sizes)


def _unflatten(flat, spec):
    treedef, shapes, sizes = spec
    leaves = []
    off = 0
    for shp, sz in zip(shapes, sizes):
        leaves.append(flat[off:off + sz].reshape(shp))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, leaves)


def allreduce_bucketed(tree, axis_names, *, bucket_bytes=64 * 2 ** 20):
    """Fuse the pytree into ~bucket_bytes 1-D buckets, pmean each."""
    if not jax.tree_util.tree_leaves(tree):
        return tree                       # nothing to reduce
    flat, spec = _flatten_concat(tree)
    per = max(1, bucket_bytes // flat.dtype.itemsize)
    n_buckets = max(1, -(-flat.size // per))
    pad = n_buckets * per - flat.size
    flat = jnp.pad(flat, (0, pad))
    buckets = flat.reshape(n_buckets, per)
    buckets = jax.lax.pmean(buckets, axis_names)
    return _unflatten(buckets.reshape(-1)[:flat.size - pad]
                      if pad else buckets.reshape(-1), spec)


def allreduce_hierarchical(tree, *, intra_axis="data", inter_axis="pod"):
    """reduce-scatter(intra) -> all-reduce(inter) -> all-gather(intra).

    Wire cost per device: 2·(n-1)/n·V over ICI + V/n over the pod link,
    vs. V over the pod link for the flat strategy — an n× reduction of
    cross-pod traffic (n = |intra_axis|).
    """
    n = axis_size(intra_axis)

    def one(g):
        flat = g.reshape(-1)
        pad = (-flat.size) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        shard = jax.lax.psum_scatter(flat, intra_axis, scatter_dimension=0,
                                     tiled=True)
        shard = jax.lax.pmean(shard, inter_axis)
        full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
        # psum_scatter summed over intra; divide once to get the mean
        return full[:g.size].reshape(g.shape) / n

    return jax.tree_util.tree_map(one, tree)


# --------------------------------------------------------------------------
# zero1: reduce-scatter / all-gather halves, exposed separately so the
# optimizer update can run on the 1/p shard between them
# --------------------------------------------------------------------------

def flatten_padded(tree, n):
    """Flatten-concat `tree` into one 1-D vector padded to a multiple of
    ``n``.  Returns (flat, spec); `spec` round-trips via
    ``unflatten_padded``.  The same (treedef-ordered, zero-padded) layout
    is used for gradients, the param vector, and optimizer moments, so a
    worker's shard of each lines up elementwise."""
    flat, (treedef, shapes, sizes) = _flatten_concat(tree)
    size = flat.size
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, (treedef, shapes, sizes, size)


def unflatten_padded(flat, spec):
    treedef, shapes, sizes, size = spec
    return _unflatten(flat[:size], (treedef, shapes, sizes))


def reduce_scatter_mean(tree, axis_names, *, compress="none"):
    """ZeRO-1 first half: reduce-scatter the flattened pytree so each
    worker ends with the contiguous 1/p shard of the *averaged* value
    that ``jax.lax.axis_index(axis_names)`` owns.  Returns (shard, spec);
    reconstruct with ``all_gather_tree``.  Must run inside shard_map.

    ``compress="bf16"`` halves the wire volume: the flattened gradient
    is cast to bfloat16 before the reduce-scatter, and the returned
    shard is restored to float32 — the fp32 *master shard* the sharded
    optimizer keeps, so only the wire (not the state) is lossy."""
    if not jax.tree_util.tree_leaves(tree):
        raise ValueError("reduce_scatter_mean: empty pytree")
    n = _axis_size(axis_names)
    flat, spec = flatten_padded(tree, n)
    out_dtype = flat.dtype
    if compress == "bf16":
        flat, out_dtype = flat.astype(jnp.bfloat16), jnp.float32
    shard = jax.lax.psum_scatter(flat, axis_names, scatter_dimension=0,
                                 tiled=True)
    return shard.astype(out_dtype) / n, spec


def all_gather_tree(shard, axis_names, spec):
    """ZeRO-1 second half: gather the per-worker shards back into the
    full (unpadded) pytree.  Inverse of ``reduce_scatter_mean`` /
    ``flatten_padded`` + shard slicing."""
    flat = jax.lax.all_gather(shard, axis_names, axis=0, tiled=True)
    return unflatten_padded(flat, spec)


# --------------------------------------------------------------------------
# zero1_hier: two-level reduce-scatter / all-gather halves.  The slow
# cross-pod link only ever carries the 1/n_intra shard; the shard
# ownership convention is the standard contiguous one PROVIDED the
# worker's linear index is taken intra-major, i.e. axis order
# (intra, inter) — see repro.core.strategy.Zero1HierStrategy.dp_axes.
# --------------------------------------------------------------------------

def hier_reduce_scatter_mean(tree, intra_axis, inter_axis, *,
                             compress="none"):
    """Two-level ZeRO-1 first half: reduce-scatter the flattened pytree
    over the fast ``intra_axis`` (ICI), then reduce-scatter that
    1/n_intra shard over ``inter_axis`` (DCN), so each worker ends with
    the contiguous 1/(n_intra·n_pods) shard of the globally *averaged*
    value.  Worker (k, i) on a (inter=k, intra=i) mesh ends owning
    contiguous global slice ``i·n_pods + k`` — the ``local_shard``
    convention under intra-major linearisation, so optimizer shards,
    checkpoints and ``all_gather_tree`` layouts all line up.

    The cross-pod link moves only 1/n_intra of the volume (the DCN
    saving ``perf_model.zero1_hier_comm_time`` models).  ``compress``
    as in :func:`reduce_scatter_mean` (bf16 wire, fp32 master shard)."""
    if not jax.tree_util.tree_leaves(tree):
        raise ValueError("hier_reduce_scatter_mean: empty pytree")
    n = axis_size(intra_axis) * axis_size(inter_axis)
    flat, spec = flatten_padded(tree, n)
    out_dtype = flat.dtype
    if compress == "bf16":
        flat, out_dtype = flat.astype(jnp.bfloat16), jnp.float32
    shard = jax.lax.psum_scatter(flat, intra_axis, scatter_dimension=0,
                                 tiled=True)
    shard = jax.lax.psum_scatter(shard, inter_axis, scatter_dimension=0,
                                 tiled=True)
    return shard.astype(out_dtype) / n, spec


def hier_all_gather_tree(shard, intra_axis, inter_axis, spec):
    """Two-level ZeRO-1 second half: gather the 1/(n_intra·n_pods)
    shards back into the full pytree — the small cross-pod gather
    first (DCN carries 1/n_intra of the volume), then the intra-pod
    gather over ICI.  Inverse of :func:`hier_reduce_scatter_mean`."""
    piece = jax.lax.all_gather(shard, inter_axis, axis=0, tiled=True)
    flat = jax.lax.all_gather(piece, intra_axis, axis=0, tiled=True)
    return unflatten_padded(flat, spec)


def local_shard(flat, axis_names):
    """This worker's contiguous slice of a replicated padded vector —
    the same slice ``psum_scatter(..., tiled=True)`` would hand it."""
    n = _axis_size(axis_names)
    idx = jax.lax.axis_index(axis_names)
    per = flat.size // n
    return jax.lax.dynamic_slice_in_dim(flat, idx * per, per)


def allreduce_mean(tree, axis_names, *, strategy="flat", compress="none",
                   bucket_bytes=64 * 2 ** 20):
    """Average `tree` over the devices spanned by `axis_names`."""
    if not jax.tree_util.tree_leaves(tree):
        return tree
    ref = tree
    tree = _maybe_compress(tree, compress)
    if strategy == "flat":
        out = allreduce_flat(tree, axis_names)
    elif strategy == "bucketed":
        out = allreduce_bucketed(tree, axis_names, bucket_bytes=bucket_bytes)
    elif strategy == "hierarchical":
        if len(axis_names) == 1:
            out = allreduce_flat(tree, axis_names)   # single pod: degenerate
        else:
            inter, intra = axis_names[0], axis_names[1]
            out = allreduce_hierarchical(tree, intra_axis=intra,
                                         inter_axis=inter)
            # hierarchical path averaged over intra only; finish over inter
            # (pmean over inter already applied inside) -> nothing to do
    elif strategy == "zero1":
        # full round trip (grads end replicated) — the sharded-optimizer
        # path in core.data_parallel splits the two halves instead
        shard, spec = reduce_scatter_mean(tree, axis_names)
        out = all_gather_tree(shard, axis_names, spec)
    else:
        raise ValueError(strategy)
    return _restore(out, ref, compress)
