"""Losses.  Labels use -1 for masked positions (padding, image tokens)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -1


def make_labels(cfg, batch):
    """Next-token labels aligned with the model's logit sequence."""
    tokens = batch.get("tgt_tokens", batch.get("tokens"))
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], IGNORE)], axis=1)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        n_img = batch["vision_embeds"].shape[1]
        pad = jnp.full(tokens.shape[:1] + (n_img,), IGNORE, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return labels


def cross_entropy(logits, labels):
    """Mean CE over positions where labels != IGNORE.  logits fp32."""
    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - picked) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)


def lm_loss(cfg, out, batch, *, mtp_weight=0.1):
    """Total training loss: CE + MoE aux + optional MTP CE."""
    labels = make_labels(cfg, batch)
    loss = cross_entropy(out["logits"], labels)
    metrics = {"ce": loss, "aux": out["aux"]}
    total = loss + out["aux"]
    if "mtp_logits" in out:
        # MTP head at position i predicts token i+2
        tokens = batch.get("tgt_tokens", batch.get("tokens"))
        mtp_labels = jnp.concatenate(
            [tokens[:, 2:], jnp.full_like(tokens[:, :2], IGNORE)],
            axis=1)[:, :out["mtp_logits"].shape[1]]
        mtp_ce = cross_entropy(out["mtp_logits"], mtp_labels)
        total = total + mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return total, metrics
