from repro.train.loss import lm_loss, make_labels
from repro.train.step import (TrainConfig, make_train_step,
                              init_train_state, replicated_layout)

__all__ = ["lm_loss", "make_labels", "TrainConfig", "make_train_step",
           "init_train_state", "replicated_layout"]
