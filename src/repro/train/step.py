"""pjit train step for the large architectures.

This is the GSPMD realisation of the paper's technique at modern scale:
the batch is sharded over the data-parallel axes, the loss is a mean
over the global batch, and differentiating through that mean makes XLA
insert exactly the gradient all-reduce the paper placed by hand with
MPI (reduce-scatter + all-gather when weights are FSDP-sharded — the
hierarchical variant).  Features:

  * microbatch gradient accumulation (lax.scan) — activation memory
    control for the 33B-671B configs;
  * per-super-block rematerialisation (jax.checkpoint inside the model);
  * fp32 master weights with bf16 compute, or pure-bf16 (671B);
  * MoE aux-loss and MTP integrated via train.loss.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim as optim_lib
from repro.core.train_state import Layout, TrainState
from repro.models import apply_model, init_model
from repro.sharding import (ShardingConfig, param_specs, param_shardings,
                            batch_spec, dp_axes)
from repro.train.loss import lm_loss


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    microbatches: int = 1
    remat: bool = True
    grad_dtype: str = "float32"      # accumulation dtype
    param_dtype: str = "float32"     # master-weight dtype
    mtp_weight: float = 0.1
    grad_clip: float = 0.0           # global-norm clip; 0 = off
    # lr schedule: "constant" | "cosine" (peak=lr, warmup/total in steps)
    schedule: str = "constant"
    warmup_steps: int = 100
    total_steps: int = 10_000


def _global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), norm


def _split_micro(batch, n):
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_loss_fn(cfg, tc: TrainConfig):
    def loss_fn(params, batch):
        out = apply_model(cfg, params, batch, mode="train", remat=tc.remat)
        total, metrics = lm_loss(cfg, out, batch, mtp_weight=tc.mtp_weight)
        return total, metrics
    return loss_fn


def make_train_step(cfg, mesh, tc: TrainConfig, *, params_shape=None):
    """Returns (step_fn, optimizer) — ``step(state, batch) -> (state,
    metrics)`` on the :class:`TrainState` contract.  Params/opt_state
    sharding is GSPMD's business (the arrays carry NamedShardings), so
    the layout kind stays "replicated" — ``layout`` describes the
    explicit-DP shard ownership, not the compiler's partitioning."""
    lr = (optim_lib.cosine_schedule(tc.lr, tc.warmup_steps, tc.total_steps)
          if tc.schedule == "cosine" else tc.lr)
    optimizer = optim_lib.get_optimizer(tc.optimizer, lr)
    loss_fn = make_loss_fn(cfg, tc)
    gdt = jnp.dtype(tc.grad_dtype)

    def inner(params, opt_state, batch):
        if tc.microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = _split_micro(batch, tc.microbatches)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, gdt), params)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(gdt), g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            inv = 1.0 / tc.microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            loss = loss * inv
            metrics = {}
        if tc.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
            metrics["grad_norm"] = gnorm
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics}

    def step(state: TrainState, batch):
        params, opt_state, metrics = inner(state.params, state.opt_state,
                                           batch)
        return TrainState(params, opt_state, state.step + 1,
                          state.layout), metrics

    return step, optimizer


def replicated_layout(params_shape) -> Layout:
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(params_shape))
    return Layout("replicated", (), 1, total, total)


def init_train_state(cfg, mesh, tc: TrainConfig, key):
    """Materialise sharded params + opt state on the mesh.  Returns
    ``(TrainState, param_shardings)``."""
    optimizer = optim_lib.get_optimizer(tc.optimizer, tc.lr)
    pshape = jax.eval_shape(functools.partial(init_model, cfg), key)
    shardings = param_shardings(cfg, mesh, pshape,
                                ShardingConfig.for_mode("train"))
    pdt = jnp.dtype(tc.param_dtype)

    def _init(key):
        p = init_model(cfg, key)
        return jax.tree_util.tree_map(lambda x: x.astype(pdt), p)

    params = jax.jit(_init, out_shardings=shardings)(key)
    opt_state = jax.jit(optimizer.init,
                        out_shardings=opt_state_shardings(
                            optimizer, params, shardings, mesh))(params)
    state = TrainState(params, opt_state, jnp.zeros((), jnp.int32),
                       replicated_layout(pshape))
    return state, shardings


def opt_state_shardings(optimizer, params, param_shardings_tree, mesh):
    """Optimizer moments (m/v/g2) mirror the param tree -> reuse its
    shardings (ZeRO-style: state scales with the FSDP axis for free)."""
    shape = jax.eval_shape(optimizer.init, params)
    out = {}
    for k in shape:
        out[k] = (NamedSharding(mesh, P()) if k == "step"
                  else param_shardings_tree)
    return out
