from repro.sharding.rules import (
    ShardingConfig, dp_axes, param_specs, param_shardings,
    batch_spec, batch_shardings, cache_spec, cache_shardings,
    pool_spec, pool_specs, pool_shardings,
)
from repro.sharding.ctx import ServeTopology, serve_topology, get_serve_topology

__all__ = [
    "ShardingConfig", "dp_axes", "param_specs", "param_shardings",
    "batch_spec", "batch_shardings", "cache_spec", "cache_shardings",
    "pool_spec", "pool_specs", "pool_shardings",
    "ServeTopology", "serve_topology", "get_serve_topology",
]
