from repro.sharding.rules import (
    ShardingConfig, dp_axes, param_specs, param_shardings,
    batch_spec, batch_shardings, cache_spec, cache_shardings,
)

__all__ = [
    "ShardingConfig", "dp_axes", "param_specs", "param_shardings",
    "batch_spec", "batch_shardings", "cache_spec", "cache_shardings",
]
