"""Partition-spec rules: param trees, batches, caches -> PartitionSpec.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  The paper's technique fixes the OUTER story: batch and
gradient averaging span ``pod``×``data``.  Within a replica, weights are
tensor-sharded over ``model`` (heads / ffn / experts — the substrate
modern scale forces in, DESIGN.md §2.1), and optionally FSDP-sharded
over ``data`` (train mode) so optimizer state scales like ZeRO.

Rules are name-based over the param tree path, with divisibility checks:
a dim is only sharded if it divides evenly (GSPMD could pad, but even
sharding keeps the roofline numbers honest).
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    fsdp_dense: bool = True      # shard dense weights' input dim over "data"
    fsdp_experts: str = "auto"   # "auto": experts over (data,model) if divisible
    cache_seq_axis: str = "model"   # decode KV-cache seq dim sharding
    shard_batch: bool = True

    @staticmethod
    def for_mode(mode: str) -> "ShardingConfig":
        if mode == "train":
            return ShardingConfig(fsdp_dense=True)
        # serving: keep weights resident (no per-layer FSDP all-gathers)
        return ShardingConfig(fsdp_dense=False)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _size(mesh, axis) -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def _div(dim, n) -> bool:
    return n > 1 and dim % n == 0


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec(cfg, mesh, path: str, leaf, sh: ShardingConfig) -> P:
    """PartitionSpec for one parameter, by tree path."""
    shape = leaf.shape
    model = _size(mesh, "model")
    data = _size(mesh, "data") if sh.fsdp_dense else 1
    stacked = "/blocks/" in path or path.startswith("blocks/")
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    nd = len(body)

    def spec(*axes):
        return P(*(lead + tuple(axes)))

    name = path.rsplit("/", 1)[-1]

    # ---- MoE experts: (E, d, f) ----
    if "/experts/" in path or "/ffn/experts" in path.replace("experts/", "experts@"):
        pass
    if re.search(r"/experts/w_(up|gate|down)$", path):
        E = body[0]
        dsz = _size(mesh, "data")
        if sh.fsdp_experts == "auto" and _div(E, dsz * model):
            # full expert-parallel: E over data x model
            return spec(("data", "model"), None, None)
        if _div(E, model):
            # expert-TP: E over model, the FFN dim over data
            is_down = path.endswith("w_down")      # (E, f, d) vs (E, d, f)
            f_dim = body[1] if is_down else body[2]
            f_ax = "data" if _div(f_dim, dsz) else None
            return (spec("model", f_ax, None) if is_down
                    else spec("model", None, f_ax))
        return spec(None, None, None)
    if name == "router":
        return spec(None, None)

    # ---- embeddings / unembed: (V, d) ----
    # vocab-sharded ONLY: sharding d as well makes GSPMD replicate the
    # batch through the token gather (involuntary full remat) — measured
    # 3-4x activation-memory blowup.  Vocab over "model" keeps logits
    # vocab-sharded (the memory-critical tensor) and the input gather
    # lowers to a masked local gather + psum.
    if name == "table":
        return spec("model" if _div(body[0], model) else None, None)

    # ---- attention ----
    # heads shard over "model" when the count divides; otherwise (56 or
    # 40 heads on a 16-way axis, MQA kv=1) fall back to sharding head_dim
    # — always 128-divisible — so attention weights never replicate on
    # the model axis.  (Head-padding to the next multiple of 16 is the
    # beyond-paper optimization evaluated in §Perf.)
    if name == "wq" and nd == 3:              # (d, h, hd)
        d_ax = "data" if _div(body[0], data) else None
        if _div(body[1], model):
            return spec(d_ax, "model", None)
        return spec(d_ax, None, "model" if _div(body[2], model) else None)
    if name in ("wk", "wv") and nd == 3:      # (d, hk, hd)
        d_ax = "data" if _div(body[0], data) else None
        if _div(body[1], model):
            return spec(d_ax, "model", None)
        if os.environ.get("REPRO_BASELINE"):  # pre-§Perf behaviour
            return spec(d_ax, None,
                        "model" if _div(body[2], model) else None)
        # kv heads < model axis: REPLICATE heads (K/V computed redundantly
        # per model-rank — standard GQA-under-TP; hd-sharding instead costs
        # a full-activation all-reduce per layer, measured 1.9GB/layer)
        return spec(d_ax, None, None)
    if name == "wo" and nd == 3:              # (h, hd, d)
        d_ax = "data" if _div(body[2], data) else None
        if _div(body[0], model):
            return spec("model", None, d_ax)
        return spec(None, "model" if _div(body[1], model) else None, d_ax)
    if name in ("bq", "bk", "bv"):            # (h, hd)
        if _div(body[0], model):
            return spec("model", None)
        return spec(None, "model" if _div(body[1], model) else None)
    if name in ("w_uq", "w_uk", "w_uv"):      # (r, H, dim)  MLA up-projs
        return spec(None, "model" if _div(body[1], model) else None, None)
    if name in ("w_dq", "w_dkv"):             # (d, r)  MLA down-projs
        return spec("data" if _div(body[0], data) else None, None)

    # ---- MLP ----
    if name in ("w_up", "w_gate"):            # (d, ff)
        return spec("data" if _div(body[0], data) else None,
                    "model" if _div(body[1], model) else None)
    if name == "w_down":                      # (ff, d)
        return spec("model" if _div(body[0], model) else None,
                    "data" if _div(body[1], data) else None)

    # ---- Mamba ----
    if name in ("in_x", "in_z"):              # (d, dI)
        return spec("data" if _div(body[0], data) else None,
                    "model" if _div(body[1], model) else None)
    if name == "conv_w":                      # (dc, dI)
        return spec(None, "model" if _div(body[1], model) else None)
    if name in ("conv_b", "D", "dt_bias"):    # (dI,)
        return spec("model" if _div(body[0], model) else None)
    if name == "x_proj":                      # (dI, dt_rank+2ds)
        return spec("model" if _div(body[0], model) else None, None)
    if name == "dt_proj":                     # (dt_rank, dI)
        return spec(None, "model" if _div(body[1], model) else None)
    if name == "A_log":                       # (dI, dS)
        return spec("model" if _div(body[0], model) else None, None)
    if name == "out_proj":                    # (dI, d)
        return spec("model" if _div(body[0], model) else None,
                    "data" if _div(body[1], data) else None)

    # ---- RWKV6 ----
    if name in ("wr", "wk", "wv", "wg") and nd == 2:   # (d, d=H*K)
        return spec("data" if _div(body[0], data) else None,
                    "model" if _div(body[1], model) else None)
    if name == "u":                           # (H, K)
        return spec("model" if _div(body[0], model) else None, None)
    if name in ("cm_wk",):                    # (d, ff)
        return spec("data" if _div(body[0], data) else None,
                    "model" if _div(body[1], model) else None)
    if name in ("cm_wv",):                    # (ff, d)
        return spec("model" if _div(body[0], model) else None,
                    "data" if _div(body[1], data) else None)
    if name in ("cm_wr",):
        return spec(None, None)
    if name in ("w_base", "mu_base", "cm_mu_r", "cm_mu_k"):
        return spec(None) if nd == 1 else spec(*([None] * nd))
    if name in ("decay_B", "mix_B"):          # (..., r, d)
        return spec(*([None] * nd))
    if name in ("decay_A", "mix_A"):
        return spec(*([None] * nd))

    # ---- projections / misc 2-D ----
    if name in ("w1", "w2", "proj"):
        return spec(*([None] * nd))

    # default: replicate (norms, biases, scalars)
    return spec(*([None] * nd))


def param_specs(cfg, mesh, params_shape, sh: Optional[ShardingConfig] = None):
    sh = sh or ShardingConfig()
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(cfg, mesh, _path_str(path), leaf, sh),
        params_shape)


def param_shardings(cfg, mesh, params_shape, sh=None):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(cfg, mesh, params_shape, sh),
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------

def batch_spec(mesh, batch_size: int) -> P:
    axes = dp_axes(mesh)
    n = 1
    for a in axes:
        n *= _size(mesh, a)
    if batch_size % n == 0 and batch_size >= n:
        return P(axes if len(axes) > 1 else axes[0])
    return P(None)


def batch_shardings(mesh, batch_shapes: dict, batch_size: int):
    bs = batch_spec(mesh, batch_size)

    def one(leaf):
        return NamedSharding(mesh, P(*(bs + (None,) * (len(leaf.shape) - 1)))
                             if bs != P(None)
                             else P(*([None] * len(leaf.shape))))
    return jax.tree_util.tree_map(one, batch_shapes)


def cache_spec(cfg, mesh, path: str, leaf, batch_size: int,
               sh: ShardingConfig) -> P:
    """Cache layout: (nrep?, B, S, ...) kv / (nrep?, B, ...) states."""
    shape = leaf.shape
    stacked = "/blocks/" in path or path.startswith("blocks/")
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    axes = dp_axes(mesh)
    n_dp = 1
    for a in axes:
        n_dp *= _size(mesh, a)
    b_axis = (axes if len(axes) > 1 else axes[0]) \
        if (sh.shard_batch and body[0] % n_dp == 0 and body[0] >= n_dp) else None
    seq_ax = sh.cache_seq_axis
    model = _size(mesh, seq_ax)
    name = path.rsplit("/", 1)[-1]

    if name in ("k", "v"):              # (B, S, hk, hd)
        s_ax = seq_ax if _div(body[1], model) else None
        return P(*(lead + (b_axis, s_ax, None, None)))
    if name in ("ckv", "krope"):        # (B, S, r)
        s_ax = seq_ax if _div(body[1], model) else None
        return P(*(lead + (b_axis, s_ax, None)))
    if name == "ssm":                   # (B, dI, dS)
        return P(*(lead + (b_axis,
                           "model" if _div(body[1], model) else None, None)))
    if name == "conv":                  # (B, dc-1, dI)
        return P(*(lead + (b_axis, None,
                           "model" if _div(body[2], model) else None)))
    if name == "state":                 # rwkv (B, H, K, V)
        return P(*(lead + (b_axis,
                           "model" if _div(body[1], model) else None,
                           None, None)))
    if name in ("shift_tm", "shift_cm"):  # (B, d)
        return P(*(lead + (b_axis, None)))
    return P(*([None] * len(shape)))


def cache_shardings(cfg, mesh, cache_shape, batch_size, sh=None):
    sh = sh or ShardingConfig.for_mode("serve")
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(cfg, mesh, _path_str(path), leaf, batch_size, sh)),
        cache_shape)


# --------------------------------------------------------------------------
# paged serving pool specs
# --------------------------------------------------------------------------

def pool_spec(cfg, mesh, path: str, leaf, slot_axis: int) -> P:
    """PartitionSpec for one paged-serving cache leaf.

    Pooled leaves are token-major with no batch axis — the token axis
    is the page table's address space, so it must stay whole per
    replica; the *feature* axes shard over "model" instead:

    * attention k/v   ``(N, hk, hd)`` — heads over "model" when they
      divide, else head_dim (always 128-divisible), mirroring the
      wq/wk/wv weight rules so write/read stay aligned with the
      projections that produce them;
    * MLA ``ckv (N, r)`` / ``krope (N, rope)`` — latent/rope feature
      axis over "model" when divisible.

    Per-slot leaves (``slot_axis >= 0``: recurrent SSM state, O(1) in
    context) and page tables are replicated per data-replica — there is
    nothing worth sharding and the fused loop indexes them by slot.
    """
    if slot_axis >= 0:
        return P(*([None] * len(leaf.shape)))
    shape = leaf.shape
    stacked = "/blocks/" in path or path.startswith("blocks/")
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    model = _size(mesh, "model")
    name = path.rsplit("/", 1)[-1]

    def spec(*axes):
        return P(*(lead + tuple(axes)))

    if name in ("k", "v"):              # (N, hk, hd)
        if _div(body[1], model):
            return spec(None, "model", None)
        if _div(body[2], model):
            return spec(None, None, "model")
        return spec(None, None, None)
    if name in ("ckv", "krope"):        # (N, r)
        return spec(None, "model" if _div(body[1], model) else None)
    return spec(*([None] * len(body)))


def pool_specs(cfg, mesh, cache_shape, slot_axis_tree):
    """PartitionSpec tree for a paged cache (``serve.kvcache`` layout);
    ``slot_axis_tree`` marks per-slot leaves (>= 0) vs pooled (-1)."""
    paths = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _path_str(path), cache_shape)
    return jax.tree_util.tree_map(
        lambda path, leaf, ax: pool_spec(cfg, mesh, path, leaf, ax),
        paths, cache_shape, slot_axis_tree)


def pool_shardings(cfg, mesh, cache_shape, slot_axis_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        pool_specs(cfg, mesh, cache_shape, slot_axis_tree),
        is_leaf=lambda x: isinstance(x, P))
