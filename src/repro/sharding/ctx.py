"""Activation-sharding constraint context.

GSPMD left alone sometimes picks activation shardings that replicate
the batch (measured: 3-4x activation blowup on train_4k).  Production
JAX frameworks pin the residual stream with with_sharding_constraint;
we do the same, but only when a mesh has been registered (tests and
single-device runs stay constraint-free).

``set_activation_mesh(mesh)`` is called by the launcher/dry-run before
tracing; model code calls ``constrain_bsd(x)`` / ``constrain_logits``.
``activation_mesh(mesh)`` is the scoped form — launchers that may be
called in-process (tests, notebooks) must use it so a production mesh
never leaks into the caller's subsequent traces.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_activation_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_activation_mesh():
    return _MESH


@contextlib.contextmanager
def activation_mesh(mesh):
    """Scope the activation-constraint mesh: set for the duration
    (``None`` explicitly clears it), always restore the previous value
    on exit — even when the body raises."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield mesh
    finally:
        _MESH = prev


def _dp_axes():
    return tuple(a for a in ("pod", "data") if a in _MESH.axis_names)


def _dp_size():
    n = 1
    for a in _dp_axes():
        n *= _MESH.shape[a]
    return n


def constrain(x, *axes):
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*axes)))


def constrain_bsd(x):
    """Residual stream (B, S, d): batch over pod×data when divisible,
    otherwise (long_500k B=1) shard the sequence over data."""
    if _MESH is None:
        return x
    ax = _dp_axes()
    spec_b = ax if len(ax) > 1 else ax[0]
    if x.shape[0] % _dp_size() == 0:
        return constrain(x, spec_b, None, None)
    if x.ndim >= 2 and x.shape[1] % _MESH.shape.get("data", 1) == 0 \
            and x.shape[1] > 1:
        return constrain(x, None, "data", None)
    return constrain(x, *([None] * x.ndim))


def constrain_ecd(x):
    """MoE dispatch buffers (E, C, ...): experts over (data×model) when
    divisible (expert-parallel), else model, else replicated."""
    if _MESH is None:
        return x
    E = x.shape[0]
    dsz = _MESH.shape.get("data", 1)
    msz = _MESH.shape.get("model", 1)
    if dsz > 1 and msz > 1 and E % (dsz * msz) == 0:
        ax = ("data", "model")
    elif msz > 1 and E % msz == 0:
        ax = "model"
    else:
        ax = None
    return constrain(x, ax, *([None] * (x.ndim - 1)))


def constrain_tokens(x):
    """Token-major tensors (N, ...): N over pod×data when divisible."""
    if _MESH is None:
        return x
    ax = _dp_axes()
    if x.shape[0] % _dp_size() == 0:
        return constrain(x, ax if len(ax) > 1 else ax[0],
                         *([None] * (x.ndim - 1)))
    return x


def constrain_logits(x):
    """(B, S, V): batch over dp, vocab over model."""
    if _MESH is None:
        return x
    ax = _dp_axes()
    spec_b = (ax if len(ax) > 1 else ax[0]) \
        if x.shape[0] % _dp_size() == 0 else None
    v_ok = x.shape[-1] % _MESH.shape.get("model", 1) == 0
    return constrain(x, spec_b, None, "model" if v_ok else None)
