"""Activation-sharding constraint context.

GSPMD left alone sometimes picks activation shardings that replicate
the batch (measured: 3-4x activation blowup on train_4k).  Production
JAX frameworks pin the residual stream with with_sharding_constraint;
we do the same, but only when a mesh has been registered (tests and
single-device runs stay constraint-free).

``set_activation_mesh(mesh)`` is called by the launcher/dry-run before
tracing; model code calls ``constrain_bsd(x)`` / ``constrain_logits``.
``activation_mesh(mesh)`` is the scoped form — launchers that may be
called in-process (tests, notebooks) must use it so a production mesh
never leaks into the caller's subsequent traces.

Serving additionally registers a :class:`ServeTopology` — the MaxText
``dcn_data_parallelism × ici_fsdp_parallelism`` split applied to
decode: data-parallel replica groups over the DCN-ish axes
(``"pod"``/``"data"``, each replica running its own scheduler batch)
and model-sharded decode over the ICI ``"model"`` axis (paged KV pools
split on the head/latent axis, so pool bytes/device drop ~1/mp).  The
serve topology rides the same scoping discipline as the activation
mesh (``serve_topology(...)`` sets both) and gates the paged-pool read
constraints (``constrain_paged_kv`` / ``constrain_paged_latent``).
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None
_TOPO = None


@dataclasses.dataclass(frozen=True)
class ServeTopology:
    """How a serving engine maps onto a device mesh.

    replica_axes — data-parallel replica groups (DCN): each replica
                   holds a full copy of the paged pool and serves its
                   own slots.
    model_axis   — tensor/expert-sharded decode (ICI): pool leaves,
                   attention heads and expert rows split here.
    """
    mesh: object
    replica_axes: tuple
    model_axis: object          # axis name, or None (host mesh)

    @classmethod
    def from_mesh(cls, mesh) -> "ServeTopology":
        reps = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        model = "model" if "model" in mesh.axis_names else None
        return cls(mesh=mesh, replica_axes=reps, model_axis=model)

    @property
    def replicas(self) -> int:          # dcn_data_parallelism
        n = 1
        for a in self.replica_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def model_parallel(self) -> int:    # ici model sharding of decode
        return self.mesh.shape[self.model_axis] if self.model_axis else 1


def get_serve_topology():
    return _TOPO


@contextlib.contextmanager
def serve_topology(topo):
    """Scope a serve topology AND its mesh as the activation mesh (the
    paged decode path is traced under both).  ``None`` clears both;
    previous values are restored on exit even when the body raises."""
    global _MESH, _TOPO
    prev_mesh, prev_topo = _MESH, _TOPO
    _MESH = topo.mesh if topo is not None else None
    _TOPO = topo
    try:
        yield topo
    finally:
        _MESH, _TOPO = prev_mesh, prev_topo


def set_activation_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_activation_mesh():
    return _MESH


@contextlib.contextmanager
def activation_mesh(mesh):
    """Scope the activation-constraint mesh: set for the duration
    (``None`` explicitly clears it), always restore the previous value
    on exit — even when the body raises."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield mesh
    finally:
        _MESH = prev


def _dp_axes():
    return tuple(a for a in ("pod", "data") if a in _MESH.axis_names)


def _dp_size():
    n = 1
    for a in _dp_axes():
        n *= _MESH.shape[a]
    return n


def constrain(x, *axes):
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*axes)))


def constrain_bsd(x):
    """Residual stream (B, S, d): batch over pod×data when divisible,
    otherwise (long_500k B=1) shard the sequence over data."""
    if _MESH is None:
        return x
    ax = _dp_axes()
    spec_b = ax if len(ax) > 1 else ax[0]
    if x.shape[0] % _dp_size() == 0:
        return constrain(x, spec_b, None, None)
    if x.ndim >= 2 and x.shape[1] % _MESH.shape.get("data", 1) == 0 \
            and x.shape[1] > 1:
        return constrain(x, None, "data", None)
    return constrain(x, *([None] * x.ndim))


def constrain_ecd(x):
    """MoE dispatch buffers (E, C, ...): experts over (data×model) when
    divisible (expert-parallel), else model, else replicated."""
    if _MESH is None:
        return x
    E = x.shape[0]
    dsz = _MESH.shape.get("data", 1)
    msz = _MESH.shape.get("model", 1)
    if dsz > 1 and msz > 1 and E % (dsz * msz) == 0:
        ax = ("data", "model")
    elif msz > 1 and E % msz == 0:
        ax = "model"
    else:
        ax = None
    return constrain(x, ax, *([None] * (x.ndim - 1)))


def constrain_tokens(x):
    """Token-major tensors (N, ...): N over pod×data when divisible."""
    if _MESH is None:
        return x
    ax = _dp_axes()
    if x.shape[0] % _dp_size() == 0:
        return constrain(x, ax if len(ax) > 1 else ax[0],
                         *([None] * (x.ndim - 1)))
    return x


def constrain_logits(x):
    """(B, S, V): batch over dp, vocab over model."""
    if _MESH is None:
        return x
    ax = _dp_axes()
    spec_b = (ax if len(ax) > 1 else ax[0]) \
        if x.shape[0] % _dp_size() == 0 else None
    v_ok = x.shape[-1] % _MESH.shape.get("model", 1) == 0
    return constrain(x, spec_b, None, "model" if v_ok else None)


# --------------------------------------------------------------------------
# paged serving pool (spec-aware decode reads — gated on the topology)
# --------------------------------------------------------------------------

def _serve_model_size() -> int:
    if _TOPO is None or _TOPO.model_axis is None:
        return 1
    return _TOPO.model_parallel


def constrain_paged_kv(x):
    """Gathered paged K/V view (B, L, hk, hd): pin the pool's model
    sharding through the page-table gather — heads over "model" when
    they divide, head_dim otherwise (mirrors ``rules.pool_spec``), so
    GSPMD never round-trips the gathered view through replication."""
    mp = _serve_model_size()
    if mp <= 1:
        return x
    if x.shape[2] % mp == 0:
        return constrain(x, None, None, "model", None)
    if x.shape[3] % mp == 0:
        return constrain(x, None, None, None, "model")
    return x


def constrain_paged_latent(x):
    """Gathered paged MLA latent view (B, L, r): latent axis over
    "model" when it divides (the pool-leaf layout)."""
    mp = _serve_model_size()
    if mp <= 1 or x.shape[-1] % mp:
        return x
    return constrain(x, None, None, "model")


def replicate_for_kernel(x):
    """Pin a Pallas interpret-mode kernel operand (or its result) fully
    replicated under a serve topology.  The interpreter lowers the grid
    to a loop carrying the VMEM scratch as scan state; the CPU SPMD
    partitioner reshards that carry between steps ("involuntary full
    rematerialization") and produces wrong numbers — the same bug class
    ``replicate_update`` works around.  Pinning the kernel's operands
    and output replicated keeps the fused loop out of the partitioner's
    hands; the pool STORAGE stays model-sharded (the pin inserts an
    all-gather at the consumption point, the analogue of the gathered
    view the XLA reference path materialises).  Host mesh: no-op."""
    if _serve_model_size() <= 1:
        return x
    return constrain(x, *([None] * x.ndim))


def replicate_update(x):
    """Pin a paged-pool scatter UPDATE fully replicated.  The update is
    tiny (B x new-tokens), but letting GSPMD partition it along a
    feature axis that rope's split/concat just touched miscombines the
    halves when the scatter sits inside the layer ``lax.scan`` (the
    written K comes out exactly replica-count times too large on the
    CPU SPMD partitioner; a model-layout constraint on the update does
    NOT survive the scan).  Replicating the update makes the scatter
    partition trivially per pool shard.  Host mesh: no-op."""
    if _serve_model_size() <= 1:
        return x
    return constrain(x, *([None] * x.ndim))
