"""Step-addressable checkpointing.

This is the restart half of the paper's fault-tolerance story (§2.2 /
§3.1): ULFM lets the MPI job survive a rank failure because the model
state is replicated under data parallelism; recovery = reload the last
consistent state and continue.  Here: the (possibly sharded) train
state is gathered to host, written as a flat npz keyed by pytree path,
with atomic rename so a crash mid-write never corrupts the latest step.

Restore reshards onto whatever mesh the new run uses (the paper's
"continued execution with a different p" is free in JAX — shardings are
re-applied at load).
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import tempfile

import jax
import numpy as np


def _flatten(tree):
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(ckpt_dir, step: int, state) -> str:
    """state: any pytree (params, opt_state, rng, ...)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    treedef = jax.tree_util.tree_structure(state)
    final = ckpt_dir / f"step_{step:010d}.npz"
    tmp = str(final) + ".tmp.npz"     # .npz suffix: savez won't rename it
    try:
        np.savez(tmp, __treedef__=np.frombuffer(
            str(treedef).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, final)        # atomic publish
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    (ckpt_dir / "latest").write_text(str(step))
    return str(final)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    marker = ckpt_dir / "latest"
    if marker.exists():
        return int(marker.read_text().strip())
    steps = [int(m.group(1)) for f in ckpt_dir.glob("step_*.npz")
             if (m := re.match(r"step_(\d+)\.npz", f.name))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, state_like, step: int | None = None,
                       shardings=None):
    """Restore into the structure of `state_like` (shapes validated).
    `shardings`: optional matching pytree of NamedShardings to place
    leaves directly onto a (new) mesh."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step:010d}.npz")

    flat_like = _flatten(state_like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    leaves_with_path = jax.tree_util.tree_leaves_with_path(state_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_path))
    new_leaves = []
    for (path, leaf), sh in zip(leaves_with_path, shard_leaves):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        if sh is not None:
            arr = jax.device_put(arr, sh)
        new_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(state_like)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
