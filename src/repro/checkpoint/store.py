"""Step-addressable checkpointing.

This is the restart half of the paper's fault-tolerance story (§2.2 /
§3.1): ULFM lets the MPI job survive a rank failure because the model
state is replicated under data parallelism; recovery = reload the last
consistent state and continue.

Two stores live here:

* ``save_checkpoint`` / ``restore_checkpoint`` — the legacy replicated
  path: the state is gathered to host and written as one flat npz
  keyed by pytree path.
* ``save_sharded_checkpoint`` / ``restore_sharded_checkpoint`` — the
  TrainState path: every worker's shard of every sharded leaf is
  written as-is, keyed by ``(worker, layout)``, with NO all-gather on
  either side.  Same-layout restore streams each worker file straight
  onto its devices (``jax.make_array_from_callback`` pulls exactly the
  shard each device needs); cross-layout restore (replicated ↔ zero1 ↔
  zero2 ↔ zero3, contiguous ↔ bucket-major, different p) reshards on
  host through a canonical flat representation — still no device
  collective.

All writers are atomic: everything lands under a ``tmp-`` prefix first
and is published with one ``os.replace``, and ``latest_step`` refuses
to match anything but a fully-published name — a killed worker can
never leave a truncated checkpoint that a restart then picks up.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib
import re
import shutil

import jax
import numpy as np

_STEP_FILE_RE = re.compile(r"step_(\d+)\.npz")
_STEP_DIR_RE = re.compile(r"step_(\d+)\.shards")


class CorruptCheckpointError(ValueError):
    """A published checkpoint's file content is unreadable (torn write,
    bit rot, truncation).  Carries the offending file's name so the
    operator knows WHICH shard to investigate; the elastic resize
    driver catches this and falls back to the previous published step."""


def _load_npz(path) -> dict:
    """Eagerly load every member of an npz into plain host arrays,
    converting any read failure (bad zip directory, truncated member,
    zlib error) into a :class:`CorruptCheckpointError` that names the
    bad file — a torn shard must fail the restore loudly, not surface
    later as a half-filled device buffer."""
    path = pathlib.Path(path)
    try:
        with np.load(path) as npz:
            return {k: npz[k] for k in npz.files}
    except CorruptCheckpointError:
        raise
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CorruptCheckpointError(
            f"checkpoint shard file {path.name!r} in {path.parent} is "
            f"corrupt or truncated ({type(e).__name__}: {e}); the step "
            "was published but its data is unreadable — restore an "
            "earlier published step") from e


def _write_latest(ckpt_dir: pathlib.Path, step: int):
    """The marker itself must publish atomically too — a kill between
    open and write would otherwise leave an empty/partial pointer that
    breaks every restart even though the step data is intact."""
    tmp = ckpt_dir / "tmp-latest"
    tmp.write_text(str(step))
    os.replace(tmp, ckpt_dir / "latest")


def _flatten(tree):
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir, step: int, state) -> str:
    """state: any pytree (params, opt_state, rng, ...).  Replicated
    path: leaves are materialised on host in full."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
    treedef = jax.tree_util.tree_structure(state)
    final = ckpt_dir / f"step_{step:010d}.npz"
    # tmp- prefix: neither the glob nor the regex in latest_step can
    # ever pick a half-written file up (and savez keeps the .npz name)
    tmp = str(ckpt_dir / f"tmp-step_{step:010d}.npz")
    try:
        np.savez(tmp, __treedef__=np.frombuffer(
            str(treedef).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, final)        # atomic publish
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    _sweep_stale_tmp(ckpt_dir)
    _write_latest(ckpt_dir, step)
    return str(final)


def latest_step(ckpt_dir) -> int | None:
    """Newest fully-published step.  Only exact ``step_N.npz`` files or
    ``step_N.shards`` directories count — ``tmp-`` leftovers from a
    killed writer are invisible, and a corrupt marker falls through to
    the directory scan instead of killing the restart."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    marker = ckpt_dir / "latest"
    if marker.exists():
        try:
            return int(marker.read_text().strip())
        except ValueError:
            pass                          # torn marker: trust the scan
    steps = published_steps(ckpt_dir)
    return max(steps) if steps else None


def published_steps(ckpt_dir) -> list:
    """Every fully-published step in ``ckpt_dir``, ascending.  Only
    exact ``step_N.npz`` files / ``step_N.shards`` directories count;
    ``tmp-`` staging leftovers are invisible.  The elastic resize
    driver walks this list newest-first when a restore fails."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = set()
    if ckpt_dir.exists():
        for f in ckpt_dir.iterdir():
            m = _STEP_FILE_RE.fullmatch(f.name)
            if m and f.is_file():
                steps.add(int(m.group(1)))
            m = _STEP_DIR_RE.fullmatch(f.name)
            if m and f.is_dir():
                steps.add(int(m.group(1)))
    return sorted(steps)


def checkpoint_meta(ckpt_dir, step: int | None = None) -> dict:
    """The ``meta.json`` of a published sharded step (latest by
    default) — layout, leaf manifest, and any ``extra`` record the
    writer attached (e.g. the launcher's data cursor)."""
    d, _ = _checkpoint_dir(ckpt_dir, step)
    return json.loads((d / "meta.json").read_text())


def _sweep_stale_tmp(ckpt_dir: pathlib.Path):
    """Remove ``tmp-`` staging leftovers from writers that died between
    shard writes.  Runs after every successful publish: anything still
    under a ``tmp-`` prefix at that point belongs to a dead writer (the
    live writer's staging dir was just renamed away).  ``tmp-latest``
    is the marker's own staging file — only ever alive inside
    ``_write_latest``, which runs after this sweep."""
    for f in ckpt_dir.iterdir():
        if not f.name.startswith("tmp-") or f.name == "tmp-latest":
            continue
        try:
            if f.is_dir():
                shutil.rmtree(f)
            else:
                f.unlink()
        except OSError:
            pass                      # already gone / racing sweep: fine


def _prune_published(ckpt_dir: pathlib.Path, keep_last: int):
    """Retention: drop the oldest published steps beyond the newest
    ``keep_last``, so long runs with frequent checkpoints don't fill
    the disk.  Never touches the newest step."""
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    for step in published_steps(ckpt_dir)[:-keep_last]:
        for victim in (ckpt_dir / f"step_{step:010d}.npz",
                       ckpt_dir / f"step_{step:010d}.shards"):
            try:
                if victim.is_dir():
                    shutil.rmtree(victim)
                elif victim.exists():
                    victim.unlink()
            except OSError:
                pass


def restore_checkpoint(ckpt_dir, state_like, step: int | None = None,
                       shardings=None):
    """Restore into the structure of `state_like` (shapes validated).
    `shardings`: optional matching pytree of NamedShardings to place
    leaves directly onto a (new) mesh."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step:010d}.npz")

    flat_like = _flatten(state_like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    leaves_with_path = jax.tree_util.tree_leaves_with_path(state_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_path))
    new_leaves = []
    for (path, leaf), sh in zip(leaves_with_path, shard_leaves):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        if sh is not None:
            arr = jax.device_put(arr, sh)
        new_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(state_like)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def restore_train_state(ckpt_dir, template, step: int | None = None):
    """Restore a TrainState picking the store by what is ON DISK: a
    ``step_N.shards`` directory goes through the sharded store (which
    also reshards across layout changes), a legacy ``step_N.npz`` is
    loaded leaf-for-leaf into replicated leaves.  The single dispatch
    point behind ``Trainer.restore`` and the launchers.  Returns
    ``(TrainState, step)``."""
    import jax.numpy as jnp

    from repro.core.train_state import TrainState  # local: avoid cycle
    ckpt_dir = pathlib.Path(ckpt_dir)
    at = step if step is not None else latest_step(ckpt_dir)
    if at is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    if (ckpt_dir / f"step_{at:010d}.shards").is_dir():
        return restore_sharded_checkpoint(ckpt_dir, template, step)
    layout = template.layout
    if layout.sharded or layout.params_flat:
        raise ValueError(
            f"checkpoint step {at} in {ckpt_dir} is a legacy npz, which "
            f"cannot restore into the sharded {layout.kind!r} layout; "
            "restore into a replicated-layout state first and re-save "
            "through save_sharded_checkpoint")
    (params, opt_state), at = restore_checkpoint(
        ckpt_dir, (template.params, template.opt_state), step)
    return TrainState(params, opt_state, jnp.asarray(at, jnp.int32),
                      layout), at


def restore_serve_params(ckpt_dir, params_template, step: int | None = None):
    """Read-only serve restore: ONLY the parameters, reassembled in
    full on host — no optimizer state, no mesh, no TrainState template
    and no device collective.  This is the checkpoint half of the
    train-and-serve loop: whatever layout training wrote (replicated /
    zero1 / zero2 / zero3 / any registered custom strategy, sharded
    store or legacy npz), serving gets the plain parameter pytree of
    ``params_template`` (shapes/dtypes from ``jax.eval_shape`` of
    ``init_model``).  The template is the FULL model tree — auxiliary
    heads ride along with the trunk, e.g. ``params["mtp"]`` on
    ``mtp_depth > 0`` archs, which is what the serve scheduler's
    ``spec_decode`` drafts from.  Returns ``(params, step)``."""
    from repro.core.train_state import Layout  # local: avoid cycle
    ckpt_dir = pathlib.Path(ckpt_dir)
    at = step if step is not None else latest_step(ckpt_dir)
    if at is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{at:010d}.shards"
    if not d.is_dir():
        # legacy npz: params were saved under a pytree prefix — either
        # "params/..." (TrainState-shaped dicts) or "0/..." (the GSPMD
        # launcher's (params, opt_state) tuple)
        data = np.load(ckpt_dir / f"step_{at:010d}.npz")
        leaves_with_path = jax.tree_util.tree_leaves_with_path(
            params_template)
        new_leaves = []
        for path, leaf in leaves_with_path:
            key = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            arr = None
            for cand in (key, f"params/{key}", f"0/{key}"):
                if cand in data.files:
                    arr = data[cand]
                    break
            if arr is None:
                raise ValueError(
                    f"checkpoint step {at} has no params leaf {key!r}")
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: checkpoint shape {arr.shape} "
                                 f"!= template {leaf.shape}")
            new_leaves.append(arr.astype(leaf.dtype))
        treedef = jax.tree_util.tree_structure(params_template)
        return jax.tree_util.tree_unflatten(treedef, new_leaves), at
    meta = json.loads((d / "meta.json").read_text())
    saved_strategy = meta["layout"].get("strategy")
    if saved_strategy is not None:
        # resolve BEFORE touching the layout (registers custom kinds;
        # unknown strategies fail with the registered-names list)
        from repro.core.strategy import available_strategies, get_strategy
        try:
            get_strategy(saved_strategy)
        except ValueError as e:
            raise ValueError(
                f"checkpoint {d} was written by strategy "
                f"{saved_strategy!r}, which is not registered here; "
                f"registered strategies: {list(available_strategies())}"
            ) from e
    src = Layout.from_json(meta["layout"])

    @functools.lru_cache(maxsize=None)
    def worker_npz(w):
        return _load_npz(d / f"worker_{w:05d}.npz")

    @functools.lru_cache(maxsize=None)
    def replicated_npz():
        return _load_npz(d / "replicated.npz")

    canonical = _src_canonical_params(meta, src, worker_npz, replicated_npz)
    n_template = sum(
        int(np.prod(np.shape(l)))
        for l in jax.tree_util.tree_leaves(params_template))
    if n_template != canonical.size:
        raise ValueError(
            f"checkpoint has {canonical.size} params, serve template has "
            f"{n_template} — wrong architecture/config for this "
            "checkpoint?")
    return _unflatten_params_like(canonical, params_template), at


# --------------------------------------------------------------------------
# sharded TrainState checkpoints: per-shard files, no gather either way
# --------------------------------------------------------------------------

def _state_tree(state):
    return {"params": state.params, "opt_state": state.opt_state,
            "step": state.step}


def _is_sharded_leaf(leaf) -> bool:
    sharding = getattr(leaf, "sharding", None)
    return sharding is not None and not sharding.is_fully_replicated


@dataclasses.dataclass
class StateSnapshot:
    """A TrainState frozen into plain host buffers — the per-worker
    shard format ``save_sharded_checkpoint`` writes, detached from the
    devices.  Producing one (:func:`snapshot_train_state`) is the ONLY
    part of a save that must block the step path (one device→host copy
    per shard, no gather); :func:`write_state_snapshot` turns it into
    a published step from any thread."""
    step: int
    meta: dict                       # layout + leaf manifest (+ extra)
    replicated: dict                 # key -> np.ndarray
    per_worker: dict                 # worker -> {key: np.ndarray}

    @property
    def nbytes(self) -> int:
        total = sum(a.nbytes for a in self.replicated.values())
        for payload in self.per_worker.values():
            total += sum(a.nbytes for a in payload.values())
        return total


def snapshot_train_state(state, step: int, *, extra: dict | None = None
                         ) -> StateSnapshot:
    """Device→host half of a sharded save: copy each worker's shards
    (``addressable_shards`` — no all-gather) and the replicated leaves
    into host arrays, plus the meta.json record.  This is the blocking
    portion of an async save; everything after it is pure file I/O.
    ``extra`` is recorded verbatim under ``meta["extra"]`` (the
    launcher stores its data cursor there)."""
    from repro.core.train_state import (  # local: avoid cycle
        TrainState, shard_worker_index)
    if not isinstance(state, TrainState):
        raise TypeError("snapshot_train_state takes a TrainState; "
                        "use save_checkpoint for loose pytrees")
    layout = state.layout

    tree = _state_tree(state)
    flat = _flatten(tree)
    meta_leaves = {}
    replicated = {}
    per_worker = {w: {} for w in range(layout.num_shards)}
    for key, leaf in flat.items():
        sharded = _is_sharded_leaf(leaf)
        meta_leaves[key] = {"shape": list(np.shape(leaf)),
                            "dtype": str(np.asarray(leaf).dtype
                                         if not hasattr(leaf, "dtype")
                                         else leaf.dtype),
                            "sharded": sharded}
        if not sharded:
            replicated[key] = np.asarray(leaf)
            continue
        per = leaf.shape[0] // layout.num_shards
        seen = set()
        for shard in leaf.addressable_shards:
            idx = shard.index[0] if shard.index else slice(None)
            start = 0 if idx.start is None else int(idx.start)
            stop = leaf.shape[0] if idx.stop is None else int(idx.stop)
            if stop - start != per or start % per:
                # e.g. a replicated (num_shards=1) layout over leaves
                # the compiler actually device-sharded — saving would
                # silently drop every shard but the first
                raise ValueError(
                    f"{key}: device shard [{start}:{stop}] does not tile "
                    f"the leaf into layout.num_shards={layout.num_shards} "
                    "contiguous slices — state and layout disagree")
            w = shard_worker_index(shard.index, per)
            if w in seen:
                continue
            seen.add(w)
            per_worker[w][key] = np.asarray(shard.data)
        if len(seen) != layout.num_shards:
            raise ValueError(
                f"{key}: only {len(seen)}/{layout.num_shards} shards "
                "addressable on this host")

    # the layout record carries the registry *strategy name* (the
    # strategy's checkpoint_layout hook), so a restore resolves the
    # exact strategy that wrote the state — and fails loudly, listing
    # the registered names, when it is unknown.  A Strategy INSTANCE
    # passed straight into DPConfig may never have been registered;
    # saving still works (to_json already records the name) — only a
    # later restore demands registration.
    layout_meta = layout.to_json()
    if layout.strategy is not None:
        from repro.core.strategy import get_strategy  # local: avoid cycle
        try:
            layout_meta = get_strategy(
                layout.strategy).checkpoint_layout(layout)
        except ValueError:
            pass                      # unregistered instance: keep to_json
    meta = {"step": int(step), "layout": layout_meta,
            "treedef": str(jax.tree_util.tree_structure(tree)),
            "leaves": meta_leaves}
    if extra is not None:
        meta["extra"] = extra
    return StateSnapshot(int(step), meta, replicated, per_worker)


def write_state_snapshot(ckpt_dir, snap: StateSnapshot, *,
                         keep_last: int | None = None) -> str:
    """File half of a sharded save — pure host I/O on a
    :class:`StateSnapshot`, safe to run from a background thread.  The
    whole step is staged under a ``tmp-`` directory and published with
    one atomic ``os.replace``; after a successful publish, stale
    ``tmp-`` leftovers from dead writers are swept and (with
    ``keep_last=``) published steps beyond the newest *keep_last* are
    pruned."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    step = snap.step
    final = ckpt_dir / f"step_{step:010d}.shards"
    tmp = ckpt_dir / f"tmp-step_{step:010d}.shards"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    (tmp / "meta.json").write_text(json.dumps(snap.meta, indent=1))
    np.savez(str(tmp / "replicated.npz"), **snap.replicated)
    if any(snap.per_worker.values()):  # fully replicated: no worker files
        for w, payload in snap.per_worker.items():
            np.savez(str(tmp / f"worker_{w:05d}.npz"), **payload)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)            # atomic publish
    _sweep_stale_tmp(ckpt_dir)
    if keep_last is not None:
        _prune_published(ckpt_dir, keep_last)
    _write_latest(ckpt_dir, step)
    return str(final)


def save_sharded_checkpoint(ckpt_dir, step: int, state, *,
                            keep_last: int | None = None,
                            extra: dict | None = None) -> str:
    """Write a TrainState keyed by ``(worker, layout)``: each sharded
    leaf is saved as the per-worker shards the devices already hold
    (``addressable_shards`` — no all-gather), replicated leaves once.
    Layout + leaf manifest go to ``meta.json``.  The whole step is
    staged under a ``tmp-`` directory and published with one atomic
    ``os.replace``.  Synchronous composition of
    :func:`snapshot_train_state` + :func:`write_state_snapshot`; the
    async checkpointer (``repro.elastic``) runs the same two halves
    with the write on a background thread."""
    return write_state_snapshot(
        ckpt_dir, snapshot_train_state(state, step, extra=extra),
        keep_last=keep_last)


def _checkpoint_dir(ckpt_dir, step):
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:010d}.shards"
    if not d.is_dir():
        raise FileNotFoundError(f"no sharded checkpoint for step {step} "
                                f"in {ckpt_dir}")
    return d, step


def _put_like(arr, leaf):
    sharding = getattr(leaf, "sharding", None)
    if sharding is None:
        return np.asarray(arr, dtype=getattr(leaf, "dtype", None))
    return jax.device_put(np.asarray(arr), sharding)


def restore_sharded_checkpoint(ckpt_dir, template, step: int | None = None):
    """Restore into the shardings/structure of ``template`` (a
    TrainState fresh from ``init_train_state``).  Same layout: each
    device pulls exactly its shard from its worker file (bitwise, no
    host-side full buffer).  Different layout (kind, shard count, or
    bucket permutation): reshard on host through the canonical flat
    representation.  Returns ``(TrainState, step)``."""
    from repro.core.train_state import (Layout, TrainState,
                                        shard_worker_index)
    if not isinstance(template, TrainState):
        raise TypeError("restore_sharded_checkpoint needs a TrainState "
                        "template (init_train_state(...))")
    d, step = _checkpoint_dir(ckpt_dir, step)
    meta = json.loads((d / "meta.json").read_text())
    saved_strategy = meta["layout"].get("strategy")
    if saved_strategy is not None:
        # resolve through the registry BEFORE touching the layout: a
        # checkpoint written by a custom strategy that is not registered
        # in this process must fail with the full name list, not a
        # shard-shape mismatch later
        from repro.core.strategy import available_strategies, get_strategy
        try:
            get_strategy(saved_strategy)
        except ValueError as e:
            raise ValueError(
                f"checkpoint {d} was written by strategy "
                f"{saved_strategy!r}, which is not registered here; "
                f"registered strategies: {list(available_strategies())}. "
                "Import/register it (repro.core.strategy."
                "register_strategy) before restoring") from e
    src = Layout.from_json(meta["layout"])
    tgt = template.layout
    if src.total != tgt.total:
        raise ValueError(f"checkpoint has {src.total} params, template "
                         f"has {tgt.total}")

    @functools.lru_cache(maxsize=None)
    def worker_npz(w):
        return _load_npz(d / f"worker_{w:05d}.npz")

    @functools.lru_cache(maxsize=None)
    def replicated_npz():
        return _load_npz(d / "replicated.npz")

    same = (src.kind == tgt.kind and src.num_shards == tgt.num_shards
            and src.bucket_bytes == tgt.bucket_bytes)
    tree_like = _state_tree(template)
    if same:
        new_flat = {}
        for key, leaf in _flatten(tree_like).items():
            info = meta["leaves"].get(key)
            if info is None:
                raise ValueError(f"checkpoint missing leaf {key}")
            if tuple(info["shape"]) != tuple(np.shape(leaf)):
                raise ValueError(f"{key}: checkpoint shape "
                                 f"{info['shape']} != {np.shape(leaf)}")
            if info["dtype"] != str(getattr(leaf, "dtype", "")):
                raise ValueError(
                    f"{key}: checkpoint dtype {info['dtype']} != template "
                    f"{getattr(leaf, 'dtype', None)} — restore into a "
                    "matching template or reshard explicitly")
            if info["sharded"]:
                per = leaf.shape[0] // tgt.num_shards
                new_flat[key] = jax.make_array_from_callback(
                    leaf.shape, leaf.sharding,
                    lambda idx, key=key, per=per: worker_npz(
                        shard_worker_index(idx, per))[key])
            else:
                new_flat[key] = _put_like(replicated_npz()[key], leaf)
        return _rebuild(template, tree_like, new_flat), step
    return _reshard_restore(template, meta, src, tgt, worker_npz,
                            replicated_npz), step


def _rebuild(template, tree_like, new_flat):
    from repro.core.train_state import TrainState
    keys = list(_flatten(tree_like))
    leaves = [new_flat[k] for k in keys]
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return TrainState(tree["params"], tree["opt_state"], tree["step"],
                      template.layout)


# ---- cross-layout resharding (host-side, still gather-free on device) ----

def _src_flat_leaf(key, meta, src, worker_npz, replicated_npz):
    """Canonical (contiguous, unpadded) flat vector for a source leaf
    that is a flat master vector — assembling worker shards and undoing
    the bucket-major permutation where needed."""
    if meta["leaves"][key]["sharded"]:
        from repro.core.train_state import assemble_full_flat
        shards = [worker_npz(w)[key] for w in range(src.num_shards)]
        full = assemble_full_flat(shards, src)
    else:
        full = replicated_npz()[key]
    return full[:src.total]


def _src_param_order_keys(meta, prefix):
    return [k for k in meta["leaves"] if k.startswith(prefix)]


def _src_canonical_moment(top_key, meta, src, worker_npz, replicated_npz):
    """Canonical flat [total] f32 for one optimizer moment, whatever
    structure the source stored it in."""
    flat_key = f"opt_state/{top_key}/flat"
    if flat_key in meta["leaves"]:
        return _src_flat_leaf(flat_key, meta, src, worker_npz,
                              replicated_npz)
    keys = _src_param_order_keys(meta, f"opt_state/{top_key}/")
    if not keys:
        raise ValueError(f"checkpoint has no moment {top_key!r}")
    parts = [np.asarray(replicated_npz()[k], np.float32).ravel()
             for k in keys]
    return np.concatenate(parts)[:src.total]


def _src_params_flat(meta, src) -> bool:
    """Whether the source checkpoint's params are the flat master
    vector (zero3 or any custom params-sharded strategy): exactly ONE
    "params" leaf, sharded, 1-D of the padded length.  A params pytree
    that happens to be a single bare replicated array also flattens to
    the key "params" but fails the sharded/shape signature."""
    info = meta["leaves"].get("params")
    return (info is not None and info.get("sharded")
            and list(info["shape"]) == [src.padded_total])


def _src_canonical_params(meta, src, worker_npz, replicated_npz):
    if _src_params_flat(meta, src):
        return _src_flat_leaf("params", meta, src, worker_npz,
                              replicated_npz)
    keys = _src_param_order_keys(meta, "params/")
    parts = [np.asarray(replicated_npz()[k]).ravel().astype(np.float32)
             for k in keys]
    return np.concatenate(parts)[:src.total]


def _tgt_flat_array(canonical, leaf, tgt):
    """Place a canonical flat [total] vector as the target's padded,
    (possibly bucket-major-permuted) sharded leaf."""
    from repro.core.train_state import split_flat_shards
    padded = np.zeros(tgt.padded_total, canonical.dtype)
    padded[:tgt.total] = canonical
    shards = split_flat_shards(padded, tgt)
    per = tgt.shard_len
    from repro.core.train_state import shard_worker_index
    return jax.make_array_from_callback(
        leaf.shape, leaf.sharding,
        lambda idx: np.asarray(shards[shard_worker_index(idx, per)],
                               dtype=leaf.dtype))


def _unflatten_params_like(canonical, params_like):
    leaves, treedef = jax.tree_util.tree_flatten(params_like)
    out, off = [], 0
    for leaf in leaves:
        size = int(np.prod(np.shape(leaf)))
        out.append(canonical[off:off + size]
                   .reshape(np.shape(leaf))
                   .astype(getattr(leaf, "dtype", canonical.dtype)))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def _reshard_restore(template, meta, src, tgt, worker_npz, replicated_npz):
    from repro.core.train_state import TrainState
    # params
    p_canon = _src_canonical_params(meta, src, worker_npz, replicated_npz)
    if tgt.params_flat:
        params = _tgt_flat_array(
            p_canon.astype(np.float32), template.params, tgt)
    else:
        tree = _unflatten_params_like(p_canon, template.params)
        params = jax.tree_util.tree_map(_put_like, tree, template.params)
    # optimizer state, key by the TEMPLATE's top-level structure
    opt_state = {}
    for k, sub in template.opt_state.items():
        sub_leaves = jax.tree_util.tree_leaves(sub)
        if sub_leaves and getattr(sub_leaves[0], "ndim", 0) == 0 \
                and len(sub_leaves) == 1 and not isinstance(sub, dict):
            scalar_key = f"opt_state/{k}"
            opt_state[k] = _put_like(replicated_npz()[scalar_key], sub)
            continue
        if isinstance(sub, dict) and set(sub) == {"flat"}:
            canon = _src_canonical_moment(k, meta, src, worker_npz,
                                          replicated_npz)
            opt_state[k] = {"flat": _tgt_flat_array(
                canon.astype(np.float32), sub["flat"], tgt)}
        else:
            canon = _src_canonical_moment(k, meta, src, worker_npz,
                                          replicated_npz)
            tree = _unflatten_params_like(canon, sub)
            opt_state[k] = jax.tree_util.tree_map(_put_like, tree, sub)
    step_leaf = _put_like(replicated_npz()["step"], template.step)
    return TrainState(params, opt_state, step_leaf, tgt)
