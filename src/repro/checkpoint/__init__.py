from repro.checkpoint.store import (
    CorruptCheckpointError, StateSnapshot, checkpoint_meta, latest_step,
    published_steps, restore_checkpoint, restore_serve_params,
    restore_sharded_checkpoint, restore_train_state, save_checkpoint,
    save_sharded_checkpoint, snapshot_train_state, write_state_snapshot,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "published_steps", "checkpoint_meta",
           "save_sharded_checkpoint", "restore_sharded_checkpoint",
           "restore_train_state", "restore_serve_params",
           "CorruptCheckpointError", "StateSnapshot",
           "snapshot_train_state", "write_state_snapshot"]
