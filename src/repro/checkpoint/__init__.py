from repro.checkpoint.store import (
    latest_step, restore_checkpoint, restore_serve_params,
    restore_sharded_checkpoint, restore_train_state, save_checkpoint,
    save_sharded_checkpoint,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "save_sharded_checkpoint", "restore_sharded_checkpoint",
           "restore_train_state", "restore_serve_params"]
