from repro.data.synthetic import (
    make_dataset, PAPER_DATASET_SHAPES, synthetic_tokens,
)
from repro.data.pipeline import ShardedLoader, rank0_scatter
from repro.data.specs import input_specs, batch_struct

__all__ = ["make_dataset", "PAPER_DATASET_SHAPES", "synthetic_tokens",
           "ShardedLoader", "rank0_scatter", "input_specs", "batch_struct"]
