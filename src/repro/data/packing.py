"""Sequence packing for LM training.

Concatenates variable-length documents into fixed-length training rows
separated by an EOS token, with a segment-id tensor so the loss can
mask cross-document positions (and attention could, if per-segment
masking is enabled).  Greedy first-fit packing — the standard
throughput lever for long-tail document lengths.
"""
from __future__ import annotations

import numpy as np


def pack_documents(docs, seq_len: int, *, eos_id: int, pad_id: int = 0):
    """docs: list of 1-D int arrays.  Returns (tokens, segment_ids) of
    shape (n_rows, seq_len); segment 0 = padding."""
    rows, segs = [], []
    cur = np.full((seq_len,), pad_id, np.int32)
    cur_seg = np.zeros((seq_len,), np.int32)
    off, seg = 0, 1

    def flush():
        nonlocal cur, cur_seg, off, seg
        rows.append(cur)
        segs.append(cur_seg)
        cur = np.full((seq_len,), pad_id, np.int32)
        cur_seg = np.zeros((seq_len,), np.int32)
        off, seg = 0, 1

    for doc in docs:
        doc = np.asarray(doc, np.int32)
        need = len(doc) + 1                     # + EOS
        while need > 0:
            space = seq_len - off
            if space == 0:
                flush()
                continue
            take = min(space, len(doc))
            cur[off:off + take] = doc[:take]
            cur_seg[off:off + take] = seg
            off += take
            doc = doc[take:]
            need = len(doc) + 1
            if len(doc) == 0:
                if off < seq_len:
                    cur[off] = eos_id
                    cur_seg[off] = seg
                    off += 1
                seg += 1
                need = 0
    if off > 0:
        flush()
    return np.stack(rows), np.stack(segs)


def packing_labels(tokens, segment_ids, *, ignore=-1):
    """Next-token labels that never cross a document boundary."""
    labels = np.concatenate(
        [tokens[:, 1:], np.full_like(tokens[:, :1], ignore)], axis=1)
    seg_next = np.concatenate(
        [segment_ids[:, 1:], np.zeros_like(segment_ids[:, :1])], axis=1)
    cross = (seg_next != segment_ids) | (seg_next == 0)
    return np.where(cross, ignore, labels)
