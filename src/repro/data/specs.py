"""ShapeDtypeStruct stand-ins for every model input — the dry-run's
"fake data" (weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import init_cache
from repro.models.model import VISION_EMBED_DIM


def batch_struct(cfg, shape, *, mode=None):
    """Shapes of the training/prefill batch for one input-shape spec."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    sd = jax.ShapeDtypeStruct
    if cfg.is_encoder_decoder:
        return {"src_embeds": sd((B, S, cfg.d_model), act),
                "tgt_tokens": sd((B, S), i32)}
    if cfg.frontend == "vision":
        n_img = cfg.num_frontend_tokens
        return {"tokens": sd((B, S - n_img), i32),
                "vision_embeds": sd((B, n_img, VISION_EMBED_DIM), act)}
    return {"tokens": sd((B, S), i32)}


def decode_struct(cfg, shape, cache_dtype=jnp.bfloat16):
    """(tokens, cache, cache_pos) structs for a decode step."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, cache_dtype, cross_len=S))
    return {"tokens": sd((B, 1), jnp.int32), "cache": cache,
            "cache_pos": sd((), jnp.int32)}


def input_specs(cfg, shape, *, mode=None):
    """Public entry: all input structs for the step the shape lowers."""
    mode = mode or shape.mode
    if mode in ("train", "prefill"):
        return batch_struct(cfg, shape, mode=mode)
    return decode_struct(cfg, shape)
