"""Data distribution — the paper's §3.3.1 work distribution, JAX-native.

The paper: "the default process (rank zero) reads the samples from the
disk and splits them across processes" with point-to-point sends.  The
JAX equivalent of that scatter is device_put with a batch-sharded
NamedSharding: the host (rank 0 here — single-controller) holds the
global array and the runtime scatters shards to devices.  Equal splits
only, like the paper ("each device is considered of equal compute
capacity").

``ShardedLoader`` adds the epoch/shuffle/steady-state machinery a real
training loop needs (deterministic per-epoch permutation, drop-last).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.rules import dp_axes


def rank0_scatter(mesh, batch):
    """Scatter a host-resident batch across the data-parallel axes —
    the MPI_Scatter moment of the paper."""
    axes = dp_axes(mesh)
    spec_axes = axes if len(axes) > 1 else axes[0]

    def put(x):
        spec = P(*((spec_axes,) + (None,) * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, batch)


class ShardedLoader:
    """Deterministic epoch-shuffled minibatch loader over numpy arrays."""

    def __init__(self, data, batch_size: int, *, mesh=None, seed: int = 0,
                 drop_last: bool = True):
        self.data = data                     # dict of (N, ...) arrays
        self.n = len(next(iter(data.values())))
        self.batch_size = batch_size
        self.mesh = mesh
        self.seed = seed
        self.drop_last = drop_last

    def epoch(self, epoch_idx: int):
        rng = np.random.default_rng(self.seed + epoch_idx)
        perm = rng.permutation(self.n)
        nb = self.n // self.batch_size if self.drop_last else \
            -(-self.n // self.batch_size)
        for b in range(nb):
            idx = perm[b * self.batch_size:(b + 1) * self.batch_size]
            batch = {k: v[idx] for k, v in self.data.items()}
            if self.mesh is not None:
                batch = rank0_scatter(self.mesh, batch)
            yield batch

    def steps_per_epoch(self):
        return self.n // self.batch_size
