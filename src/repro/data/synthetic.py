"""Synthetic datasets with the paper's exact dataset shapes.

The paper's datasets (MNIST, CIFAR10, Adult, Acoustic, HIGGS) are not
redistributable offline, so we generate seeded teacher-labelled data
with identical feature/class/sample geometry: a frozen random "teacher"
MLP labels Gaussian-mixture inputs, giving a learnable (non-trivial,
non-separable) problem so accuracy/loss curves behave like real data
and every tensor shape matches the paper's Table 1 exactly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# name -> (n_features | image hw+c, n_classes, n_train)
PAPER_DATASET_SHAPES = {
    "adult":    {"features": 123, "classes": 2, "train": 32_561},
    "acoustic": {"features": 50, "classes": 3, "train": 78_823},
    "mnist":    {"features": 784, "classes": 10, "train": 60_000,
                 "image": (28, 28, 1)},
    "cifar10":  {"features": 3072, "classes": 10, "train": 50_000,
                 "image": (32, 32, 3)},
    "higgs":    {"features": 28, "classes": 2, "train": 10_900_000,
                 "subsample": 200_000},   # keep CPU benches tractable
}


@dataclasses.dataclass
class Dataset:
    name: str
    x: np.ndarray          # (N, features) or (N, H, W, C)
    y: np.ndarray          # (N,)
    num_classes: int


def _teacher_labels(key, x, n_classes):
    d = x.reshape(x.shape[0], -1).shape[1]
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (d, 64)) / np.sqrt(d)
    w2 = jax.random.normal(k2, (64, n_classes)) / 8.0
    logits = jnp.tanh(x.reshape(x.shape[0], -1) @ w1) @ w2
    # temperature + argmax -> deterministic, learnable labels
    return jnp.argmax(logits, axis=-1)


def make_dataset(name: str, *, seed: int = 0, as_images: bool = False,
                 n: int | None = None) -> Dataset:
    spec = PAPER_DATASET_SHAPES[name]
    n = n or spec.get("subsample", spec["train"])
    key = jax.random.PRNGKey(seed)
    kx, kc, ky = jax.random.split(key, 3)
    d = spec["features"]
    # gaussian mixture: one centre per class region
    centers = jax.random.normal(kc, (8, d)) * 1.5
    comp = jax.random.randint(kx, (n,), 0, 8)
    x = centers[comp] + jax.random.normal(ky, (n, d))
    y = _teacher_labels(key, x, spec["classes"])
    x = np.asarray(x, np.float32)
    if as_images and "image" in spec:
        x = x.reshape((n,) + spec["image"])
    return Dataset(name, x, np.asarray(y, np.int32), spec["classes"])


def synthetic_tokens(key, batch, seq_len, vocab):
    """Zipf-ish synthetic token stream for LM smoke training."""
    u = jax.random.uniform(key, (batch, seq_len))
    ranks = jnp.floor(vocab ** u).astype(jnp.int32)   # heavy-tailed
    return jnp.clip(ranks, 0, vocab - 1)
