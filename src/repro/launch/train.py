"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 50 --batch 8 --seq 128

With ``--reduced`` (default on CPU) the smoke variant runs on the host
devices; without it, the full config is trained on the production mesh
(TPU slice) using the sharded train step, microbatching, remat and
checkpointing — the same code path the dry-run lowers.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import optim as optim_lib
from repro.api import Trainer
from repro.checkpoint import (checkpoint_meta, latest_step,
                              restore_train_state, save_checkpoint,
                              save_sharded_checkpoint)
from repro.elastic import FaultInjector, FaultPlan
from repro.configs import ARCHITECTURES, get_config, smoke_config
from repro.data import synthetic_tokens
from repro.launch.mesh import make_production_mesh, make_host_mesh
from repro.models import init_model
from repro.core import (DPConfig, available_strategies,
                        init_train_state as init_dp_train_state)
from repro.sharding import batch_shardings
from repro.sharding.ctx import set_activation_mesh
from repro.train.step import (TrainConfig, make_loss_fn, make_train_step,
                              init_train_state as init_gspmd_train_state)


def step_batch(cfg, key, step, batch, seq):
    """The batch for global step ``step`` — a pure function of
    ``(seed, step)``, so a resumed run regenerates the exact stream the
    killed run would have seen (the data cursor in ``meta.json`` is
    just ``(data_seed, next_step)``)."""
    return make_batch(cfg, jax.random.fold_in(key, step), batch, seq)


def data_cursor(seed, next_step):
    """The ``extra=`` payload saved with every checkpoint: enough to
    restart the synthetic stream without replaying or skipping."""
    return {"data_cursor": {"data_seed": int(seed),
                            "next_step": int(next_step)}}


def restore_cursor(ckpt_dir, at, default_seed):
    """Read the saved data cursor (absent in pre-cursor checkpoints:
    fall back to the CLI seed at the restored step)."""
    try:
        cur = checkpoint_meta(ckpt_dir, at).get("extra", {})["data_cursor"]
        return int(cur["data_seed"]), int(cur["next_step"])
    except (FileNotFoundError, KeyError):
        return default_seed, at


def make_batch(cfg, key, batch, seq):
    toks = synthetic_tokens(key, batch, seq, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        return {"src_embeds": jax.random.normal(
            key, (batch, seq, cfg.d_model), jnp.bfloat16),
            "tgt_tokens": toks}
    if cfg.frontend == "vision":
        nv = min(cfg.num_frontend_tokens, seq // 2)
        return {"tokens": toks[:, :seq - nv],
                "vision_embeds": jax.random.normal(
                    key, (batch, nv, 1024), jnp.bfloat16)}
    return {"tokens": toks}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke variant on host devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="checkpoint every N steps")
    ap.add_argument("--ckpt-async", action="store_true",
                    help="publish checkpoints from a background daemon "
                         "(repro.elastic.AsyncCheckpointer): the step "
                         "loop blocks only for the device->host copy")
    ap.add_argument("--ckpt-keep-last", type=int, default=0,
                    help="retain only the newest N published steps "
                         "(0: keep all)")
    ap.add_argument("--data-seed", type=int, default=0,
                    help="seed of the per-step synthetic batch stream")
    ap.add_argument("--fault-step", type=int, default=-1,
                    help="fault injection: hard-kill (os._exit) the run "
                         "at this step boundary; REPRO_FAULT_STEP env "
                         "overrides")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dp-strategy", default="",
                    choices=["", *available_strategies()],
                    help="reduced mode: run the explicit shard_map DP step "
                         "with this registered strategy (zero1 shards the "
                         "optimizer state 1/p per device, zero2 also the "
                         "gradient accumulator, zero3 also the params; "
                         "zero1_hier/zero3_hier stage their collectives "
                         "over pod*data so DCN only carries the "
                         "1/n_intra shard)")
    ap.add_argument("--overlap", default="off",
                    choices=["off", "on", "serial"],
                    help="bucket-level overlap scheduler: 'on' double-"
                         "buffers the gradient collectives behind "
                         "neighbouring buckets' compute, 'serial' runs the "
                         "same buckets barrier-chained (baseline)")
    ap.add_argument("--bucket-bytes", type=int, default=64 * 2 ** 20,
                    help="target bucket size for bucketed/overlap schedules")
    args = ap.parse_args()
    if args.dp_strategy and not args.reduced:
        ap.error("--dp-strategy requires --reduced (the full-mesh path "
                 "gets its sharding from GSPMD, not DPConfig)")
    if args.overlap != "off" and not args.dp_strategy:
        ap.error("--overlap requires --dp-strategy (it schedules the "
                 "explicit DP collectives)")

    if args.reduced:
        cfg = smoke_config(args.arch).with_overrides(dtype="float32")
        mesh = make_host_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        set_activation_mesh(mesh)

    tc = TrainConfig(optimizer=args.optimizer, lr=args.lr,
                     microbatches=args.microbatches,
                     remat=not args.reduced)
    key = jax.random.PRNGKey(0)

    if args.reduced and args.dp_strategy:
        # explicit shard_map data parallelism (the paper's MPI layout),
        # driven end to end through the Trainer facade — strategy
        # resolution, TrainState construction and sharded checkpointing
        # all live behind it
        return run_dp(args, cfg, tc, mesh, key)
    if args.reduced:
        params = init_model(cfg, key)
        step_fn, optimizer = make_train_step(cfg, mesh, tc)
        state = init_dp_train_state(optimizer, params)   # replicated
        step = jax.jit(step_fn)
    else:
        state, shardings = init_gspmd_train_state(cfg, mesh, tc, key)
        step_fn, _ = make_train_step(cfg, mesh, tc)
        step = jax.jit(step_fn, donate_argnums=(0,))

    start = 0
    data_seed = args.data_seed
    if args.ckpt and latest_step(args.ckpt) is not None:
        # restore_train_state picks the store by what is ON DISK, not
        # the current layout: a .shards dir restores through the
        # sharded store (resharding across strategy changes), a legacy
        # npz loads leaf-for-leaf
        state, start = restore_train_state(args.ckpt, state)
        data_seed, start = restore_cursor(args.ckpt, start, data_seed)
        print(f"resumed from step {start}")

    injector = _make_injector(args)
    keep_last = args.ckpt_keep_last or None
    ckpt = _make_saver(args, reduced=args.reduced)
    data_key = jax.random.PRNGKey(data_seed)
    t0 = time.time()
    for i in range(start, start + args.steps):
        batch = step_batch(cfg, data_key, i, args.batch, args.seq)
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == start + args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            if args.reduced:
                # every reduced-mode TrainState (replicated or ZeRO)
                # goes through the sharded store, so later runs can
                # resume under ANY --dp-strategy via cross-layout
                # restore; the full GSPMD path keeps the legacy npz
                # (its leaves are model-sharded, not flat DP shards)
                ckpt(state, i + 1,
                     extra=data_cursor(data_seed, i + 1),
                     keep_last=keep_last)
            else:
                save_checkpoint(args.ckpt, i + 1,
                                (state.params, state.opt_state))
        if injector is not None:
            injector.after_step(i + 1)
    _finish_saves(ckpt)
    print("done")


def _make_injector(args):
    """Env wins (the subprocess tests set it); --fault-step is the CLI
    spelling of the same plan."""
    injector = FaultInjector.from_env()
    if injector is None and args.fault_step >= 0:
        injector = FaultInjector(FaultPlan(args.fault_step))
    return injector


def _make_saver(args, *, reduced):
    """The reduced-mode checkpoint callable: synchronous
    ``save_sharded_checkpoint`` or the AsyncCheckpointer daemon
    (``--ckpt-async``) — same ``(state, step, extra=, keep_last=)``
    shape either way."""
    if not (args.ckpt and reduced and args.ckpt_async):
        def sync(state, at, *, extra, keep_last):
            save_sharded_checkpoint(args.ckpt, at, state,
                                    keep_last=keep_last, extra=extra)
        return sync
    from repro.elastic import AsyncCheckpointer
    ck = AsyncCheckpointer(args.ckpt,
                           keep_last=args.ckpt_keep_last or None)

    def async_save(state, at, *, extra, keep_last):
        rec = ck.save(state, at, extra=extra)
        print(f"ckpt async step {at}: blocked {rec['blocking_s']*1e3:.1f}ms "
              f"({rec['bytes']/2**20:.1f} MiB)", flush=True)

    async_save.checkpointer = ck
    return async_save


def _finish_saves(ckpt):
    ck = getattr(ckpt, "checkpointer", None)
    if ck is not None:
        ck.wait()
        s = ck.stats()
        print(f"ckpt stats: published {s['published']}/{s['saves']} "
              f"(dropped {s['dropped']}), "
              f"blocked {s['total_blocking_s']:.3f}s, "
              f"wrote {s['total_write_s']:.3f}s", flush=True)
        ck.close()


def run_dp(args, cfg, tc, mesh, key):
    """Reduced-mode explicit-DP training, end to end through the
    Trainer facade.  The ZeRO strategies shard optimizer state / grads
    / params 1/p per device; zero1_hier additionally stages its
    collectives over the pod×data axes — all carried by the TrainState
    contract behind the facade."""
    import json

    params = init_model(cfg, key)
    optimizer = optim_lib.get_optimizer(tc.optimizer, tc.lr)
    base_loss = make_loss_fn(cfg, tc)
    overlap = {"off": False, "on": True, "serial": "serial"}[args.overlap]
    dp = DPConfig(sync="grads", strategy=args.dp_strategy,
                  microbatches=tc.microbatches, overlap=overlap,
                  bucket_bytes=args.bucket_bytes)
    trainer = Trainer.create(loss_fn=lambda p, b: base_loss(p, b)[0],
                             params=params, optimizer=optimizer, dp=dp,
                             mesh=mesh)
    print("trainer:", json.dumps(trainer.describe(), sort_keys=True)[:400],
          flush=True)

    start = 0
    data_seed = args.data_seed
    if args.ckpt and latest_step(args.ckpt) is not None:
        # elastic resume: the facade reshards across strategy/mesh
        # changes (a 2x16 zero1_hier run killed mid-flight resumes as
        # 1x8 zero3) and falls back past torn/corrupt steps to the
        # newest readable published one
        start, skipped = trainer.restore_elastic(args.ckpt)
        data_seed, start = restore_cursor(args.ckpt, start, data_seed)
        for s, reason in skipped:
            print(f"skipped corrupt step {s}: {reason}", flush=True)
        print(f"resumed from step {start}")

    injector = _make_injector(args)
    keep_last = args.ckpt_keep_last or None
    data_key = jax.random.PRNGKey(data_seed)
    if args.overlap != "off":
        # prove the schedule before running it: asyncify the lowered HLO
        # and report the -start/-done pairs a latency-hiding backend
        # would issue
        from repro.core.overlap import asyncify_hlo, lowered_hlo_text
        hlo = lowered_hlo_text(trainer.lower(
            step_batch(cfg, data_key, start, args.batch, args.seq)))
        _, rep = asyncify_hlo(hlo)
        print(f"overlap[{args.overlap}] async collective pairs: "
              f"{rep['pairs']}/{rep['collectives']} "
              f"{rep['by_kind']}", flush=True)
    t0 = time.time()
    for i in range(start, start + args.steps):
        batch = step_batch(cfg, data_key, i, args.batch, args.seq)
        metrics = trainer.step(batch)
        if i % 10 == 0 or i == start + args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            # every DP TrainState goes through the sharded store, so
            # later runs can resume under ANY --dp-strategy via
            # cross-layout restore
            cur = data_cursor(data_seed, i + 1)
            if args.ckpt_async:
                rec = trainer.save_async(args.ckpt, keep_last=keep_last,
                                         extra=cur)
                print(f"ckpt async step {i + 1}: blocked "
                      f"{rec['blocking_s']*1e3:.1f}ms", flush=True)
            else:
                trainer.save(args.ckpt, keep_last=keep_last, extra=cur)
        if injector is not None:
            injector.after_step(i + 1)
    stats = trainer.finish_saves()
    if stats is not None:
        print(f"ckpt stats: published {stats['published']}"
              f"/{stats['saves']} (dropped {stats['dropped']}), "
              f"blocked {stats['total_blocking_s']:.3f}s, "
              f"wrote {stats['total_write_s']:.3f}s", flush=True)
    print("done")


if __name__ == "__main__":
    main()
