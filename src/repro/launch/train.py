"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 50 --batch 8 --seq 128

With ``--reduced`` (default on CPU) the smoke variant runs on the host
devices; without it, the full config is trained on the production mesh
(TPU slice) using the sharded train step, microbatching, remat and
checkpointing — the same code path the dry-run lowers.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import optim as optim_lib
from repro.api import Trainer
from repro.checkpoint import (latest_step, restore_train_state,
                              save_checkpoint, save_sharded_checkpoint)
from repro.configs import ARCHITECTURES, get_config, smoke_config
from repro.data import synthetic_tokens
from repro.launch.mesh import make_production_mesh, make_host_mesh
from repro.models import init_model
from repro.core import (DPConfig, available_strategies,
                        init_train_state as init_dp_train_state)
from repro.sharding import batch_shardings
from repro.sharding.ctx import set_activation_mesh
from repro.train.step import (TrainConfig, make_loss_fn, make_train_step,
                              init_train_state as init_gspmd_train_state)


def make_batch(cfg, key, batch, seq):
    toks = synthetic_tokens(key, batch, seq, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        return {"src_embeds": jax.random.normal(
            key, (batch, seq, cfg.d_model), jnp.bfloat16),
            "tgt_tokens": toks}
    if cfg.frontend == "vision":
        nv = min(cfg.num_frontend_tokens, seq // 2)
        return {"tokens": toks[:, :seq - nv],
                "vision_embeds": jax.random.normal(
                    key, (batch, nv, 1024), jnp.bfloat16)}
    return {"tokens": toks}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke variant on host devices")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dp-strategy", default="",
                    choices=["", *available_strategies()],
                    help="reduced mode: run the explicit shard_map DP step "
                         "with this registered strategy (zero1 shards the "
                         "optimizer state 1/p per device, zero2 also the "
                         "gradient accumulator, zero3 also the params; "
                         "zero1_hier stages zero1 over pod*data so DCN "
                         "only carries the 1/n_intra shard)")
    ap.add_argument("--overlap", default="off",
                    choices=["off", "on", "serial"],
                    help="bucket-level overlap scheduler: 'on' double-"
                         "buffers the gradient collectives behind "
                         "neighbouring buckets' compute, 'serial' runs the "
                         "same buckets barrier-chained (baseline)")
    ap.add_argument("--bucket-bytes", type=int, default=64 * 2 ** 20,
                    help="target bucket size for bucketed/overlap schedules")
    args = ap.parse_args()
    if args.dp_strategy and not args.reduced:
        ap.error("--dp-strategy requires --reduced (the full-mesh path "
                 "gets its sharding from GSPMD, not DPConfig)")
    if args.overlap != "off" and not args.dp_strategy:
        ap.error("--overlap requires --dp-strategy (it schedules the "
                 "explicit DP collectives)")

    if args.reduced:
        cfg = smoke_config(args.arch).with_overrides(dtype="float32")
        mesh = make_host_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        set_activation_mesh(mesh)

    tc = TrainConfig(optimizer=args.optimizer, lr=args.lr,
                     microbatches=args.microbatches,
                     remat=not args.reduced)
    key = jax.random.PRNGKey(0)

    if args.reduced and args.dp_strategy:
        # explicit shard_map data parallelism (the paper's MPI layout),
        # driven end to end through the Trainer facade — strategy
        # resolution, TrainState construction and sharded checkpointing
        # all live behind it
        return run_dp(args, cfg, tc, mesh, key)
    if args.reduced:
        params = init_model(cfg, key)
        step_fn, optimizer = make_train_step(cfg, mesh, tc)
        state = init_dp_train_state(optimizer, params)   # replicated
        step = jax.jit(step_fn)
    else:
        state, shardings = init_gspmd_train_state(cfg, mesh, tc, key)
        step_fn, _ = make_train_step(cfg, mesh, tc)
        step = jax.jit(step_fn, donate_argnums=(0,))

    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        # restore_train_state picks the store by what is ON DISK, not
        # the current layout: a .shards dir restores through the
        # sharded store (resharding across strategy changes), a legacy
        # npz loads leaf-for-leaf
        state, start = restore_train_state(args.ckpt, state)
        print(f"resumed from step {start}")

    batch = make_batch(cfg, key, args.batch, args.seq)
    t0 = time.time()
    for i in range(start, start + args.steps):
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == start + args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt and (i + 1) % 50 == 0:
            if args.reduced:
                # every reduced-mode TrainState (replicated or ZeRO)
                # goes through the sharded store, so later runs can
                # resume under ANY --dp-strategy via cross-layout
                # restore; the full GSPMD path keeps the legacy npz
                # (its leaves are model-sharded, not flat DP shards)
                save_sharded_checkpoint(args.ckpt, i + 1, state)
            else:
                save_checkpoint(args.ckpt, i + 1,
                                (state.params, state.opt_state))
    print("done")


def run_dp(args, cfg, tc, mesh, key):
    """Reduced-mode explicit-DP training, end to end through the
    Trainer facade.  The ZeRO strategies shard optimizer state / grads
    / params 1/p per device; zero1_hier additionally stages its
    collectives over the pod×data axes — all carried by the TrainState
    contract behind the facade."""
    import json

    params = init_model(cfg, key)
    optimizer = optim_lib.get_optimizer(tc.optimizer, tc.lr)
    base_loss = make_loss_fn(cfg, tc)
    overlap = {"off": False, "on": True, "serial": "serial"}[args.overlap]
    dp = DPConfig(sync="grads", strategy=args.dp_strategy,
                  microbatches=tc.microbatches, overlap=overlap,
                  bucket_bytes=args.bucket_bytes)
    trainer = Trainer.create(loss_fn=lambda p, b: base_loss(p, b)[0],
                             params=params, optimizer=optimizer, dp=dp,
                             mesh=mesh)
    print("trainer:", json.dumps(trainer.describe(), sort_keys=True)[:400],
          flush=True)

    start = 0
    if args.ckpt and latest_step(args.ckpt) is not None:
        # the facade picks the store by what is ON DISK (.shards dir vs
        # legacy npz) and reshards across strategy changes — a zero1
        # run resumed as flat, flat resumed as zero3, ...
        start = trainer.restore(args.ckpt)
        print(f"resumed from step {start}")

    batch = make_batch(cfg, key, args.batch, args.seq)
    if args.overlap != "off":
        # prove the schedule before running it: asyncify the lowered HLO
        # and report the -start/-done pairs a latency-hiding backend
        # would issue
        from repro.core.overlap import asyncify_hlo, lowered_hlo_text
        hlo = lowered_hlo_text(trainer.lower(batch))
        _, rep = asyncify_hlo(hlo)
        print(f"overlap[{args.overlap}] async collective pairs: "
              f"{rep['pairs']}/{rep['collectives']} "
              f"{rep['by_kind']}", flush=True)
    t0 = time.time()
    for i in range(start, start + args.steps):
        metrics = trainer.step(batch)
        if i % 10 == 0 or i == start + args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt and (i + 1) % 50 == 0:
            # every DP TrainState goes through the sharded store, so
            # later runs can resume under ANY --dp-strategy via
            # cross-layout restore
            trainer.save(args.ckpt)
    print("done")


if __name__ == "__main__":
    main()
