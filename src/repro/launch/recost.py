import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Merge StableHLO-walker FLOP/byte counts into the dry-run JSON.

``compiled.cost_analysis()`` undercounts loop bodies (counted once);
this re-lowers each pair (no compile — seconds) and records
``flops_global`` / ``dot_bytes_global`` from repro.roofline.hlocost.
"""
import json
import time

from repro.launch.dryrun import RESULTS_DIR, lower_pair, pairs_for
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlocost import stablehlo_cost


def main():
    out_path = RESULTS_DIR / "dryrun_single.json"
    results = json.loads(out_path.read_text())
    mesh = make_production_mesh()
    for arch, shape in pairs_for():
        key = f"{arch}|{shape}"
        entry = results.get(key)
        if entry is None or not entry.get("ok"):
            continue
        if "flops_global" in entry and "--force" not in os.sys.argv:
            continue
        t0 = time.time()
        lowered, cfg, tc = lower_pair(arch, shape, mesh)
        cost = stablehlo_cost(lowered.as_text())
        entry["flops_global"] = cost["flops"]
        entry["dot_bytes_global"] = cost["dot_bytes"]
        entry["unresolved_loops"] = cost["unresolved_loops"]
        print(f"{key:45s} flops={cost['flops']:.3e} "
              f"dot_bytes={cost['dot_bytes']:.3e} "
              f"unresolved={cost['unresolved_loops']} "
              f"({time.time()-t0:.1f}s)", flush=True)
        out_path.write_text(json.dumps(results, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
