"""Serving launcher: batched greedy generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --batch 4 --prompt-len 32 --new-tokens 16

Without --reduced, the full config is served on the production mesh
with the sharded prefill/decode steps the dry-run lowers (decode_32k
shape).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, get_config, smoke_config
from repro.data import synthetic_tokens
from repro.launch.mesh import make_production_mesh, make_host_mesh
from repro.models import init_model
from repro.serve.engine import ServeEngine
from repro.sharding.ctx import set_activation_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    if args.reduced:
        cfg = smoke_config(args.arch).with_overrides(dtype="float32")
        dtype = jnp.float32
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh()
        set_activation_mesh(mesh)
        dtype = jnp.bfloat16
    if cfg.is_encoder_decoder or cfg.frontend != "none":
        raise SystemExit("serve launcher drives decoder-only archs; "
                         "see examples/ for VLM / enc-dec handling")

    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    prompts = synthetic_tokens(key, args.batch, args.prompt_len,
                               cfg.vocab_size)
    eng = ServeEngine(cfg, params, batch_size=args.batch,
                      max_len=args.prompt_len + args.new_tokens,
                      dtype=dtype)
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"{args.batch} seqs x {args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s incl. compile)")
    print(out.tolist())


if __name__ == "__main__":
    main()
