"""Serving launcher: continuous batching by default, checkpoint-backed.

    # serve fresh random weights (smoke config) with the paged engine
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --batch 4 --prompt-len 32 --new-tokens 16

    # close the train-and-serve loop: serve what train.py checkpointed
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --dp-strategy zero1 --steps 50 --ckpt /tmp/ck
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --restore /tmp/ck

Without --reduced, the full config is served on the production mesh
with the sharded prefill/decode steps the dry-run lowers (decode_32k
shape) — via the LEGACY slab engine: the paged pool is not mesh-
sharded yet, so the continuous engine is reduced-mode only and the
launcher refuses the combination.  The activation mesh is SCOPED to
this call (``sharding.ctx.activation_mesh``) so in-process callers
never inherit it.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_config, smoke_config
from repro.data import synthetic_tokens
from repro.launch.mesh import make_production_mesh
from repro.models import init_model
from repro.serve import (SamplingConfig, make_engine,
                         make_engine_from_checkpoint)
from repro.serve.scheduler import ContinuousScheduler
from repro.sharding.ctx import activation_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="serving slots (decode batch width)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous engine: total requests to submit "
                         "(default: --batch; > --batch exercises "
                         "admission on retirement)")
    ap.add_argument("--engine", default=None,
                    choices=["continuous", "legacy"],
                    help="default: continuous when --reduced, legacy on "
                         "the production mesh (the paged pool is not "
                         "mesh-sharded yet — ROADMAP follow-on)")
    ap.add_argument("--restore", default="",
                    help="serve the params of this checkpoint dir "
                         "(written by launch/train.py — any sharded "
                         "layout, or legacy npz) instead of random init")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG seed")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--eos-id", type=int, default=None)
    args = ap.parse_args(argv)

    if args.reduced:
        cfg = smoke_config(args.arch).with_overrides(dtype="float32")
        mesh = None
        dtype = jnp.float32
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh()
        dtype = jnp.bfloat16
    if cfg.is_encoder_decoder or cfg.frontend != "none":
        raise SystemExit("serve launcher drives decoder-only archs; "
                         "see examples/ for VLM / enc-dec handling")

    engine = args.engine or ("continuous" if args.reduced else "legacy")
    if engine == "continuous" and not args.reduced:
        raise SystemExit(
            "--engine continuous does not run on the production mesh "
            "yet: the paged KV pool is unsharded (host-mesh only), so "
            "at the decode_32k shape it would replicate every slot's "
            "pages per chip; use --engine legacy (sharded slab decode) "
            "or --reduced")

    sampling = SamplingConfig(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    max_len = -(-(args.prompt_len + args.new_tokens + 8)
                // args.page_size) * args.page_size
    engine_kw = dict(engine=engine, batch_size=args.batch,
                     max_len=max_len, dtype=dtype, eos_id=args.eos_id,
                     sampling=sampling, seed=args.seed)
    if engine == "continuous":
        engine_kw["page_size"] = args.page_size

    key = jax.random.PRNGKey(args.seed)
    # the activation mesh is scoped: nothing leaks into in-process
    # callers after this returns (the --reduced path explicitly runs
    # mesh-free even if a previous caller left one set)
    with activation_mesh(mesh):
        if args.restore:
            eng = make_engine_from_checkpoint(args.restore, cfg,
                                              step=args.step, **engine_kw)
            print(f"serving checkpoint step {eng.restored_step} "
                  f"from {args.restore}")
        else:
            eng = make_engine(cfg, init_model(cfg, key), **engine_kw)

        n_req = args.requests or args.batch
        if engine == "legacy" and n_req > args.batch:
            raise SystemExit(
                f"--requests {n_req} > --batch {args.batch}: the legacy "
                "lockstep engine has no queue (all slots start and "
                "retire together); use the continuous engine or raise "
                "--batch")
        prompts = synthetic_tokens(key, n_req, args.prompt_len,
                                   cfg.vocab_size)
        t0 = time.time()
        if isinstance(eng, ContinuousScheduler):
            outs = eng.generate(list(np.asarray(prompts)),
                                args.new_tokens)
            dt = time.time() - t0
            n_tok = sum(len(o) for o in outs)
            st = eng.stats()
            print(f"{n_req} requests x {args.new_tokens} tokens in "
                  f"{dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile, "
                  f"{st['syncs_per_token']:.3f} host syncs/token, "
                  f"pool {st['pool_pages_in_use']} pages live)")
            outs = [o.tolist() for o in outs]
        else:
            out = eng.generate(prompts[:args.batch], args.new_tokens)
            dt = time.time() - t0
            print(f"{args.batch} seqs x {args.new_tokens} tokens in "
                  f"{dt:.2f}s "
                  f"({args.batch*args.new_tokens/dt:.1f} tok/s incl. "
                  f"compile)")
            outs = np.asarray(out).tolist()
        print(outs)
    return outs


if __name__ == "__main__":
    main()
