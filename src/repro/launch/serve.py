"""Serving launcher: continuous batching by default, checkpoint-backed.

    # serve fresh random weights (smoke config) with the paged engine
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --batch 4 --prompt-len 32 --new-tokens 16

    # close the train-and-serve loop: serve what train.py checkpointed
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --dp-strategy zero1 --steps 50 --ckpt /tmp/ck
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --restore /tmp/ck

Without --reduced, the full config is served on the production mesh
(data=16, model=16) by the CONTINUOUS engine: the paged pool is
model-sharded over the mesh (``sharding.rules.pool_spec``), params
land with the serve-mode shardings, and MoE decode routes through the
expert-parallel ``shard_map``.  ``--mesh-shape DxM`` overrides the
topology at any scale (tests use 2x4 under
``--xla_force_host_platform_device_count=8``) and composes with
--reduced.  The mesh is threaded INTO the engine (``make_engine(...,
mesh=)``) and every compiled call runs under a scoped serve topology,
so in-process callers never inherit device state from this launcher.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_config, smoke_config
from repro.data import synthetic_tokens
from repro.launch.mesh import make_production_mesh, make_serve_mesh
from repro.models import init_model
from repro.serve import (FrontDoor, SamplingConfig, make_engine,
                         make_engine_from_checkpoint)
from repro.serve.scheduler import ContinuousScheduler
from repro.sharding.ctx import activation_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="serving slots (decode batch width)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=None,
                    help="continuous engine: total requests to submit "
                         "(default: --batch; > --batch exercises "
                         "admission on retirement)")
    ap.add_argument("--engine", default=None,
                    choices=["continuous", "legacy"],
                    help="default: continuous (the production path); "
                         "legacy is the lockstep slab reference")
    ap.add_argument("--mesh-shape", default=None, metavar="DxM",
                    help="serve mesh shape, e.g. 2x4 (data x model); "
                         "default: production 16x16 without --reduced, "
                         "no mesh with --reduced")
    ap.add_argument("--restore", default="",
                    help="serve the params of this checkpoint dir "
                         "(written by launch/train.py — any sharded "
                         "layout, or legacy npz) instead of random init")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--decode-kernel", default=None,
                    choices=["xla", "pallas"],
                    help="paged decode attention: xla (gather + masked "
                         "softmax reference) or pallas (fused page-"
                         "table-gather flash kernel; interpret-mode on "
                         "CPU).  Default: the arch config's setting")
    ap.add_argument("--report", action="store_true",
                    help="print the dispatch-discipline report: per-"
                         "phase (prefill/decode) compiled-call and "
                         "host-sync counters from the scheduler")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="continuous engine: speculative decode with "
                         "K-token verify chunks (the carried token + "
                         "K-1 MTP drafts per fused-loop step).  Greedy-"
                         "only, needs an arch with cfg.mtp_depth > 0; "
                         "outputs stay bitwise-equal to K=0")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous engine: radix prefix cache — "
                         "shared prompt prefixes alias already-written "
                         "KV pages instead of re-prefilling")
    ap.add_argument("--stream", action="store_true",
                    help="continuous engine: serve through the async "
                         "front door, printing each request's tokens "
                         "as its decode chunks sync")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling PRNG seed")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--eos-id", type=int, default=None)
    args = ap.parse_args(argv)

    # resolve the request count ONCE, up front: None and the legacy 0
    # sentinel both mean "--batch requests" — every later consumer
    # (submission, the legacy-engine bound, the report line) sees the
    # resolved value, never the sentinel
    if not args.requests:
        args.requests = args.batch

    if args.reduced:
        cfg = smoke_config(args.arch).with_overrides(dtype="float32")
        dtype = jnp.float32
    else:
        cfg = get_config(args.arch)
        dtype = jnp.bfloat16
    if args.decode_kernel:
        cfg = cfg.with_overrides(decode_kernel=args.decode_kernel)
    if args.mesh_shape:
        try:
            d, m = (int(v) for v in args.mesh_shape.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--mesh-shape {args.mesh_shape!r}: "
                             "expected DxM, e.g. 2x4")
        mesh = make_serve_mesh(d, m)
    else:
        mesh = None if args.reduced else make_production_mesh()
    if cfg.is_encoder_decoder or cfg.frontend != "none":
        raise SystemExit("serve launcher drives decoder-only archs; "
                         "see examples/ for VLM / enc-dec handling")

    engine = args.engine or "continuous"
    if engine == "legacy" and (args.prefix_cache or args.stream
                               or args.spec_decode):
        raise SystemExit("--prefix-cache/--stream/--spec-decode are "
                         "continuous-engine features (the lockstep slab "
                         "has neither a page table to alias, a queue to "
                         "stream from, nor a fused loop to widen)")
    if engine == "legacy" and args.requests > args.batch:
        raise SystemExit(
            f"--requests {args.requests} > --batch {args.batch}: the "
            "legacy lockstep engine has no queue (all slots start and "
            "retire together); use the continuous engine or raise "
            "--batch")

    sampling = SamplingConfig(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    # decode-overshoot slack: one decode chunk of 8 normally; under
    # spec decode each of those steps may write a K-token verify chunk
    # (plus K rejected-draft positions) into allocated pages
    slack = 8 * args.spec_decode + args.spec_decode if args.spec_decode \
        else 8
    max_len = -(-(args.prompt_len + args.new_tokens + slack)
                // args.page_size) * args.page_size
    engine_kw = dict(engine=engine, batch_size=args.batch,
                     max_len=max_len, dtype=dtype, eos_id=args.eos_id,
                     sampling=sampling, seed=args.seed, mesh=mesh)
    if engine == "continuous":
        engine_kw["page_size"] = args.page_size
        engine_kw["prefix_cache"] = args.prefix_cache
        if args.spec_decode:
            engine_kw["spec_decode"] = args.spec_decode

    key = jax.random.PRNGKey(args.seed)
    # the activation mesh is SCOPED: nothing leaks into in-process
    # callers after this returns, and the mesh-free paths explicitly
    # run mesh-free even if a previous caller left one set (the
    # engines additionally scope the serve topology per compiled call)
    with activation_mesh(mesh):
        if args.restore:
            eng = make_engine_from_checkpoint(args.restore, cfg,
                                              step=args.step, **engine_kw)
            print(f"serving checkpoint step {eng.restored_step} "
                  f"from {args.restore}")
        else:
            eng = make_engine(cfg, init_model(cfg, key), **engine_kw)

        n_req = args.requests
        prompts = synthetic_tokens(key, n_req, args.prompt_len,
                                   cfg.vocab_size)
        t0 = time.time()
        if isinstance(eng, ContinuousScheduler):
            if args.stream:
                fd = FrontDoor(eng)
                handles = [fd.submit(p, args.new_tokens)
                           for p in np.asarray(prompts)]
                outs = []
                for i, h in enumerate(handles):
                    toks = list(h)     # pumps; tokens print as they sync
                    print(f"req {i} (ttft {h.ttft * 1e3:.0f}ms): {toks}")
                    outs.append(toks)
            else:
                outs = [o.tolist() for o in
                        eng.generate(list(np.asarray(prompts)),
                                     args.new_tokens)]
            dt = time.time() - t0
            n_tok = sum(len(o) for o in outs)
            st = eng.stats()
            extra = (f", prefix hit rate {st['prefix_hit_rate']:.0%}"
                     if args.prefix_cache else "")
            if args.spec_decode:
                sd = st["spec_decode"]
                extra += (f", spec k={sd['k']} acceptance "
                          f"{sd['acceptance']:.0%} "
                          f"({sd['tokens_per_step']:.2f} tok/verify)")
            print(f"{n_req} requests x {args.new_tokens} tokens in "
                  f"{dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile, "
                  f"{st['syncs_per_token']:.3f} host syncs/token, "
                  f"pool {st['pool_pages_in_use']} pages live, "
                  f"{st['pool_bytes_per_device']} pool bytes/device"
                  f"{extra})")
            if args.report:
                # dispatch discipline per phase: prefill = chunk
                # scatters with the first-token sample fused into the
                # last one (1 sync/request); decode = fused chunk loops
                # (1 sync/decode_chunk tokens)
                print(f"report: decode_kernel={cfg.decode_kernel} "
                      f"prefill {st['prefill_dispatches']} dispatches / "
                      f"{st['prefill_host_syncs']} host syncs "
                      f"({st['prefill_host_syncs'] / n_req:.2f} "
                      f"syncs/request), "
                      f"decode {st['decode_dispatches']} dispatches / "
                      f"{st['decode_host_syncs']} host syncs "
                      f"({st['decode_host_syncs'] / max(1, n_tok):.3f} "
                      f"syncs/token)")
        else:
            out = eng.generate(prompts[:args.batch], args.new_tokens)
            dt = time.time() - t0
            print(f"{args.batch} seqs x {args.new_tokens} tokens in "
                  f"{dt:.2f}s "
                  f"({args.batch*args.new_tokens/dt:.1f} tok/s incl. "
                  f"compile)")
            if args.report:
                # the lockstep slab has no phase split — one prefill
                # dispatch, then a blocking round-trip per token
                spt = eng.host_syncs / (args.batch * args.new_tokens)
                print(f"report: legacy {eng.dispatches} dispatches / "
                      f"{eng.host_syncs} host syncs "
                      f"({spt:.3f} syncs/token)")
            outs = np.asarray(out).tolist()
        print(outs)
    return outs


if __name__ == "__main__":
    main()
