"""Production meshes.

Single pod : (data=16, model=16)        = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16) = 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    import numpy as np
    dev = np.asarray(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_host_mesh(n_data: int | None = None):
    """Small mesh over whatever devices exist (tests, benchmarks)."""
    devices = jax.devices()
    n = n_data or len(devices)
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))


def make_serve_mesh(n_data: int, n_model: int):
    """A ``(data, model)`` serve mesh at an arbitrary scale — the shape
    the serving engines take via ``mesh=``.  "data" carries the DP
    replica groups (DCN side in production), "model" the model-sharded
    decode (ICI side); ``make_production_mesh()`` is the 16x16 instance
    of the same layout.  Tests build host-scale instances (e.g. 2x4
    under --xla_force_host_platform_device_count=8)."""
    need = n_data * n_model
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"serve mesh ({n_data}, {n_model}) needs {need} devices, "
            f"have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    import numpy as np
    dev = np.asarray(devices[:need]).reshape(n_data, n_model)
    return jax.sharding.Mesh(dev, ("data", "model"))
