import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each pair this lowers the REAL step function (train_step for
train_4k, prefill_step for prefill_32k, decode_step for decode shapes)
against ShapeDtypeStruct inputs carrying production NamedShardings, on
the 256-chip single-pod mesh and the 512-chip two-pod mesh, then:

  * compiled.memory_analysis()  — proves the pair fits per-chip HBM
  * compiled.cost_analysis()    — HLO FLOPs/bytes for §Roofline
  * HLO-text collective walk    — collective bytes per §Roofline

Results accumulate in benchmarks/results/dryrun_<mesh>.json so reruns
skip completed pairs (--force to redo).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single
"""
import argparse
import functools
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCHITECTURES, INPUT_SHAPES, LONG_500K_SKIPS,
                           config_for_shape)
from repro.data.specs import batch_struct, decode_struct
from repro.launch.mesh import make_production_mesh
from repro.models import init_model
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.sharding import (ShardingConfig, param_shardings, batch_shardings,
                            cache_shardings, dp_axes)
from repro.train.step import (TrainConfig, make_train_step,
                              opt_state_shardings)
from repro import optim as optim_lib

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results"


# --------------------------------------------------------------------------
# per-pair run configuration (memory-driven; see EXPERIMENTS.md §Dry-run)
# --------------------------------------------------------------------------

BASELINE = bool(os.environ.get("REPRO_BASELINE"))

# §Perf optimized settings (EXPERIMENTS.md); REPRO_BASELINE=1 restores the
# paper-faithful pre-hillclimb configuration for baseline measurement.
OPTIMIZED_CFG = {} if BASELINE else {
    "deepseek-coder-33b": {"pad_heads_to": 64},   # T1: 56->64 exact padding
    "qwen2.5-32b": {"pad_heads_to": 48},          # same fix (40->48)
    "deepseek-v3-671b": {"moe.capacity_factor": 1.0},   # T3 iter 2
}
OPTIMIZED_RUN = {} if BASELINE else {
    "jamba-v0.1-52b": {"microbatches": 8},        # T2: halve FSDP AG volume
}


def run_config(arch: str, shape_name: str) -> TrainConfig:
    big = arch in ("deepseek-coder-33b", "qwen2.5-32b", "granite-20b",
                   "jamba-v0.1-52b")
    if arch == "deepseek-v3-671b":
        # 671B on 256 v5e chips: bf16 end-to-end + SGD is the only fit
        tc = TrainConfig(optimizer="sgd", lr=1e-3, microbatches=16,
                         grad_dtype="bfloat16", param_dtype="bfloat16")
    elif big:
        tc = TrainConfig(optimizer="adamw", microbatches=16,
                         param_dtype="float32")
    else:
        tc = TrainConfig(optimizer="adamw", microbatches=4,
                         param_dtype="float32")
    over = OPTIMIZED_RUN.get(arch)
    if over:
        import dataclasses as _dc
        tc = _dc.replace(tc, **over)
    return tc


def _apply_cfg_overrides(arch: str, cfg):
    over = OPTIMIZED_CFG.get(arch)
    if not over:
        return cfg
    import dataclasses as _dc
    plain = {k: v for k, v in over.items() if not k.startswith("moe.")}
    moekw = {k[4:]: v for k, v in over.items() if k.startswith("moe.")}
    if plain:
        cfg = cfg.with_overrides(**plain)
    if moekw and cfg.moe is not None:
        cfg = cfg.with_overrides(moe=_dc.replace(cfg.moe, **moekw))
    return cfg


def _param_structs(cfg, tc, mesh, mode):
    key = jax.random.PRNGKey(0)
    pshape = jax.eval_shape(functools.partial(init_model, cfg), key)
    pdt = jnp.dtype(tc.param_dtype if mode == "train" else "bfloat16")
    pshape = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, pdt), pshape)
    sh = ShardingConfig.for_mode(mode)
    shardings = param_shardings(cfg, mesh, pshape, sh)
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        pshape, shardings), shardings


# --------------------------------------------------------------------------
# HLO collective accounting
# --------------------------------------------------------------------------

_SHAPE_ATOM = r"[a-z0-9]+\[[0-9,]*\](?:\{[0-9,:TSE()*]*\})?"
_SEP = r",\s*(?:/\*[^*]*\*/\s*)?"          # HLO prints /*index=N*/ comments
_COLL_RE = re.compile(
    r"=\s+(\(?" + _SHAPE_ATOM + r"(?:" + _SEP + _SHAPE_ATOM + r")*\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEAD_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Walk HLO computations; collectives inside while-bodies are
    multiplied by the loop trip count (recovered from the loop-condition
    comparison constant — our loops are all counted lax.scans).  Returns
    {kind: bytes} using the op OUTPUT shape as the moved-volume proxy."""
    comps = {}   # name -> {"coll": {...}, "calls": [(name, cond_or_None)]}
    consts = {}  # computation -> max s32 constant (loop-bound heuristic)
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        hm = _HEAD_RE.match(line)
        if hm and "->" in line:
            cur = hm.group(2)
            comps[cur] = {"coll": {}, "calls": []}
            if hm.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        for c in _CONST_RE.finditer(line):
            consts[cur] = max(consts.get(cur, 0), int(c.group(1)))
        cm = _COLL_RE.search(line)
        if cm:
            result_types, kind, is_start = cm.groups()
            if is_start and "-done" in line:
                continue
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(result_types):
                size = 1
                for d in dims.split(","):
                    if d:
                        size *= int(d)
                nbytes += size * _DTYPE_BYTES.get(dt, 4)
            comps[cur]["coll"][kind] = comps[cur]["coll"].get(kind, 0) + nbytes
        if " while(" in line or "= while(" in line or ") while(" in line:
            bm = _BODY_RE.search(line)
            cm2 = _COND_RE.search(line)
            if bm:
                comps[cur]["calls"].append(
                    (bm.group(1), cm2.group(1) if cm2 else None))
        for name in _CALL_RE.findall(line):
            comps[cur]["calls"].append((name, "ONE"))
        bm2 = _BRANCH_RE.search(line)
        if bm2:
            for name in bm2.group(1).split(","):
                comps[cur]["calls"].append((name.strip().lstrip("%"), "ONE"))

    @functools.lru_cache(maxsize=None)
    def total(name):
        node = comps.get(name)
        if node is None:
            return ()
        acc = dict(node["coll"])
        for child, cond in node["calls"]:
            trips = 1
            if cond not in (None, "ONE"):
                trips = max(1, consts.get(cond, 1))
            elif cond is None:
                trips = 1
            for kind, b in total(child):
                acc[kind] = acc.get(kind, 0) + trips * b
        return tuple(sorted(acc.items()))

    if entry is None and comps:
        entry = next(iter(comps))
    return dict(total(entry)) if entry else {}


# --------------------------------------------------------------------------
# lowering per mode
# --------------------------------------------------------------------------

def lower_pair(arch: str, shape_name: str, mesh):
    from repro.sharding.ctx import set_activation_mesh
    set_activation_mesh(mesh)
    shape = INPUT_SHAPES[shape_name]
    cfg = _apply_cfg_overrides(arch, config_for_shape(arch, shape_name))
    tc = run_config(arch, shape_name)
    mode = shape.mode

    if mode == "train":
        from repro.core.train_state import TrainState
        from repro.train.step import replicated_layout
        params, pshard = _param_structs(cfg, tc, mesh, "train")
        optimizer = optim_lib.get_optimizer(tc.optimizer, tc.lr)
        opt_shape = jax.eval_shape(optimizer.init, params)
        opt_sh = opt_state_shardings(optimizer, params, pshard, mesh)
        opt_state = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            opt_shape, opt_sh)
        batch = batch_struct(cfg, shape)
        bshard = batch_shardings(mesh, batch, shape.global_batch)
        batch = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            batch, bshard)
        state = TrainState(
            params, opt_state,
            jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=NamedSharding(mesh, P())),
            replicated_layout(params))
        step, _ = make_train_step(cfg, mesh, tc)
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
        return lowered, cfg, tc

    if mode == "prefill":
        params, _ = _param_structs(cfg, tc, mesh, "serve")
        batch = batch_struct(cfg, shape)
        bshard = batch_shardings(mesh, batch, shape.global_batch)
        batch = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            batch, bshard)
        from repro.models import init_cache
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               jnp.bfloat16, cross_len=shape.seq_len))
        csh = cache_shardings(cfg, mesh, cache_shape, shape.global_batch)
        cache = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            cache_shape, csh)
        stepf = make_prefill_step(cfg)
        with mesh:
            lowered = jax.jit(stepf, donate_argnums=(2,)).lower(
                params, batch, cache)
        return lowered, cfg, tc

    # decode
    params, _ = _param_structs(cfg, tc, mesh, "serve")
    ds = decode_struct(cfg, shape)
    csh = cache_shardings(cfg, mesh, ds["cache"], shape.global_batch)
    cache = jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        ds["cache"], csh)
    ax = dp_axes(mesh)
    tok_spec = P(ax if len(ax) > 1 else ax[0], None) \
        if shape.global_batch % (2 ** len(ax) * 8) == 0 else P(None, None)
    ntok = jax.ShapeDtypeStruct(
        ds["tokens"].shape, ds["tokens"].dtype,
        sharding=NamedSharding(mesh, tok_spec))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    stepf = make_decode_step(cfg)
    with mesh:
        lowered = jax.jit(stepf, donate_argnums=(2,)).lower(
            params, ntok, cache, pos)
    return lowered, cfg, tc


def _f32_upcast_bytes(hlo_text: str) -> int:
    """CPU-backend artifact estimate: the CPU emitter upcasts bf16 dot
    operands to f32 (verified: the lowered StableHLO has no such f32
    tensors).  On TPU these buffers would not exist.  Heuristic: sum of
    the largest f32 buffer per shape that also appears as a bf16 tensor
    in the module (one live copy per shape)."""
    shapes = {}
    for m in re.finditer(r"= \(?(f32|bf16)\[([0-9,]+)\]", hlo_text):
        dt, dims = m.groups()
        shapes.setdefault(dims, set()).add(dt)
    total = 0
    for dims, dts in shapes.items():
        if dts == {"f32", "bf16"}:
            size = 1
            for d in dims.split(","):
                size *= int(d)
            if size * 4 > 10 * 2 ** 20:      # only count >10MB buffers
                total += size * 4
    return total


def analyse(lowered, cfg):
    from repro.core.overlap import async_overlap_report
    from repro.roofline.hlocost import stablehlo_cost
    shcost = stablehlo_cost(lowered.as_text())
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    # which collectives a latency-hiding backend could split into
    # -start/-done pairs and bury behind concurrent work (the CPU
    # backend never asyncifies, so this is dataflow analysis, not grep)
    ovl = async_overlap_report(hlo, min_bytes=64 * 1024)
    entries = [e for comp in ovl["computations"].values() for e in comp]
    # per-pair window sizes feed roofline/analysis.py: the bytes of the
    # overlappable collectives are comm a latency-hiding schedule buries
    # behind compute, so the roofline subtracts them (capped by the
    # compute term) from the exposed collective time
    res = {
        "compile_s": round(compile_s, 1),
        "async_overlap": {"pairs": ovl["pairs"],
                          "collectives": ovl["collectives"],
                          "by_kind": ovl["by_kind"],
                          "report_bytes": int(sum(e["bytes"]
                                                  for e in entries)),
                          "overlappable_bytes": int(sum(
                              e["bytes"] for e in entries
                              if e["overlappable"])),
                          "windows": [[e["kind"], int(e["bytes"]),
                                       int(e["window_ops"])]
                                      for e in entries
                                      if e["overlappable"]][:128]},
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "flops_global": shcost["flops"],
        "dot_bytes_global": shcost["dot_bytes"],
        "unresolved_loops": shcost["unresolved_loops"],
        "collective_bytes": coll,
        "f32_upcast_bytes_est": _f32_upcast_bytes(hlo),
        "hlo_chars": len(hlo),
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            res[attr] = int(v)
    return res


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def pairs_for(arch=None, shape=None):
    archs = [arch] if arch else list(ARCHITECTURES)
    shapes = [shape] if shape else list(INPUT_SHAPES)
    out = []
    for a in archs:
        for s in shapes:
            if s == "long_500k" and a in LONG_500K_SKIPS:
                continue
            out.append((a, s))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--lower-only", action="store_true",
                    help="skip compile (fast sharding sanity check)")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"dryrun_{args.mesh}.json"
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    todo = pairs_for(args.arch, args.shape)
    for arch, shape in todo:
        keyname = f"{arch}|{shape}"
        if keyname in results and results[keyname].get("ok") \
                and not args.force:
            print(f"[skip] {keyname}")
            continue
        print(f"[dryrun:{args.mesh}] {keyname} ...", flush=True)
        t0 = time.time()
        try:
            lowered, cfg, tc = lower_pair(arch, shape, mesh)
            entry = {"ok": True, "lower_s": round(time.time() - t0, 1),
                     "params": cfg.param_count(),
                     "params_active": cfg.param_count(active_only=True),
                     "run_config": {"optimizer": tc.optimizer,
                                    "microbatches": tc.microbatches,
                                    "param_dtype": tc.param_dtype}}
            if INPUT_SHAPES[shape].mode == "train":
                from repro.core import (available_strategies, dp_world_size,
                                        get_strategy, perf_model)
                n_dp = dp_world_size(mesh)
                opt = optim_lib.get_optimizer(tc.optimizer, tc.lr)
                entry["dp_memory"] = {
                    k: round(v, 4) for k, v in perf_model.dp_memory_report(
                        cfg.param_count(), opt.state_factor, n_dp).items()}
                # per-strategy modeled step wire time, asked of each
                # registered strategy (zero1_hier shows the DCN saving
                # on the multi-pod mesh)
                shape_d = dict(mesh.shape)
                n_pods = int(shape_d.get("pod", 1))
                n_intra = int(shape_d.get("data", n_dp))
                entry["dp_comm_model_s"] = {
                    name: round(get_strategy(name).comm_time(
                        4.0 * cfg.param_count(), p=n_dp, n_intra=n_intra,
                        n_pods=n_pods, microbatches=tc.microbatches), 4)
                    for name in available_strategies()}
            if not args.lower_only:
                entry.update(analyse(lowered, cfg))
        except Exception as e:  # noqa: BLE001 — record failures, keep going
            entry = {"ok": False, "error": f"{type(e).__name__}: {e}",
                     "trace": traceback.format_exc()[-2000:]}
            print(entry["error"])
        results[keyname] = entry
        out_path.write_text(json.dumps(results, indent=1, sort_keys=True))
        print(f"[done] {keyname}: "
              f"{json.dumps({k: v for k, v in entry.items() if k != 'trace'})[:400]}",
              flush=True)


if __name__ == "__main__":
    main()
