"""Core building blocks: norms, MLPs, embeddings, RoPE.

Pure functional style: ``init_*`` returns a param pytree (fp32 master
weights), ``apply_*`` consumes it.  Compute happens in ``cfg.dtype``
(bf16 by default); params are cast at the point of use so fp32 masters
are preserved for the optimizer (TPU-native mixed precision — a
documented adaptation from the paper's fp32-on-CPU setup).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def dense_init(key, d_in, d_out, *, std=None, dtype=jnp.float32):
    std = std if std is not None else 1.0 / np.sqrt(d_in)
    return truncated_normal(key, (d_in, d_out), std, dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"]).astype(dt)


def rmsnorm_nop(x, eps=1e-6):
    """Scale-free rmsnorm (qk-norm without learned scale uses this form)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt)


def init_layernorm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"] + p["bias"]).astype(dt)


# --------------------------------------------------------------------------
# MLP (gated SwiGLU or plain 2-mat)
# --------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, gated=True):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff),
         "w_down": dense_init(ks[1], d_ff, d_model)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff)
    return p


def apply_mlp(p, x, gated=True):
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    if gated:
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"].astype(dt)


# --------------------------------------------------------------------------
# Embeddings
# --------------------------------------------------------------------------

def init_embed(key, vocab, d_model):
    return {"table": truncated_normal(key, (vocab, d_model), 0.02)}


def apply_embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def apply_unembed(p, x):
    # logits in fp32 for a numerically stable loss
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))            # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
