"""SSM-family mixer blocks: RWKV-6 (Finch) time/channel-mix and Mamba-1.

Both expose the same interface as attention:
    apply_*(cfg, p, x, mode=..., cache=...) -> (out, new_cache)
with O(1)-per-token recurrent state instead of a KV cache — this is what
makes long_500k decode native for the ssm/hybrid architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.layers import dense_init, truncated_normal, init_rmsnorm, rmsnorm


# ==========================================================================
# RWKV-6 (Finch) — data-dependent decay, token-shift LoRAs
# ==========================================================================

_MIX_NAMES = ("w", "k", "v", "r", "g")


def init_rwkv6(cfg, key):
    rc = cfg.rwkv
    d = cfg.d_model
    H = d // rc.head_dim
    K = rc.head_dim
    ks = jax.random.split(key, 16)
    p = {
        # token-shift ddlerp
        "mu_base": truncated_normal(ks[0], (d,), 0.02),
        "mu": truncated_normal(ks[1], (5, d), 0.02),
        "mix_A": truncated_normal(ks[2], (5, d, rc.mix_lora), 0.02),
        "mix_B": truncated_normal(ks[3], (5, rc.mix_lora, d), 0.02),
        # data-dependent decay (log-log space)
        "w_base": truncated_normal(ks[4], (d,), 0.02) - 6.0,
        "decay_A": truncated_normal(ks[5], (d, rc.decay_lora), 0.02),
        "decay_B": truncated_normal(ks[6], (rc.decay_lora, d), 0.02),
        "u": truncated_normal(ks[7], (H, K), 0.02),
        "wr": dense_init(ks[8], d, d),
        "wk": dense_init(ks[9], d, d),
        "wv": dense_init(ks[10], d, d),
        "wg": dense_init(ks[11], d, d),
        "wo": dense_init(ks[12], d, d),
        "ln_x": init_rmsnorm(K),        # per-head group norm on the output
        # channel mix
        "cm_mu_r": truncated_normal(ks[13], (d,), 0.02),
        "cm_mu_k": truncated_normal(ks[13], (d,), 0.02),
        "cm_wr": dense_init(ks[14], d, d),
        "cm_wk": dense_init(ks[14], d, cfg.d_ff),
        "cm_wv": dense_init(ks[15], cfg.d_ff, d),
    }
    return p


def make_rwkv6_cache(cfg, batch, dtype):
    rc = cfg.rwkv
    d = cfg.d_model
    H, K = d // rc.head_dim, rc.head_dim
    return {
        "state": jnp.zeros((batch, H, K, K), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), dtype),
        "shift_cm": jnp.zeros((batch, d), dtype),
    }


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,d) last token of the previous segment."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x, x_prev, dt):
    """Finch data-dependent token-shift: one mix per (w,k,v,r,g)."""
    xx = x_prev - x
    base = x + xx * p["mu_base"].astype(dt)                       # (B,S,d)
    t = jnp.tanh(jnp.einsum("bsd,ndr->bsnr", base, p["mix_A"].astype(dt)))
    lora = jnp.einsum("bsnr,nrd->bsnd", t, p["mix_B"].astype(dt))
    mixed = x[:, :, None, :] + xx[:, :, None, :] * (
        p["mu"].astype(dt)[None, None] + lora)
    return tuple(mixed[:, :, i] for i in range(5))      # each (B,S,d)


def apply_rwkv6_time_mix(cfg, p, x, *, mode="train", cache=None):
    rc = cfg.rwkv
    B, S, d = x.shape
    dt = x.dtype
    H, K = d // rc.head_dim, rc.head_dim

    prev = cache["shift_tm"].astype(dt) if cache is not None else jnp.zeros(
        (B, d), dt)
    x_prev = _token_shift(x, prev)
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev, dt)

    r = (xr @ p["wr"].astype(dt)).reshape(B, S, H, K)
    k = (xk @ p["wk"].astype(dt)).reshape(B, S, H, K)
    v = (xv @ p["wv"].astype(dt)).reshape(B, S, H, K)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    w_log = -jnp.exp(
        (p["w_base"].astype(jnp.float32)
         + (jnp.tanh(xw @ p["decay_A"].astype(dt))
            @ p["decay_B"].astype(dt)).astype(jnp.float32))
    ).reshape(B, S, H, K)

    state0 = (cache["state"] if cache is not None
              else jnp.zeros((B, H, K, K), jnp.float32))
    if mode == "decode" and S == 1:
        y, state = ops.wkv6_step(r[:, 0], k[:, 0], v[:, 0], w_log[:, 0],
                                 p["u"], state0)
        y = y[:, None]
    else:
        y, state = ops.wkv6(r, k, v, w_log, p["u"], state0)

    y = rmsnorm(p["ln_x"], y.astype(dt).reshape(B, S, H, K), cfg.norm_eps)
    y = y.reshape(B, S, d) * g
    out = y @ p["wo"].astype(dt)

    new_cache = None
    if cache is not None:
        new_cache = {"state": state, "shift_tm": x[:, -1, :],
                     "shift_cm": cache["shift_cm"]}
    return out, new_cache


def apply_rwkv6_channel_mix(cfg, p, x, *, cache=None):
    dt = x.dtype
    B = x.shape[0]
    prev = cache["shift_cm"].astype(dt) if cache is not None else jnp.zeros(
        (B, x.shape[-1]), dt)
    x_prev = _token_shift(x, prev)
    xx = x_prev - x
    xk = x + xx * p["cm_mu_k"].astype(dt)
    xr = x + xx * p["cm_mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["cm_wr"].astype(dt)) * (
        kk @ p["cm_wv"].astype(dt))
    new_cache = None
    if cache is not None:
        new_cache = dict(cache, shift_cm=x[:, -1, :])
    return out, new_cache


# ==========================================================================
# Mamba-1 (selective scan)
# ==========================================================================

def init_mamba(cfg, key):
    mc = cfg.mamba
    d = cfg.d_model
    dI = mc.expand * d
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 8)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1]
    dt_init = jnp.exp(jax.random.uniform(ks[0], (dI,))
                      * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt_init + jnp.log1p(-jnp.exp(-dt_init))   # inverse softplus
    return {
        # split x/z projections (instead of one fused 2*dI matrix) so the
        # d_inner output dim shards cleanly over the model axis
        "in_x": dense_init(ks[1], d, dI),
        "in_z": dense_init(ks[6], d, dI),
        "conv_w": truncated_normal(ks[2], (mc.d_conv, dI), 0.5 / np.sqrt(mc.d_conv)),
        "conv_b": jnp.zeros((dI,), jnp.float32),
        "x_proj": dense_init(ks[3], dI, dt_rank + 2 * mc.d_state),
        "dt_proj": dense_init(ks[4], dt_rank, dI, std=dt_rank ** -0.5),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (dI, mc.d_state))),
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": dense_init(ks[5], dI, d),
    }


def make_mamba_cache(cfg, batch, dtype):
    mc = cfg.mamba
    dI = mc.expand * cfg.d_model
    return {"ssm": jnp.zeros((batch, dI, mc.d_state), jnp.float32),
            "conv": jnp.zeros((batch, mc.d_conv - 1, dI), dtype)}


def _causal_conv(p, x, cache, mc):
    """Depthwise causal conv over time.  x: (B,S,dI)."""
    B, S, dI = x.shape
    dt = x.dtype
    prev = (cache["conv"].astype(dt) if cache is not None
            else jnp.zeros((B, mc.d_conv - 1, dI), dt))
    xp = jnp.concatenate([prev, x], axis=1)              # (B, S+dc-1, dI)
    w = p["conv_w"].astype(dt)                           # (dc, dI)
    out = sum(xp[:, i:i + S, :] * w[i] for i in range(mc.d_conv))
    out = out + p["conv_b"].astype(dt)
    new_conv = xp[:, -(mc.d_conv - 1):, :] if cache is not None else None
    return jax.nn.silu(out), new_conv


def apply_mamba(cfg, p, x, *, mode="train", cache=None):
    mc = cfg.mamba
    B, S, d = x.shape
    dt_ = x.dtype
    dI = mc.expand * d
    dt_rank = p["dt_proj"].shape[0]

    xs = x @ p["in_x"].astype(dt_)
    z = x @ p["in_z"].astype(dt_)
    xs, new_conv = _causal_conv(p, xs, cache, mc)

    proj = xs @ p["x_proj"].astype(dt_)
    dt_low = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + mc.d_state]
    Cm = proj[..., dt_rank + mc.d_state:]
    dt_full = jax.nn.softplus(
        dt_low @ p["dt_proj"].astype(dt_) + p["dt_bias"].astype(dt_))
    A = -jnp.exp(p["A_log"])

    state0 = (cache["ssm"] if cache is not None
              else jnp.zeros((B, dI, mc.d_state), jnp.float32))
    if mode == "decode" and S == 1:
        y, state = ops.mamba_step(xs[:, 0], dt_full[:, 0], A, Bm[:, 0],
                                  Cm[:, 0], p["D"], state0)
        y = y[:, None]
    else:
        y, state = ops.mamba_scan(xs, dt_full, A, Bm, Cm, p["D"], state0)

    y = y.astype(dt_) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_)

    new_cache = None
    if cache is not None:
        new_cache = {"ssm": state, "conv": new_conv}
    return out, new_cache
