"""Attention: MHA/GQA (+qk-norm, qkv-bias, sliding window), MLA, cross-attn.

Memory discipline: full (S, S) score materialisation at 32k+ sequence
lengths does not fit HBM, so prefill/train attention is computed in
query chunks via ``lax.scan`` (flash-attention memory behaviour at the
XLA level; the Pallas kernel in ``repro.kernels.flash_attention`` is the
TPU-optimised version of the same loop).  Decode attends a single query
against the KV cache.

KV caches are dicts of arrays with a leading-batch layout
``(B, S_max, kv_heads, head_dim)`` (MLA: latent ``(B, S_max, r)``).
``cache_pos`` is the number of tokens already in the cache.

Paged serving cache: attention K/V can instead live in a shared *page
pool* with a token-major layout ``(num_pages * page_size, kv_heads,
head_dim)`` (MLA: ``(N, r)``) and no batch axis at all.  A per-slot
page table (``PagedView``) maps each slot's logical token positions to
physical pool slots, so decode reads/writes go through gather/scatter
and every slot only occupies the pages it was allocated —
``repro.serve.kvcache`` owns allocation; this module owns the read
path.  ``cache_pos`` is then a per-slot ``(B,)`` vector, which is what
continuous batching needs (slots at different depths in one step).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, rmsnorm_nop, apply_rope, init_rmsnorm, rmsnorm
from repro.sharding.ctx import (constrain_paged_kv, constrain_paged_latent,
                                replicate_update)

NEG_INF = -1e30


class PagedView(NamedTuple):
    """How a (decode-mode) model call should read a paged KV cache.

    page_table — (B, table_width) int32: physical page id of each
                 slot's logical block (0 = the reserved trash page,
                 used both for never-allocated blocks and as the write
                 sink of idle slots, whose table rows are all zero).
    page_size  — tokens per page; static under jit (close over it).
    """
    page_table: Any
    page_size: int


# --------------------------------------------------------------------------
# chunked softmax attention core
# --------------------------------------------------------------------------

def _grouped_scores(qc, k):
    # qc: (B, hk, g, Cq, hd)  k: (B, T, hk, hd) -> (B, hk, g, Cq, T)
    return jnp.einsum("bkgqd,btkd->bkgqt", qc, k,
                      preferred_element_type=jnp.float32)


def _grouped_out(probs, v):
    # probs: (B, hk, g, Cq, T)  v: (B, T, hk, hd) -> (B, hk, g, Cq, hd)
    return jnp.einsum("bkgqt,btkd->bkgqd", probs.astype(v.dtype), v)


def chunked_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                      window=0, kv_valid_len=None, chunk=1024):
    """q: (B,S,h,hd); k,v: (B,T,hk,hd).  Returns (B,S,h,hd).

    q_positions: (S,) global positions of queries.
    kv_positions: (T,) global positions of keys.
    kv_valid_len: scalar — keys at kv_positions >= this are masked
        (used at decode where the cache tail is unwritten).
    """
    B, S, h, hd = q.shape
    T, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / np.sqrt(hd)

    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad), constant_values=-1)
    nc = q.shape[1] // chunk

    qg = q.reshape(B, nc, chunk, hk, g, hd).transpose(1, 0, 3, 4, 2, 5)
    qp = q_positions.reshape(nc, chunk)

    def step(_, inp):
        qc, qpos = inp                                   # (B,hk,g,Cq,hd), (Cq,)
        s = _grouped_scores(qc, k) * scale               # (B,hk,g,Cq,T) fp32
        m = jnp.ones((chunk, T), bool)
        if causal:
            m &= kv_positions[None, :] <= qpos[:, None]
        if window:
            m &= kv_positions[None, :] > qpos[:, None] - window
        if kv_valid_len is not None:
            m &= kv_positions[None, :] < kv_valid_len
        m &= qpos[:, None] >= 0                          # query padding
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return None, _grouped_out(p, v)                  # (B,hk,g,Cq,hd)

    # checkpoint each q-chunk: bwd recomputes the (Cq, T) score/prob
    # tiles instead of saving them for every chunk — flash-attention
    # memory behaviour under autodiff
    _, out = jax.lax.scan(jax.checkpoint(step), None, (qg, qp))
    hd_v = v.shape[-1]                                   # may differ (MLA)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nc * chunk, h, hd_v)
    return out[:, :S]


def masked_attention(q, k, v, *, q_positions, kv_positions, window=0):
    """Per-slot-position attention core for the paged serve path.

    q: (B, S, h, hd); k, v: (B, T, hk, hd); q_positions: (B, S) global
    positions per slot; kv_positions: (T,) logical cache positions.
    Key t is visible to query (b, s) iff ``kv_positions[t] <=
    q_positions[b, s]`` (within the sliding window when set) — the
    causal mask alone covers cache validity, since every position <=
    the query's has been written by this slot.  Single q-chunk (the
    same einsums, shapes and masking value as one ``chunked_attention``
    step, so greedy decode is bitwise-identical to the slab path): S is
    a decode token or a prefill chunk here, never a 32k sequence.
    """
    B, S, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, S, hk, g, hd).transpose(0, 2, 3, 1, 4)
    s = _grouped_scores(qg, k) * scale               # (B,hk,g,S,T) fp32
    m = kv_positions[None, None, :] <= q_positions[:, :, None]   # (B,S,T)
    if window:
        m &= kv_positions[None, None, :] > q_positions[:, :, None] - window
    m &= q_positions[:, :, None] >= 0                # query padding
    s = jnp.where(m[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = _grouped_out(p, v)                         # (B,hk,g,S,hd_v)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, h, v.shape[-1])


# --------------------------------------------------------------------------
# paged-pool addressing (repro.serve.kvcache allocates; this reads/writes)
# --------------------------------------------------------------------------

def paged_write_indices(paged: PagedView, positions):
    """(B, S) logical positions -> (B, S) physical pool-token indices.
    Out-of-range / negative positions map to the trash page (page 0),
    so padded prefill lanes and idle slots scatter harmlessly."""
    table = paged.page_table
    bs = paged.page_size
    width = table.shape[1]
    pos = jnp.clip(positions, 0, width * bs - 1)
    phys = jnp.take_along_axis(table, pos // bs, axis=1) * bs + pos % bs
    valid = (positions >= 0) & (positions < width * bs)
    return jnp.where(valid, phys, 0)


def paged_read(pool_leaf, paged: PagedView):
    """Gather a slot-major view (B, L, ...) out of a token-major pool
    (N, ...), L = table_width * page_size.  Unallocated blocks gather
    the trash page and are masked by the causal/validity mask.  The
    gather is PAGE-granular — whole contiguous pages, table_width rows
    per slot — not per-token: on CPU/XLA a token-granular gather
    scalarises and eats the fused-loop dispatch win."""
    table = paged.page_table
    bs = paged.page_size
    B, width = table.shape
    pages = pool_leaf.reshape((pool_leaf.shape[0] // bs, bs)
                              + pool_leaf.shape[1:])
    full = pages[table]                       # (B, width, bs, ...)
    return (full.reshape((B, width * bs) + pool_leaf.shape[1:]),
            jnp.arange(width * bs))


def _paged_append(pool_leaf, paged: PagedView, positions, new):
    """Scatter S new per-slot entries (B, S, ...) into the pool."""
    idx = paged_write_indices(paged, positions)
    flat = new.reshape((-1,) + new.shape[2:]).astype(pool_leaf.dtype)
    return pool_leaf.at[idx.reshape(-1)].set(flat)


def _pos2d(positions):
    """Normalise positions to (B, S) for rope / per-slot masking."""
    return positions if positions.ndim == 2 else positions[None]


# --------------------------------------------------------------------------
# GQA attention layer
# --------------------------------------------------------------------------

def _padded_heads(cfg):
    """(h_padded, real_head_mask or None).  Padding layout: each kv head's
    group is padded at the END (q head j of kv head i sits at i*g_new+j),
    so GQA grouping stays aligned and the padded slots are exact zeros."""
    h, hk = cfg.num_heads, cfg.num_kv_heads
    if not cfg.pad_heads_to or cfg.pad_heads_to == h:
        return h, None
    hp = cfg.pad_heads_to
    assert hp % hk == 0 and hp > h
    g_old, g_new = h // hk, hp // hk
    mask = np.zeros((hp,), np.float32)
    for i in range(hk):
        mask[i * g_new:i * g_new + g_old] = 1.0
    return hp, mask


def init_attention(cfg, key, *, cross=False):
    d, hk, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    h, mask = _padded_heads(cfg)
    ks = jax.random.split(key, 6)
    wq = dense_init(ks[0], d, h * hd).reshape(d, h, hd)
    wo = dense_init(ks[3], h * hd, d).reshape(h, hd, d)
    if mask is not None:
        wq = wq * mask[None, :, None]
        wo = wo * mask[:, None, None]
    p = {
        "wq": wq,
        "wk": dense_init(ks[1], d, hk * hd).reshape(d, hk, hd),
        "wv": dense_init(ks[2], d, hk * hd).reshape(d, hk, hd),
        "wo": wo,
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((hk, hd), jnp.float32)
        p["bv"] = jnp.zeros((hk, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def make_cache(cfg, batch, max_len, dtype, *, pool=None):
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    if pool is not None:
        num_pages, page_size = pool
        n = num_pages * page_size
        return {"k": jnp.zeros((n, hk, hd), dtype),
                "v": jnp.zeros((n, hk, hd), dtype)}
    return {"k": jnp.zeros((batch, max_len, hk, hd), dtype),
            "v": jnp.zeros((batch, max_len, hk, hd), dtype)}


def apply_attention(cfg, p, x, *, positions, mode="train", cache=None,
                    cache_pos=None, kv_src=None, causal=True, rope=None,
                    paged=None):
    """Self- or cross-attention.

    mode: 'train' (no cache), 'prefill' (fill + return cache),
          'decode' (read/update cache, x is (B,1,d)).
    kv_src: encoder output for cross-attention ('train'/'prefill' only;
          decode reads the cross cache without touching kv_src).
    rope: apply rotary embeddings; defaults to `causal` (self-attention
          yes, cross-attention no; bidirectional encoders pass rope=True).
    paged: PagedView — decode-mode only: `cache` is a token-major page
          pool, `positions` is per-slot (B, S), reads/writes go through
          the page table.
    """
    dt = x.dtype
    B = x.shape[0]
    window = cfg.swa_window
    rope = causal if rope is None else rope

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)

    if paged is not None:
        if mode != "decode" or not causal:
            raise ValueError("paged KV cache is decode-mode "
                             "self-attention only")
        pos2 = _pos2d(positions)
        src = kv_src if kv_src is not None else x
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dt))
        if "bk" in p:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        if cfg.qk_norm:
            k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
        if rope:
            k = apply_rope(k, pos2, cfg.rope_theta)
            q = apply_rope(q, pos2, cfg.rope_theta)
        # pin the UPDATE replicated before the scatter: rope's
        # split/concat along a model-sharded head_dim otherwise leaves
        # GSPMD free to partition the scatter update in a way that
        # miscombines the halves inside the layer scan (observed on the
        # CPU SPMD partitioner); host mesh: no-op
        k = replicate_update(k)
        v = replicate_update(v)
        k_pool = _paged_append(cache["k"], paged, pos2, k)
        v_pool = _paged_append(cache["v"], paged, pos2, v)
        if cfg.decode_kernel == "pallas":
            # fused path: the page-table gather never materialises —
            # the kernel's BlockSpec index map streams pages from the
            # pool into the online-softmax loop
            from repro.kernels.paged_decode import paged_flash_decode
            out = paged_flash_decode(
                q, k_pool.astype(dt), v_pool.astype(dt),
                paged.page_table, pos2, page_size=paged.page_size,
                window=window)
        else:
            # spec-aware read: keep the pool's "model" sharding (heads
            # or head_dim) pinned through the page-table gather under a
            # serve topology — a no-op on the host mesh
            k_full, kv_positions = paged_read(k_pool, paged)
            v_full, _ = paged_read(v_pool, paged)
            k_full = constrain_paged_kv(k_full)
            v_full = constrain_paged_kv(v_full)
            out = masked_attention(q, k_full.astype(dt), v_full.astype(dt),
                                   q_positions=pos2,
                                   kv_positions=kv_positions,
                                   window=window)
        _, head_mask = _padded_heads(cfg)
        if head_mask is not None:
            out = out * jnp.asarray(head_mask, dt)[None, None, :, None]
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
        return out, {"k": k_pool, "v": v_pool}

    if mode == "decode" and kv_src is None and not causal:
        # cross-attention decode: cache holds the full encoder K/V
        k, v = cache["k"], cache["v"]
        new_cache = cache
        kv_positions = jnp.arange(k.shape[1])
        kv_valid = None
    else:
        src = kv_src if kv_src is not None else x
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dt))
        if "bk" in p:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        if cfg.qk_norm:
            k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
        if rope:
            k = apply_rope(k, positions[None], cfg.rope_theta)

        if mode == "train":
            new_cache = None
            kv_positions = positions
            kv_valid = None
        elif mode == "prefill":
            new_cache = {"k": k, "v": v} if cache is None else {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1)}
            kv_positions = positions
            kv_valid = None
        else:  # decode self-attention: append to cache, attend over prefix
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
            new_cache = {"k": k_cache, "v": v_cache}
            k, v = k_cache.astype(dt), v_cache.astype(dt)
            kv_positions = jnp.arange(k.shape[1])
            kv_valid = cache_pos + x.shape[1]

    if rope:
        q = apply_rope(q, positions[None], cfg.rope_theta)

    out = chunked_attention(
        q, k, v, q_positions=positions, kv_positions=kv_positions,
        causal=causal, window=window if causal else 0, kv_valid_len=kv_valid)
    _, head_mask = _padded_heads(cfg)
    if head_mask is not None:
        # zero the padded heads BEFORE wo so their (garbage) attention
        # outputs contribute neither to the output nor to wo's gradient
        out = out * jnp.asarray(head_mask, dt)[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# --------------------------------------------------------------------------

def init_mla(cfg, key):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank),
        "q_norm": init_rmsnorm(m.q_lora_rank),
        "w_uq": dense_init(ks[1], m.q_lora_rank, H * qk_hd).reshape(
            m.q_lora_rank, H, qk_hd),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim),
        "kv_norm": init_rmsnorm(m.kv_lora_rank),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim
                           ).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim
                           ).reshape(m.kv_lora_rank, H, m.v_head_dim),
        "wo": dense_init(ks[5], H * m.v_head_dim, d).reshape(
            H, m.v_head_dim, d),
    }


def make_mla_cache(cfg, batch, max_len, dtype, *, pool=None):
    m = cfg.mla
    if pool is not None:
        num_pages, page_size = pool
        n = num_pages * page_size
        return {"ckv": jnp.zeros((n, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((n, m.qk_rope_head_dim), dtype)}
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


def _mla_qkv(cfg, p, x, positions):
    m = cfg.mla
    dt = x.dtype
    pos2 = _pos2d(positions)
    ql = rmsnorm(p["q_norm"], x @ p["w_dq"].astype(dt), cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["w_uq"].astype(dt))
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], pos2, cfg.rope_theta)
    dkv = x @ p["w_dkv"].astype(dt)
    ckv = rmsnorm(p["kv_norm"], dkv[..., :m.kv_lora_rank], cfg.norm_eps)
    krope = apply_rope(dkv[..., m.kv_lora_rank:][:, :, None, :],
                       pos2, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, krope


def apply_mla(cfg, p, x, *, positions, mode="train", cache=None,
              cache_pos=None, paged=None):
    m = cfg.mla
    dt = x.dtype
    B, S = x.shape[:2]
    q_nope, q_rope, ckv, krope = _mla_qkv(cfg, p, x, positions)

    if paged is not None:
        if mode != "decode":
            raise ValueError("paged MLA cache is decode-mode only")
        # absorbed decode against the paged latent pool; per-query
        # causal masking (the slab path masks per chunk-end instead)
        pos2 = _pos2d(positions)
        # same update-pinning as the GQA path: rope splits the rope-dim
        # and rmsnorm reduces over the latent — both along axes the pool
        # shards over "model"
        ckv = replicate_update(ckv)
        krope = replicate_update(krope)
        ckv_pool = _paged_append(cache["ckv"], paged, pos2, ckv)
        krope_pool = _paged_append(cache["krope"], paged, pos2, krope)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"].astype(dt))
        scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        if cfg.decode_kernel == "pallas":
            from repro.kernels.paged_decode import paged_flash_decode_mla
            out_lat = paged_flash_decode_mla(
                q_lat, q_rope, ckv_pool.astype(dt),
                krope_pool.astype(dt), paged.page_table, pos2,
                page_size=paged.page_size, scale=scale,
                window=cfg.swa_window)
        else:
            ckv_c, kv_positions = paged_read(ckv_pool, paged)
            krope_c, _ = paged_read(krope_pool, paged)
            ckv_c = constrain_paged_latent(ckv_c)
            krope_c = constrain_paged_latent(krope_c)
            ckv_c, krope_c = ckv_c.astype(dt), krope_c.astype(dt)
            scores = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_c,
                                 preferred_element_type=jnp.float32)
                      + jnp.einsum("bshk,btk->bhst", q_rope, krope_c,
                                   preferred_element_type=jnp.float32))
            scores = scores * scale
            mask = kv_positions[None, None, :] <= pos2[:, :, None]
            if cfg.swa_window:
                mask &= kv_positions[None, None, :] > pos2[:, :, None] \
                    - cfg.swa_window
            scores = jnp.where(mask[:, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(dt), ckv_c)
        out = jnp.einsum("bshr,rhv->bshv", out_lat, p["w_uv"].astype(dt))
        out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))
        return out, {"ckv": ckv_pool, "krope": krope_pool}

    if mode in ("train", "prefill"):
        # expand latent to per-head K/V; chunked attention as usual
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"].astype(dt))
        v = jnp.einsum("bsr,rhv->bshv", ckv, p["w_uv"].astype(dt))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                      k_nope.shape[:3] + (m.qk_rope_head_dim,))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(q, k, v, q_positions=positions,
                                kv_positions=positions, causal=True,
                                window=cfg.swa_window)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1),
                "krope": jax.lax.dynamic_update_slice_in_dim(
                    cache["krope"], krope.astype(cache["krope"].dtype), 0,
                    axis=1)}
    else:
        # absorbed decode: score/attend in the 512-dim latent space
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_pos, axis=1)
        krope_c = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope.astype(cache["krope"].dtype), cache_pos,
            axis=1)
        new_cache = {"ckv": ckv_c, "krope": krope_c}
        T = ckv_c.shape[1]
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"].astype(dt))
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_c.astype(dt),
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshk,btk->bhst", q_rope, krope_c.astype(dt),
                               preferred_element_type=jnp.float32))
        scores = scores / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        kv_positions = jnp.arange(T)
        # per-query causal: a multi-token decode chunk (chunked prefill)
        # must not let token s see tokens s+1.. of its own chunk
        qpos = _pos2d(positions)[0]                      # (S,)
        mask = kv_positions[None, :] <= qpos[:, None]
        if cfg.swa_window:
            mask = mask & (kv_positions[None, :] > qpos[:, None]
                           - cfg.swa_window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(dt),
                             ckv_c.astype(dt))
        out = jnp.einsum("bshr,rhv->bshv", out_lat, p["w_uv"].astype(dt))

    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))
    return out, new_cache


def mla_scale_note(cfg):
    """Prefill scaling uses sqrt(nope+rope) inside chunked_attention via
    head_dim of the concatenated q — consistent with decode."""
    return cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
