"""Fine-grained mixture-of-experts (DeepSeekMoE / Jamba style).

Two dispatch implementations, numerically identical:

* ``apply_moe`` (no mesh): capacity-based scatter/gather in plain jnp —
  the reference path for CPU tests and small models.

* ``apply_moe`` (mesh registered): explicit expert-parallel shard_map.
  GSPMD cannot partition data-dependent scatter/gather across a sharded
  expert axis (it replicates — measured 98 GB/device on the 671B
  config), so the production path makes the communication explicit, the
  way TPU MoE systems actually run:

    - each data-shard routes its local tokens and packs them into a
      local (E, C_loc, d) buffer (dense local scatter);
    - if experts are sharded over "data" (256-expert configs), a
      ``lax.all_to_all`` over the data axis exchanges expert rows —
      THE MoE collective the roofline measures;
    - each model-rank slices its own expert rows (activations are
      replicated over "model", so no collective is needed there);
    - expert FFNs run as dense batched matmuls on local shards;
    - the combine retraces the path and finishes with a psum over
      "model" (which merges with the layer's tensor-parallel reduce).

Router: softmax over experts, top-k, renormalised weights, plus the
Switch-style load-balance auxiliary loss (coefficient in MoEConfig).
Shared experts (DeepSeek) run densely on every token outside shard_map.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, shard_map_kwargs

from repro.models.layers import dense_init, init_mlp, apply_mlp
from repro.sharding import ctx as shctx
from repro.sharding.ctx import constrain_ecd, constrain_tokens

CAPACITY_FACTOR = 1.25


def init_moe(cfg, key):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], d, m.num_experts, std=0.02)}
    if getattr(m, "router_type", "softmax") == "sigmoid":
        # V3 aux-free balancing bias: used for SELECTION only, excluded
        # from gradients (updated by the trainer from load statistics)
        p["router_bias"] = jnp.zeros((m.num_experts,), jnp.float32)
    # routed experts: stacked (E, ...) for batched einsum
    ke = jax.random.split(ks[1], 3)
    mats = {"w_up": dense_init(ke[0], d, m.num_experts * m.d_expert
                               ).reshape(d, m.num_experts, m.d_expert
                                         ).transpose(1, 0, 2),
            "w_down": dense_init(ke[1], m.d_expert,
                                 m.num_experts * d
                                 ).reshape(m.d_expert, m.num_experts, d
                                           ).transpose(1, 0, 2)}
    if cfg.mlp_gated:
        mats["w_gate"] = dense_init(ke[2], d, m.num_experts * m.d_expert
                                    ).reshape(d, m.num_experts, m.d_expert
                                              ).transpose(1, 0, 2)
    p["experts"] = mats
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[2], d, m.num_shared_experts * m.d_expert,
                               gated=cfg.mlp_gated)
    return p


def _routing(cfg, p, xf):
    """xf: (N, d) -> (top-k weights (N,k), top-k idx (N,k), aux loss)."""
    m = cfg.moe
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if getattr(m, "router_type", "softmax") == "sigmoid":
        # DeepSeek-V3: sigmoid affinity; SELECT by score + balance bias
        # (bias carries no gradient and no weight), weight by the
        # bias-free scores renormalised over the selection.
        scores = jax.nn.sigmoid(logits)                        # (N, E)
        bias = jax.lax.stop_gradient(p["router_bias"])
        _, top_idx = jax.lax.top_k(scores + bias[None, :], m.top_k)
        top_w = jnp.take_along_axis(scores, top_idx, axis=1)
        top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-20)
        probs = scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)                # (N, E)
        top_w, top_idx = jax.lax.top_k(probs, m.top_k)         # (N, k)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch aux loss: E * sum_e f_e * P_e (kept tiny for sigmoid mode —
    # V3 relies on the bias, the aux term is a sequence-level backstop)
    one_hot = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32)
    f = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)             # fraction routed
    P = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(f * P) * m.router_aux_coef
    return top_w, top_idx, aux


def update_router_bias(cfg, p, counts, *, gamma=1e-3):
    """V3 aux-free balancing: bias += gamma (underloaded experts),
    -= gamma (overloaded).  counts: (E,) tokens routed per expert this
    step (host-side trainer utility, outside the gradient path).

    The update accumulates in fp32 regardless of the bias/count dtypes:
    a bf16 bias near +/-8 cannot resolve a 1e-3 step (ulp there is
    0.0625), so low-precision accumulation silently freezes the
    balancing long before the bias saturates; integer counts would
    also truncate the mean."""
    bias = p["router_bias"]
    counts = jnp.asarray(counts, jnp.float32)
    mean = jnp.mean(counts)
    step = jnp.float32(gamma) * jnp.sign(mean - counts)
    return (bias.astype(jnp.float32) + step).astype(bias.dtype)


def apply_moe(cfg, p, x, *, capacity_factor=None):
    """x: (B, S, d) -> (y, aux_loss).  Dispatches on mesh presence."""
    if capacity_factor is None:
        capacity_factor = getattr(cfg.moe, "capacity_factor",
                                  CAPACITY_FACTOR)
    if shctx.get_activation_mesh() is not None:
        return apply_moe_ep(cfg, p, x, capacity_factor=capacity_factor)
    return apply_moe_dense(cfg, p, x, capacity_factor=capacity_factor)


def apply_moe_dense(cfg, p, x, *, capacity_factor=CAPACITY_FACTOR):
    """Reference scatter/gather path (single device / tests)."""
    m = cfg.moe
    B, S, d = x.shape
    dt = x.dtype
    N = B * S
    xf = x.reshape(N, d)

    top_w, top_idx, aux = _routing(cfg, p, xf)
    k = m.top_k
    E = m.num_experts
    C = max(1, int(capacity_factor * N * k / E))
    # round capacity to a multiple of 8 lanes-friendly size
    C = min(N, -(-C // 8) * 8)

    # position of each (token, slot) within its expert
    flat_e = top_idx.reshape(N * k)                             # (N*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (N*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                        # running count
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < C
    flat_w = top_w.reshape(N * k) * keep

    # scatter tokens into (E, C, d)
    tok_idx = jnp.repeat(jnp.arange(N), k)
    buf = jnp.zeros((E, C, d), dt)
    safe_pos = jnp.where(keep, flat_pos, 0)
    upd = constrain_tokens(xf[tok_idx] * keep[:, None].astype(dt))
    buf = buf.at[flat_e, safe_pos].add(upd, mode="drop")
    buf = constrain_ecd(buf)       # expert-parallel layout (the all-to-all)

    # per-expert dense FFN: (E, C, d) x (E, d, f)
    up = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_up"].astype(dt))
    if cfg.mlp_gated:
        gate = jnp.einsum("ecd,edf->ecf", buf,
                          p["experts"]["w_gate"].astype(dt))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = constrain_ecd(h)
    out_buf = constrain_ecd(
        jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_down"].astype(dt)))

    # gather back with router weights; (N*k) slots are token-major so a
    # reshape-sum over the k slot axis recombines them
    y = constrain_tokens(out_buf[flat_e, safe_pos]
                         * flat_w[:, None].astype(dt))
    y = y.reshape(N, k, d).sum(axis=1).reshape(B, S, d)

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"], x, gated=cfg.mlp_gated)
    return y, aux


# ==========================================================================
# expert-parallel shard_map path (production mesh)
# ==========================================================================

def _ep_factors(cfg, mesh):
    """How the expert axis maps onto the mesh.

    Returns (ep_data, ep_model): E is sharded over `model` when E % model
    == 0, and additionally over `data` when E % (model*data) == 0 (the
    256-expert configs).  Otherwise experts stay model-sharded and their
    FFN dim is tensor-parallel over `data` (Megatron expert-TP)."""
    E = cfg.moe.num_experts
    msz = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    dsz = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    ep_model = msz if (msz > 1 and E % msz == 0) else 1
    ep_data = dsz if (ep_model == msz and dsz > 1
                      and (E // ep_model) % dsz == 0) else 1
    return ep_data, ep_model


def _route_local(cfg, p, xf, capacity_factor):
    """Local routing: xf (N, d) -> (flat_e, safe_pos, keep, flat_w, aux, C).
    Pure-local (no collectives)."""
    m = cfg.moe
    N = xf.shape[0]
    k, E = m.top_k, m.num_experts
    top_w, top_idx, aux = _routing(cfg, p, xf)
    C = max(1, int(capacity_factor * N * k / E))
    C = min(max(N, 8), -(-C // 8) * 8)

    flat_e = top_idx.reshape(N * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < C
    flat_w = top_w.reshape(N * k) * keep
    safe_pos = jnp.where(keep, flat_pos, 0)
    return flat_e, safe_pos, keep, flat_w, aux, C


def _pack(xf, flat_e, safe_pos, keep, C, rows, *, start=0, k=1):
    """Scatter tokens into expert rows [start, start+rows).  One scatter
    per top-k slot so the (N*k, d) token-copy tensor is never
    materialised (measured 13 GB/device at 262k tokens otherwise).
    Returns (buf (rows, C, d), sel mask over the N*k slots)."""
    N, d = xf.shape
    dt = xf.dtype
    sel = keep & (flat_e >= start) & (flat_e < start + rows)
    le = jnp.where(sel, flat_e - start, 0).reshape(N, k)
    pos = safe_pos.reshape(N, k)
    selk = sel.reshape(N, k)
    buf = jnp.zeros((rows, C, d), dt)
    for j in range(k):
        buf = buf.at[le[:, j], pos[:, j]].add(
            xf * selk[:, j][:, None].astype(dt))
    return buf, sel


def _combine(out, flat_e, safe_pos, flat_w, sel, k, dt, *, start=0,
             local_rows=None):
    """Gather expert outputs back per top-k slot and weight-sum them.
    out: (rows, C, d) local expert outputs.  ``local_rows`` overrides the
    expert-id -> local-row mapping (default: flat_e - start)."""
    N = flat_e.shape[0] // k
    rows = local_rows if local_rows is not None else flat_e - start
    le = jnp.where(sel, rows, 0).reshape(N, k)
    pos = safe_pos.reshape(N, k)
    w = (flat_w * sel).reshape(N, k)
    y = None
    for j in range(k):
        yj = out[le[:, j], pos[:, j]] * w[:, j][:, None].astype(dt)
        y = yj if y is None else y + yj
    return y


def _expert_ffn(cfg, experts, buf, mi=None, f_slice=None):
    """Dense batched FFN over a local expert buffer."""
    dt = buf.dtype
    w_up = experts["w_up"].astype(dt)
    w_down = experts["w_down"].astype(dt)
    w_gate = experts.get("w_gate")
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    if w_gate is not None:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   w_gate.astype(dt))) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def apply_moe_ep(cfg, p, x, *, capacity_factor=CAPACITY_FACTOR):
    """Expert-parallel MoE under the registered mesh (see module doc)."""
    mesh = shctx.get_activation_mesh()
    m = cfg.moe
    B, S, d = x.shape
    dt = x.dtype
    ep_data, ep_model = _ep_factors(cfg, mesh)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsz = axis_sizes.get("data", 1)
    msz = axis_sizes.get("model", 1)
    has_pod = "pod" in mesh.axis_names
    dp_ax = ("pod", "data") if has_pod else ("data",)
    batch_sharded = B % (dsz * (axis_sizes.get("pod", 1))) == 0

    bspec = P(dp_ax if len(dp_ax) > 1 else dp_ax[0], None, None) \
        if batch_sharded else P(None, None, None)
    # router + experts enter with their parameter shardings
    from repro.sharding.rules import param_spec, ShardingConfig
    sh = ShardingConfig()
    especs = {kk: param_spec(cfg, mesh, f"ffn/experts/{kk}", vv, sh)
              for kk, vv in p["experts"].items()}
    rspec = P(None, None)

    # is the expert FFN dim tensor-parallel over 'data'? (expert-TP mode)
    f_tp = (ep_data == 1 and dsz > 1 and m.d_expert % dsz == 0
            and msz > 1 and m.num_experts % msz == 0)

    E = m.num_experts
    k = m.top_k
    has_bias = "router_bias" in p

    def body(xb, router, bias, experts):
        rp = ({"router": router, "router_bias": bias}
              if bias is not None else {"router": router})
        xf = xb.reshape(-1, d)
        if ep_data > 1 and batch_sharded:
            # ---- full expert-parallel: local pack + all-to-all('data')
            flat_e, safe_pos, keep, flat_w, aux, C = _route_local(
                cfg, rp, xf, capacity_factor)
            buf, _ = _pack(xf, flat_e, safe_pos, keep, C, E, k=k)
            buf = jax.lax.all_to_all(buf, "data", split_axis=0,
                                     concat_axis=1, tiled=True)
            mi = jax.lax.axis_index("model")
            e_loc = E // (ep_data * ep_model)
            buf = jax.lax.dynamic_slice_in_dim(buf, mi * e_loc, e_loc, 0)
            out = _expert_ffn(cfg, experts, buf)
            if os.environ.get("REPRO_BASELINE"):
                # pre-§Perf: pad back to all model ranks' rows and a2a
                # ((ep_model-1)/ep_model of the reverse wire is zeros)
                out_full = jnp.zeros((E // ep_data, C * ep_data, d), dt)
                out_full = jax.lax.dynamic_update_slice_in_dim(
                    out_full, out, mi * e_loc, 0)
                out_back = jax.lax.all_to_all(
                    out_full, "data", split_axis=1, concat_axis=0,
                    tiled=True)
                y = _combine(out_back, flat_e, safe_pos, flat_w, keep, k,
                             dt)
            else:
                # §Perf target 3: reverse a2a on the model-local slice
                # ONLY (16x wire saving on the reverse path).
                # out: (e_loc, C*ep_data, d) -> (ep_data*e_loc, C, d),
                # row di*e_loc+r = expert (di*ep_model+mi)*e_loc+r of MY
                # tokens.
                out_back = jax.lax.all_to_all(
                    out, "data", split_axis=1, concat_axis=0, tiled=True)
                mi_of_e = (flat_e // e_loc) % ep_model
                row = ((flat_e // (e_loc * ep_model)) * e_loc
                       + flat_e % e_loc)
                sel = keep & (mi_of_e == mi)
                y = _combine(out_back, flat_e, safe_pos, flat_w, sel, k,
                             dt, local_rows=row)
            y = jax.lax.psum(y, "model")   # sum expert shards over model
            aux = jax.lax.pmean(aux, dp_ax)
        elif batch_sharded and ep_data == 1 and f_tp:
            # ---- expert-FSDP: E over 'model', weight f-shards FSDP'd
            # over 'data'.  Tokens never move: each rank all-gathers the
            # (small) weight shards and processes its local tokens with
            # its local experts.  Gradients reduce-scatter automatically
            # (transpose of all_gather).
            ew = {
                "w_up": jax.lax.all_gather(experts["w_up"], "data",
                                           axis=2, tiled=True),
                "w_down": jax.lax.all_gather(experts["w_down"], "data",
                                             axis=1, tiled=True)}
            if "w_gate" in experts:
                ew["w_gate"] = jax.lax.all_gather(experts["w_gate"], "data",
                                                  axis=2, tiled=True)
            flat_e, safe_pos, keep, flat_w, aux, C = _route_local(
                cfg, rp, xf, capacity_factor)
            e_loc = E // ep_model
            mi = jax.lax.axis_index("model")
            start = mi * e_loc
            buf_loc, sel = _pack(xf, flat_e, safe_pos, keep, C, e_loc,
                                 start=start, k=k)
            out = _expert_ffn(cfg, ew, buf_loc)
            y = _combine(out, flat_e, safe_pos, flat_w, sel, k, dt,
                         start=start)
            y = jax.lax.psum(y, "model")
            aux = jax.lax.pmean(aux, dp_ax)
        else:
            # ---- replicated-token fallback (unshardable batch, e.g.
            # long_500k B=1): every rank routes all tokens, computes its
            # local expert shard, partial sums reduce over sharded axes.
            flat_e, safe_pos, keep, flat_w, aux, C = _route_local(
                cfg, rp, xf, capacity_factor)
            e_loc = E // (ep_data * ep_model)
            mi = jax.lax.axis_index("model")
            start = mi * e_loc
            if ep_data > 1:
                di = jax.lax.axis_index("data")
                start = (di * ep_model + mi) * e_loc
            buf_loc, sel = _pack(xf, flat_e, safe_pos, keep, C, e_loc,
                                 start=start, k=k)
            if f_tp:
                ew = {kk: jax.lax.all_gather(
                    vv, "data", axis=(1 if kk == "w_down" else 2),
                    tiled=True) for kk, vv in experts.items()}
            else:
                ew = experts
            out = _expert_ffn(cfg, ew, buf_loc)
            y = _combine(out, flat_e, safe_pos, flat_w, sel, k, dt,
                         start=start)
            red = ("model", "data") if ep_data > 1 else ("model",)
            y = jax.lax.psum(y, red)
            if has_pod:
                aux = jax.lax.pmean(aux, "pod")
        return y.reshape(xb.shape), aux

    wrapped = shard_map(
        body, mesh=mesh,
        in_specs=(bspec, rspec, P(None) if has_bias else None, especs),
        out_specs=(bspec, P()),
        **shard_map_kwargs(check_vma=False))
    y, aux = wrapped(x, p["router"], p.get("router_bias"), p["experts"])

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"], x, gated=cfg.mlp_gated)
    return y, aux
