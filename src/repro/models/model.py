"""Top-level models.

* ``init_model`` / ``apply_model`` — the assigned large architectures
  (decoder-only, encoder-decoder, VLM with stubbed frontends).
* ``init_paper_net`` / ``apply_paper_net`` — the paper's Table-1 DNNs and
  CNNs (5x5 conv / ReLU / 2x2 max-pool / sigmoid FC / softmax out).

``apply_model(cfg, params, batch, mode=..., cache=..., cache_pos=...)``
returns ``{"logits", "cache", "aux", "mtp_logits"?}``.

Batch formats:
  decoder-only : {"tokens": (B,S)}
  vlm          : {"tokens": (B,S_text), "vision_embeds": (B,N_img,D_vis)}
  audio encdec : {"src_embeds": (B,S_src,d_model), "tgt_tokens": (B,S_tgt)}
  decode       : {"tokens": (B,1)} + cache/cache_pos
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import (
    init_embed, apply_embed, init_rmsnorm, rmsnorm, dense_init,
    truncated_normal)
from repro.sharding.ctx import constrain_bsd, constrain_logits

VISION_EMBED_DIM = 1024      # CLIP ViT-L/14-336 output width (stubbed)


def _encoder_cfg(cfg):
    return cfg.with_overrides(num_layers=cfg.encoder_layers,
                              is_encoder_decoder=False,
                              attn_layer_period=1, ssm_kind="none",
                              moe=None)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_model(cfg, key):
    ks = jax.random.split(key, 8)
    params = {"embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model)}
    if cfg.frontend == "vision":
        params["vision_proj"] = {
            "w1": dense_init(ks[1], VISION_EMBED_DIM, cfg.d_model),
            "b1": jnp.zeros((cfg.d_model,), jnp.float32),
            "w2": dense_init(ks[2], cfg.d_model, cfg.d_model),
            "b2": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    if cfg.is_encoder_decoder:
        params["encoder"] = tfm.init_stack(_encoder_cfg(cfg), ks[3])
        params["enc_norm"] = init_rmsnorm(cfg.d_model)
    params["decoder"] = tfm.init_stack(cfg, ks[4],
                                       cross=cfg.is_encoder_decoder)
    params["final_norm"] = init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"] = {"table": truncated_normal(
            ks[5], (cfg.vocab_size, cfg.d_model), 0.02)}
    if cfg.mtp_depth > 0:
        dense_ff = cfg.moe.dense_d_ff if cfg.moe else cfg.d_ff
        params["mtp"] = {
            "norm_h": init_rmsnorm(cfg.d_model),
            "norm_e": init_rmsnorm(cfg.d_model),
            "proj": dense_init(ks[6], 2 * cfg.d_model, cfg.d_model),
            "block": tfm.init_layer(cfg, ks[7], ("attn", "mlp"),
                                    dense_ff=dense_ff),
        }
    return params


def init_cache(cfg, batch, max_len, dtype, *, cross_len=0, pool=None):
    """pool=(num_pages, page_size): build the *paged* serving cache —
    attention/MLA K/V live in shared token-major page pools sized by
    the pool, not by batch×max_len; per-slot recurrent SSM states keep
    the (batch,) axis.  Decode then reads through a
    ``serve.kvcache``-managed page table (``apply_model(paged=...)``)."""
    return tfm.init_stack_cache(cfg, batch, max_len, dtype,
                                cross=cfg.is_encoder_decoder,
                                cross_len=cross_len, pool=pool)


# --------------------------------------------------------------------------
# apply
# --------------------------------------------------------------------------

def _logits(cfg, params, x):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["unembed"]["table"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    return constrain_logits(logits)


def _vision_proj(params, v, dt):
    p = params["vision_proj"]
    h = v.astype(dt) @ p["w1"].astype(dt) + p["b1"].astype(dt)
    return jax.nn.gelu(h) @ p["w2"].astype(dt) + p["b2"].astype(dt)


def apply_model(cfg, params, batch, *, mode="train", cache=None,
                cache_pos=None, remat=False, last_only=False, paged=None):
    dt = jnp.dtype(cfg.dtype)
    aux = jnp.zeros((), jnp.float32)
    if paged is not None and mode != "decode":
        raise ValueError("paged KV cache reads are decode-mode only "
                         "(chunked prefill runs as decode)")

    # ---------- encoder (audio frontend stub feeds src_embeds) ----------
    enc_out = None
    if cfg.is_encoder_decoder and "src_embeds" in batch:
        src = batch["src_embeds"].astype(dt)
        pos_e = jnp.arange(src.shape[1])
        enc_cfg = _encoder_cfg(cfg)
        enc, _, a = tfm.apply_stack(enc_cfg, params["encoder"], src,
                                    positions=pos_e, mode="train",
                                    causal=False, remat=remat)
        enc_out = rmsnorm(params["enc_norm"], enc, cfg.norm_eps)
        aux = aux + a

    # ---------- decoder input sequence ----------
    tokens = batch.get("tgt_tokens", batch.get("tokens"))
    x = apply_embed(params["embed"], tokens, dt)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        vis = _vision_proj(params, batch["vision_embeds"], dt)
        x = jnp.concatenate([vis, x], axis=1)
    x = constrain_bsd(x)

    S = x.shape[1]
    if mode == "decode":
        # scalar cache_pos: all slots at the same depth (lockstep slab
        # path, positions (S,)); per-slot (B,) vector: continuous
        # batching, positions (B, S) — paged reads only
        cache_pos = jnp.asarray(cache_pos)
        if cache_pos.ndim == 1:
            if paged is None:
                raise ValueError("per-slot cache_pos needs a paged cache "
                                 "(pass paged=PagedView(...))")
            positions = cache_pos[:, None] + jnp.arange(S)[None]
        else:
            positions = cache_pos + jnp.arange(S)
    else:
        positions = jnp.arange(S)

    x, new_cache, a = tfm.apply_stack(
        cfg, params["decoder"], x, positions=positions, mode=mode,
        cache=cache, cache_pos=cache_pos, enc_out=enc_out, causal=True,
        remat=remat, paged=paged)
    aux = aux + a

    if last_only:
        # serving: only the last position's logits are needed — slice
        # before the unembed matmul (saves S x the logits compute and
        # the (B, S, V) fp32 buffer)
        x = x[:, -1:]
    out = {"logits": _logits(cfg, params, x), "cache": new_cache,
           "aux": aux, "hidden": x}

    # ---------- multi-token prediction head (train only) ----------
    if cfg.mtp_depth > 0 and mode == "train":
        p = params["mtp"]
        # combine hidden at position i with embedding of token i+1
        h = rmsnorm(p["norm_h"], x[:, :-1], cfg.norm_eps)
        e = rmsnorm(p["norm_e"],
                    apply_embed(params["embed"], tokens[:, 1:], dt),
                    cfg.norm_eps)
        hm = jnp.concatenate([h, e], axis=-1) @ p["proj"].astype(dt)
        pos_m = jnp.arange(hm.shape[1])
        hm, _, _ = tfm.apply_layer(cfg, ("attn", "mlp"), p["block"], hm,
                                   positions=pos_m, mode="train")
        out["mtp_logits"] = _logits(cfg, params, hm)
    return out


# --------------------------------------------------------------------------
# MTP drafting (speculative decode)
# --------------------------------------------------------------------------

def _mtp_self_attention(cfg, p, x, dt):
    """The MTP block's attention for a *window-1* (self-only) query.

    Decode-mode drafting feeds the block one position at a time, and the
    only key that position can see is itself: the softmax over a single
    key is identically 1, so the attention output IS the value at the
    query's own position — the q/k projections, qk-norm and RoPE all
    cancel exactly.  That reduction lets the draft head run with no KV
    pool, no page table and no positions, for both GQA and MLA layers.
    Draft quality only moves the acceptance rate; the verify forward
    keeps greedy outputs lossless regardless.
    """
    from repro.models.attention import _padded_heads  # local: avoid cycle
    if cfg.attention == "mla":
        m = cfg.mla
        dkv = x @ p["w_dkv"].astype(dt)
        ckv = rmsnorm(p["kv_norm"], dkv[..., :m.kv_lora_rank], cfg.norm_eps)
        out = jnp.einsum("bsr,rhv->bshv", ckv, p["w_uv"].astype(dt))
        return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))
    hp, head_mask = _padded_heads(cfg)
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bv" in p:
        v = v + p["bv"].astype(dt)
    B, S = v.shape[:2]
    out = jnp.broadcast_to(v[:, :, :, None, :],
                           (B, S, hk, hp // hk, hd)).reshape(B, S, hp, hd)
    if head_mask is not None:
        out = out * jnp.asarray(head_mask, dt)[None, None, :, None]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def _mtp_block(cfg, p, x, dt):
    """norm1 → self-only attention → residual → norm2 → mlp → residual —
    the decode-mode twin of the train-mode ``tfm.apply_layer`` call on
    ``params["mtp"]["block"]`` (which is always an ("attn","mlp") layer,
    dense FFN even for MoE trunks)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    x = x + _mtp_self_attention(cfg, p["mixer"], h, dt)
    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    from repro.models.layers import apply_mlp  # local: avoid re-export churn
    return x + apply_mlp(p["ffn"], h, gated=cfg.mlp_gated)


def mtp_draft(cfg, params, hidden, tokens, k):
    """Greedy-draft ``k`` future tokens from the trunk's last hidden state.

    EAGLE-style chained depth-1 drafting with the DeepSeek MTP head:
    each step combines the current hidden (``norm_h``) with the
    embedding of the newest token (``norm_e``), projects the concat back
    to ``d_model``, runs the MTP transformer block (window-1 attention —
    see :func:`_mtp_self_attention`), reads a greedy token off the
    shared unembedding, and feeds the block's output hidden + the new
    draft's embedding back in for the next step.  This mirrors the
    train-mode head exactly at chain depth 1: hidden at position ``i``
    plus token ``i+1`` predicts token ``i+2``.

    hidden : (B, 1, d) trunk hidden at the last accepted position
             (``apply_model(...)["hidden"]``, pre-final-norm).
    tokens : (B, 1) int32 — the newest committed/accepted token.
    Returns (draft_tokens (B, k) int32, last_hidden (B, 1, d)).
    """
    if cfg.mtp_depth <= 0:
        raise ValueError("mtp_draft needs cfg.mtp_depth > 0 (no MTP head "
                         "in this architecture)")
    dt = jnp.dtype(cfg.dtype)
    p = params["mtp"]
    h, t = hidden.astype(dt), tokens
    drafts = []
    for _ in range(k):
        e = apply_embed(params["embed"], t, dt)
        hm = jnp.concatenate(
            [rmsnorm(p["norm_h"], h, cfg.norm_eps),
             rmsnorm(p["norm_e"], e, cfg.norm_eps)],
            axis=-1) @ p["proj"].astype(dt)
        hm = _mtp_block(cfg, p["block"], hm, dt)
        t = jnp.argmax(_logits(cfg, params, hm), axis=-1).astype(jnp.int32)
        h = hm
        drafts.append(t[:, 0])
    return jnp.stack(drafts, axis=1), h


# ==========================================================================
# Paper Table-1 networks
# ==========================================================================

def init_paper_net(net, key):
    ks = jax.random.split(key, 16)
    if net.kind == "dnn":
        params = {"layers": []}
        for i, (din, dout) in enumerate(
                zip(net.layer_sizes[:-1], net.layer_sizes[1:])):
            params["layers"].append({
                "w": dense_init(ks[i], din, dout),
                "b": jnp.zeros((dout,), jnp.float32)})
        return params
    # CNN: 5x5 convs + 2x2 pools, then sigmoid FC, then softmax out
    params = {"conv": [], "fc": []}
    cin = net.image_channels
    h, w = net.image_hw
    for i, cout in enumerate(net.conv_channels):
        params["conv"].append({
            "w": truncated_normal(ks[i], (5, 5, cin, cout),
                                  (2.0 / (25 * cin)) ** 0.5),
            "b": jnp.zeros((cout,), jnp.float32)})
        cin = cout
        h, w = h // 2, w // 2        # 2x2 max-pool after each conv
    flat = h * w * cin
    params["fc"].append({"w": dense_init(ks[8], flat, net.fc_size),
                         "b": jnp.zeros((net.fc_size,), jnp.float32)})
    params["fc"].append({"w": dense_init(ks[9], net.fc_size, net.num_classes),
                         "b": jnp.zeros((net.num_classes,), jnp.float32)})
    return params


def apply_paper_net(net, params, x):
    """x: (B, features) for DNN; (B, H, W, C) for CNN.  Returns logits."""
    if net.kind == "dnn":
        h = x
        for i, layer in enumerate(params["layers"]):
            h = h @ layer["w"] + layer["b"]
            if i < len(params["layers"]) - 1:
                h = jax.nn.sigmoid(h)
        return h
    h = x
    for layer in params["conv"]:
        h = jax.lax.conv_general_dilated(
            h, layer["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + layer["b"]
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.sigmoid(h @ params["fc"][0]["w"] + params["fc"][0]["b"])
    return h @ params["fc"][1]["w"] + params["fc"][1]["b"]
