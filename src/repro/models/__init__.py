from repro.models.model import (
    init_model, apply_model, init_cache, mtp_draft,
    init_paper_net, apply_paper_net,
)

__all__ = ["init_model", "apply_model", "init_cache", "mtp_draft",
           "init_paper_net", "apply_paper_net"]
