"""Decoder stack assembly: heterogeneous layers, scan-over-superblocks.

``cfg.block_structure()`` splits the depth into an unrolled prefix (e.g.
DeepSeek's leading dense-FFN layers) plus a repeating super-block (e.g.
Jamba's 8-layer mamba/attn/MoE period).  The super-block is applied with
``jax.lax.scan`` over stacked params so the lowered HLO contains ONE
copy of the block body regardless of depth — this keeps 62-layer models
SPMD-partitionable in reasonable compile time and is also what makes
activation rematerialisation per-block natural.

Caches are pytrees mirroring the param structure:
  attn  -> {"k","v"}            (B, S_max, hk, hd)
  mla   -> {"ckv","krope"}      (B, S_max, r)
  mamba -> {"ssm","conv"}       (B, dI, dS) / (B, dc-1, dI)
  rwkv6 -> {"state","shift_tm","shift_cm"}
stacked with a leading (n_repeats,) axis for the scanned blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    init_rmsnorm, rmsnorm, init_mlp, apply_mlp, dense_init)
from repro.sharding.ctx import constrain_bsd


# --------------------------------------------------------------------------
# single layer
# --------------------------------------------------------------------------

def init_layer(cfg, key, spec, *, dense_ff=None, cross=False):
    mixer, ffn = spec
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {"norm1": init_rmsnorm(d)}
    if mixer == "attn":
        p["mixer"] = (attn_lib.init_mla(cfg, ks[0])
                      if cfg.attention == "mla"
                      else attn_lib.init_attention(cfg, ks[0]))
    elif mixer == "mamba":
        p["mixer"] = ssm_lib.init_mamba(cfg, ks[0])
    elif mixer == "rwkv6":
        # rwkv block: norm1+time-mix, norm2+channel-mix (its own "ffn")
        p["mixer"] = ssm_lib.init_rwkv6(cfg, ks[0])
        p["norm2"] = init_rmsnorm(d)
        return p
    if cross:
        p["norm_cross"] = init_rmsnorm(d)
        p["cross"] = attn_lib.init_attention(cfg, ks[1], cross=True)
    p["norm2"] = init_rmsnorm(d)
    if ffn == "moe":
        p["ffn"] = moe_lib.init_moe(cfg, ks[2])
    else:
        ff = dense_ff or cfg.d_ff
        p["ffn"] = init_mlp(ks[2], d, ff, gated=cfg.mlp_gated)
    return p


def init_layer_cache(cfg, spec, batch, max_len, dtype, *, cross=False,
                     cross_len=0, pool=None):
    """pool=(num_pages, page_size): attention/MLA caches become shared
    token-major page pools (no batch axis — serve.kvcache allocates
    pages to slots); recurrent SSM state stays per-slot (O(1) in
    context, nothing to page)."""
    mixer, _ = spec
    if mixer == "attn":
        c = (attn_lib.make_mla_cache(cfg, batch, max_len, dtype, pool=pool)
             if cfg.attention == "mla"
             else attn_lib.make_cache(cfg, batch, max_len, dtype, pool=pool))
    elif mixer == "mamba":
        c = ssm_lib.make_mamba_cache(cfg, batch, dtype)
    elif mixer == "rwkv6":
        c = ssm_lib.make_rwkv6_cache(cfg, batch, dtype)
    else:
        raise ValueError(mixer)
    if cross:
        if pool is not None:
            raise ValueError("paged cache does not support cross-attention")
        c = {"self": c,
             "cross": attn_lib.make_cache(cfg, batch, cross_len, dtype)}
    return c


def apply_layer(cfg, spec, p, x, *, positions, mode, cache=None,
                cache_pos=None, enc_out=None, causal=True, paged=None):
    """Returns (x, new_cache, aux_loss)."""
    mixer, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    self_cache = cache["self"] if (cache is not None and "self" in cache) else cache

    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        if cfg.attention == "mla":
            h, new_self = attn_lib.apply_mla(
                cfg, p["mixer"], h, positions=positions, mode=mode,
                cache=self_cache, cache_pos=cache_pos, paged=paged)
        else:
            h, new_self = attn_lib.apply_attention(
                cfg, p["mixer"], h, positions=positions, mode=mode,
                cache=self_cache, cache_pos=cache_pos, causal=causal,
                rope=True, paged=paged)
    elif mixer == "mamba":
        h, new_self = ssm_lib.apply_mamba(cfg, p["mixer"], h, mode=mode,
                                          cache=self_cache)
    elif mixer == "rwkv6":
        h, new_self = ssm_lib.apply_rwkv6_time_mix(
            cfg, p["mixer"], h, mode=mode, cache=self_cache)
        x = x + h
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        h2, new_self = ssm_lib.apply_rwkv6_channel_mix(
            cfg, p["mixer"], h2,
            cache=new_self)
        x = x + h2
        return x, new_self, aux
    x = x + h

    new_cache = new_self
    if "cross" in p:
        hc = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        cross_cache = cache["cross"] if cache is not None else None
        hc, new_cross = attn_lib.apply_attention(
            cfg, p["cross"], hc, positions=positions,
            mode=("decode" if mode == "decode" else mode),
            cache=cross_cache, cache_pos=cache_pos, kv_src=enc_out,
            causal=False)
        x = x + hc
        if cache is not None:
            new_cache = {"self": new_self, "cross": new_cross}

    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if ffn == "moe":
        h, aux = moe_lib.apply_moe(cfg, p["ffn"], h)
    else:
        h = apply_mlp(p["ffn"], h, gated=cfg.mlp_gated)
    x = x + h
    return x, new_cache, aux


# --------------------------------------------------------------------------
# stack (prefix + scanned super-blocks)
# --------------------------------------------------------------------------

def init_stack(cfg, key, *, cross=False):
    prefix, pattern, n_rep = cfg.block_structure()
    kp, kb = jax.random.split(key)
    params = {}
    dense_ff = cfg.moe.dense_d_ff if cfg.moe is not None else None
    params["prefix"] = {
        f"layer{i}": init_layer(cfg, k, spec, dense_ff=dense_ff, cross=cross)
        for i, (spec, k) in enumerate(
            zip(prefix, jax.random.split(kp, max(1, len(prefix)))))
    } if prefix else {}

    def init_block(k):
        ks = jax.random.split(k, len(pattern))
        return {f"layer{i}": init_layer(cfg, ks[i], spec, cross=cross)
                for i, spec in enumerate(pattern)}

    params["blocks"] = jax.vmap(init_block)(
        jax.random.split(kb, n_rep))
    return params


def init_stack_cache(cfg, batch, max_len, dtype, *, cross=False,
                     cross_len=0, pool=None):
    prefix, pattern, n_rep = cfg.block_structure()
    mk = functools.partial(init_layer_cache, cfg, batch=batch,
                           max_len=max_len, dtype=dtype, cross=cross,
                           cross_len=cross_len, pool=pool)
    cache = {"prefix": {f"layer{i}": mk(spec)
                        for i, spec in enumerate(prefix)} if prefix else {}}

    def one_block():
        return {f"layer{i}": mk(spec) for i, spec in enumerate(pattern)}

    cache["blocks"] = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape).copy()
        if n_rep else x, one_block())
    return cache


def apply_stack(cfg, params, x, *, positions, mode, cache=None,
                cache_pos=None, enc_out=None, causal=True, remat=False,
                paged=None):
    """Returns (x, new_cache, aux)."""
    prefix, pattern, n_rep = cfg.block_structure()
    aux = jnp.zeros((), jnp.float32)
    new_cache = {"prefix": {}, "blocks": None}
    has_cache = cache is not None

    for i, spec in enumerate(prefix):
        c = cache["prefix"][f"layer{i}"] if has_cache else None
        x, nc, a = apply_layer(cfg, spec, params["prefix"][f"layer{i}"], x,
                               positions=positions, mode=mode, cache=c,
                               cache_pos=cache_pos, enc_out=enc_out,
                               causal=causal, paged=paged)
        aux = aux + a
        if has_cache:
            new_cache["prefix"][f"layer{i}"] = nc

    def one_layer(spec):
        def f(p, h, c):
            h = constrain_bsd(h)   # pin batch->data on the residual stream
            return apply_layer(cfg, spec, p, h, positions=positions,
                               mode=mode, cache=c, cache_pos=cache_pos,
                               enc_out=enc_out, causal=causal, paged=paged)
        # per-LAYER remat: bwd peak = one layer's residuals (the mamba /
        # wkv chunk-scan trajectories are the big ones), not a block's
        return jax.checkpoint(f) if remat else f

    layer_fns = [one_layer(spec) for spec in pattern]

    def block_body(carry, xs):
        h, aux_acc = carry
        p_blk = xs[0] if has_cache else xs
        c_blk = xs[1] if has_cache else None
        nc_blk = {}
        for j, spec in enumerate(pattern):
            c = c_blk[f"layer{j}"] if has_cache else None
            h, nc, a = layer_fns[j](p_blk[f"layer{j}"], h, c)
            aux_acc = aux_acc + a
            nc_blk[f"layer{j}"] = nc
        return (h, aux_acc), (nc_blk if has_cache else None)

    xs = (params["blocks"], cache["blocks"]) if has_cache else params["blocks"]
    (x, aux), blk_caches = jax.lax.scan(block_body, (x, aux), xs)
    if has_cache:
        new_cache["blocks"] = blk_caches
    return x, (new_cache if has_cache else None), aux
