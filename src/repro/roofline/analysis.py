"""Roofline analysis (deliverable g).

Reads the dry-run JSON (per-device post-SPMD numbers: XLA's cost
analysis and the HLO collective walk are both over the per-partition
module) and derives, per (arch × shape):

    compute term    = HLO_FLOPs_per_dev / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_dev / HBM_bw_per_chip
    collective term = collective_bytes_per_dev / ICI_link_bw

plus MODEL_FLOPS = 6·N·D (train; 2·N·D prefill/decode, N = active
params) and the usefulness ratio MODEL_FLOPS_per_dev / HLO_FLOPs_per_dev
(catches remat/redundancy/dispatch waste).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.configs import ARCHITECTURES, INPUT_SHAPES, config_for_shape


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    chips: int = 256


V5E = HW()


def model_flops(arch: str, shape_name: str) -> float:
    """Global model FLOPs per step: 6·N_active·tokens (train),
    2·N_active·tokens (prefill/decode)."""
    cfg = config_for_shape(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    n_act = cfg.param_count(active_only=True)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    tokens = shape.global_batch               # ONE token per sequence
    return 2.0 * n_act * tokens


def roofline_terms(entry: dict, hw: HW = V5E) -> dict:
    """entry: one dry-run JSON record.

    FLOPs/bytes come from the StableHLO walker (global, trip-count
    correct — ``flops_global`` / ``dot_bytes_global``) divided by chip
    count; collective bytes come from the compiled per-partition HLO
    walk (already per-device).

    The dry-run's ``async_overlap`` report (per-pair window sizes from
    ``repro.core.overlap.async_overlap_report``) says which fraction of
    the collective bytes has concurrent compute to hide behind; that
    hidden-comm time is subtracted from the collective term — capped by
    the compute term, since communication can only hide behind compute
    that actually exists.  ``t_collective`` is the *exposed* time the
    roofline charges; the raw and hidden components are reported
    alongside.  Old dry-run records without the window data degrade to
    hidden = 0 (raw == exposed)."""
    coll = sum(entry.get("collective_bytes", {}).values())
    flops_dev = entry.get("flops_global", entry.get("flops", 0) * hw.chips) \
        / hw.chips
    bytes_dev = entry.get("dot_bytes_global",
                          entry.get("bytes_accessed", 0) * hw.chips) \
        / hw.chips
    t_compute = flops_dev / hw.peak_flops
    t_coll_raw = coll / hw.ici_bw
    ovl = entry.get("async_overlap", {})
    report_bytes = ovl.get("report_bytes", 0)
    hidden_frac = (ovl.get("overlappable_bytes", 0) / report_bytes
                   if report_bytes else 0.0)
    t_hidden = min(hidden_frac * t_coll_raw, t_compute)
    return {
        "t_compute": t_compute,
        "t_memory": bytes_dev / hw.hbm_bw,
        "t_collective": t_coll_raw - t_hidden,
        "t_collective_raw": t_coll_raw,
        "t_collective_hidden": t_hidden,
    }


def analyse_pair(arch: str, shape_name: str, entry: dict,
                 hw: HW = V5E) -> dict:
    terms = roofline_terms(entry, hw)
    roof = {k: terms[k] for k in ("t_compute", "t_memory", "t_collective")}
    dom = max(roof, key=roof.get)
    mf = model_flops(arch, shape_name) / hw.chips      # per device
    hlo_flops_dev = terms["t_compute"] * hw.peak_flops
    ratio = mf / hlo_flops_dev if hlo_flops_dev else float("nan")
    bound = {"t_compute": "compute", "t_memory": "memory",
             "t_collective": "collective"}[dom]
    step_time = max(roof.values())
    mfu = mf / hw.peak_flops / step_time if step_time else 0.0
    return {
        "arch": arch, "shape": shape_name, **terms,
        "dominant": bound,
        "model_flops_per_dev": mf,
        "useful_ratio": ratio,
        "roofline_mfu": mfu,   # model-flops utilisation at the roofline bound
    }


_SUGGEST = {
    ("compute",): "reduce redundant HLO compute (remat policy, fused "
                  "attention kernel, avoid upcast recompute)",
    ("memory",): "improve arithmetic intensity: larger microbatch, fuse "
                 "elementwise chains, bf16 cache reads",
    ("collective",): "reshape collectives: hierarchical/bucketed reduce, "
                     "overlap with compute, shift sharding axes",
}


def suggestion(row: dict) -> str:
    if row["dominant"] == "collective":
        return ("collective-bound: cut volume (hierarchical reduce, bf16 "
                "grads) or overlap collectives with compute")
    if row["dominant"] == "memory":
        return ("memory-bound: raise arithmetic intensity (bigger per-step "
                "tiles/microbatch, fusion, bf16 residency)")
    if row["useful_ratio"] < 0.5:
        return ("compute-bound with low useful ratio: kill redundant FLOPs "
                "(remat policy, head-padding instead of hd-sharding, "
                "dispatch einsum waste)")
    return "compute-bound near roofline: only kernel-level wins remain"


def full_table(results_path=None, hw: HW = V5E):
    results_path = results_path or (
        pathlib.Path(__file__).resolve().parents[3]
        / "benchmarks" / "results" / "dryrun_single.json")
    data = json.loads(pathlib.Path(results_path).read_text())
    rows = []
    for key, entry in sorted(data.items()):
        if not entry.get("ok") or "flops" not in entry:
            continue
        arch, shape = key.split("|")
        rows.append(analyse_pair(arch, shape, entry, hw))
    return rows


def render_markdown(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | roofline-MFU | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2e} | "
            f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_mfu']:.3f} | {suggestion(r)} |")
    return "\n".join(out)
