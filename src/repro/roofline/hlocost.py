"""FLOP/byte accounting over lowered StableHLO.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE —
useless for scan-over-layers models (measured ~800x undercount on a
62-layer/16-microbatch step).  This walker parses the *lowered*
StableHLO (global, pre-SPMD shapes), counts ``dot_general`` FLOPs and
operand/output bytes, multiplies by loop trip counts recovered from
each while's condition (our loops are all counted ``lax.scan``s whose
bound is a scalar constant compared with LT), and resolves
``func.call`` edges.

Returned numbers are GLOBAL; divide by chip count for per-device terms.
``dot_bytes`` is a no-fusion-reuse upper bound on dot-related traffic.
"""
from __future__ import annotations

import re

_FUNC_RE = re.compile(r"func\.func\s+(?:public\s+|private\s+)?@([\w\-]+)\s*\(")
_CONST_RE = re.compile(
    r"%([\w.\-]+) = stablehlo.constant dense<(\d+)> : tensor<i(?:32|64)>")
_CALL_RE = re.compile(r"(?:func\.call|call)\s+@([\w\-]+)")
_CMP_RE = re.compile(r"stablehlo\.compare\s+(?:LT|LE),\s*%[\w.\-]+,\s*%([\w.\-]+)")
_DOT_RE = re.compile(
    r"stablehlo\.dot_general\s+%[\w.\-#]+,\s*%[\w.\-#]+,\s*"
    r"(?:batching_dims\s*=\s*\[([0-9, ]*)\]\s*x\s*\[[0-9, ]*\]\s*,\s*)?"
    r"contracting_dims\s*=\s*\[([0-9, ]*)\]\s*x\s*\[[0-9, ]*\]"
    r".*?:\s*\(tensor<([0-9x]*?)x?(" + (_DT :=
    r"f64|f32|f16|bf16|f8e4m3fn|f8e5m2|i64|i32|i16|i8|i1|ui32|ui8|pred"
    ) + r")>,\s*"
    r"tensor<([0-9x]*?)x?(" + _DT + r")>\)"
    r"\s*->\s*tensor<([0-9x]*?)x?(" + _DT + r")>")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "i64": 8,
                "i32": 4, "i16": 2, "i8": 1, "i1": 1, "ui32": 4, "ui8": 1,
                "f8e4m3fn": 1, "f8e5m2": 1}


def _dims(s: str):
    return [int(d) for d in s.split("x") if d] if s else []


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def stablehlo_cost(text: str) -> dict:
    funcs = {}       # name -> {"flops", "bytes", "calls": [(name, mult)]}
    cur = None
    consts = {}      # streaming (latest definition wins == lexical order)
    mult_stack = [1.0]
    while_stack = []  # (close_depth,) for multiplier pops
    pending = []      # whiles awaiting their do-block
    depth = 0
    unresolved = 0

    for raw in text.splitlines():
        line = raw.strip()

        fm = _FUNC_RE.search(line)
        if fm:
            cur = fm.group(1)
            funcs[cur] = {"flops": 0.0, "bytes": 0.0, "calls": []}
            mult_stack = [1.0]
            while_stack = []
            pending = []

        cm = _CONST_RE.search(line)
        if cm:
            consts[cm.group(1)] = int(cm.group(2))

        if "stablehlo.while" in line:
            pending.append({"trips": None, "depth": depth})

        if pending:
            mm = _CMP_RE.search(line)
            if mm:
                pending[-1]["trips"] = consts.get(mm.group(1))

        if re.search(r"}\s*do\s*{", line):
            fr = pending.pop()
            trips = fr["trips"]
            if trips is None:
                trips = 1
                unresolved += 1
            mult_stack.append(mult_stack[-1] * max(trips, 1))
            while_stack.append(fr["depth"])
            depth += raw.count("{") - raw.count("}")
            continue

        if cur:
            dm = _DOT_RE.search(line)
            if dm:
                (batch_s, contract_s, lhs_s, lhs_dt, rhs_s, rhs_dt,
                 out_s, out_dt) = dm.groups()
                lhs, out = _dims(lhs_s), _dims(out_s)
                cdims = [int(i) for i in contract_s.split(",") if i.strip()]
                k = _prod(lhs[i] for i in cdims) if cdims else 1
                funcs[cur]["flops"] += mult_stack[-1] * 2.0 * _prod(out) * k
                for shp, dt in ((lhs_s, lhs_dt), (rhs_s, rhs_dt),
                                (out_s, out_dt)):
                    funcs[cur]["bytes"] += (mult_stack[-1]
                                            * _prod(_dims(shp))
                                            * _DTYPE_BYTES.get(dt, 4))
            lm = _CALL_RE.search(line)
            if lm:
                funcs[cur]["calls"].append((lm.group(1), mult_stack[-1]))

        depth += raw.count("{") - raw.count("}")
        while while_stack and depth <= while_stack[-1]:
            while_stack.pop()
            mult_stack.pop()

    memo = {}

    def total(name):
        if name in memo:
            return memo[name]
        node = funcs.get(name)
        if node is None:
            return (0.0, 0.0)
        memo[name] = (node["flops"], node["bytes"])   # cycle guard
        f, b = node["flops"], node["bytes"]
        for callee, mult in node["calls"]:
            cf, cb = total(callee)
            f += mult * cf
            b += mult * cb
        memo[name] = (f, b)
        return memo[name]

    entry = "main" if "main" in funcs else (next(iter(funcs)) if funcs else None)
    f, b = total(entry) if entry else (0.0, 0.0)
    return {"flops": f, "dot_bytes": b, "unresolved_loops": unresolved}
