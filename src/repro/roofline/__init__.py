from repro.roofline.analysis import (
    HW, roofline_terms, model_flops, analyse_pair, full_table,
)

__all__ = ["HW", "roofline_terms", "model_flops", "analyse_pair",
           "full_table"]
