"""JAX version compatibility shims.

The repo targets both the installed JAX (0.4.x) and ≥0.6, whose public
API moved several symbols this code depends on:

  * ``shard_map``      — ``jax.shard_map`` (new) vs
                         ``jax.experimental.shard_map.shard_map`` (0.4.x).
  * replication check  — the kwarg is ``check_vma`` (new) vs
                         ``check_rep`` (0.4.x); use ``shard_map_kwargs``.
  * ``jax.lax.axis_size`` — does not exist on 0.4.x; ``axis_size`` falls
                         back to ``psum(1, axis)``, which JAX evaluates
                         statically to a Python int inside shard_map.
  * ``jax.make_mesh(axis_types=...)`` / ``jax.sharding.AxisType`` — the
                         explicit-sharding axis types are new; ``make_mesh``
                         passes them through when supported and drops them
                         otherwise (0.4.x meshes are implicitly Auto).
  * ``jax.set_mesh``   — new; on 0.4.x a ``Mesh`` is itself the context
                         manager, which ``set_mesh`` returns.

Import sites should use this module instead of probing ``jax`` directly.
"""
from __future__ import annotations

import inspect

import jax

try:  # JAX >= 0.6: top-level export
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # JAX 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

_SHARD_MAP_PARAMS = frozenset(inspect.signature(shard_map).parameters)


def shard_map_kwargs(*, check_vma: bool = True) -> dict:
    """Replication-check kwarg under whichever name this JAX spells it."""
    if "check_vma" in _SHARD_MAP_PARAMS:
        return {"check_vma": check_vma}
    return {"check_rep": check_vma}


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis (or tuple of axes), inside
    shard_map.  ``psum`` of a Python literal folds to a Python int."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
    """``jax.make_mesh`` with ``axis_types`` dropped where unsupported."""
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kw["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def auto_axis_types(ndim: int):
    """``(AxisType.Auto,) * ndim`` where AxisType exists, else None."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return None
    return (at.Auto,) * ndim


def set_mesh(mesh):
    """Context manager activating `mesh` (jax.set_mesh or legacy ctx)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
