"""Optimizers (self-contained, no optax): SGD, momentum, AdaGrad, Adam(W).

The paper trains with plain gradient descent and cites TensorFlow's
AdaGrad support; Adam/AdamW are the substrate the large-model training
path needs.  All share one interface:

    opt = adam(3e-4)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)

``lr`` may be a float or a callable step -> lr (schedules).  All
optimizer state is fp32 regardless of gradient dtype (mixed-precision
master weights live in the params tree itself).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]
    state_factor: int              # fp32 state floats per param (for memory est.)


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else lr


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd(lr: Schedule = 1e-2) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        eta = _lr_at(lr, state["step"])
        new = _tmap(lambda p, g: p - eta * g.astype(p.dtype), params, grads)
        return new, {"step": state["step"] + 1}

    return Optimizer("sgd", init, update, 0)


def momentum(lr: Schedule = 1e-2, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        eta = _lr_at(lr, state["step"])
        m = _tmap(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                  state["m"], grads)
        new = _tmap(lambda p, m_: p - eta * m_.astype(p.dtype), params, m)
        return new, {"step": state["step"] + 1, "m": m}

    return Optimizer("momentum", init, update, 1)


def adagrad(lr: Schedule = 1e-2, eps: float = 1e-10) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "g2": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        eta = _lr_at(lr, state["step"])
        g2 = _tmap(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                   state["g2"], grads)
        new = _tmap(
            lambda p, g, a: p - (eta * g.astype(jnp.float32)
                                 / (jnp.sqrt(a) + eps)).astype(p.dtype),
            params, grads, g2)
        return new, {"step": state["step"] + 1, "g2": g2}

    return Optimizer("adagrad", init, update, 1)


def adam(lr: Schedule = 3e-4, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(z, params), "v": _tmap(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = _lr_at(lr, step)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_
                  + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return p - (eta * u).astype(p.dtype)

        new = _tmap(upd, params, m, v)
        return new, {"step": step, "m": m, "v": v}

    return Optimizer("adamw" if weight_decay else "adam", init, update, 2)


def adamw(lr: Schedule = 3e-4, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr


OPTIMIZERS = {"sgd": sgd, "momentum": momentum, "adagrad": adagrad,
              "adam": adam, "adamw": adamw}


def get_optimizer(name: str, lr: Schedule, **kw) -> Optimizer:
    return OPTIMIZERS[name](lr, **kw)
