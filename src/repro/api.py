"""The user-facing training facade — the paper's transparency claim as
an API.

The source paper (and its MaTEx follow-on) sells distributed training
that needs "minimal changes" from the user.  :class:`Trainer` is that
surface for this reproduction: one object that hides strategy
resolution, TrainState construction, sharded checkpointing and the
perf model behind four calls —

    from repro.api import Trainer
    from repro.core import DPConfig

    trainer = Trainer.create(model_cfg=cfg, dp=DPConfig(strategy="zero1"),
                             mesh=mesh)
    for batch in batches:
        metrics = trainer.step(batch)
    trainer.save(ckpt_dir)            # per-shard, atomic, gather-free
    ...
    trainer = Trainer.create(...same...)
    trainer.restore(ckpt_dir)         # reshards across layout changes
    print(trainer.describe())

``create`` takes either a ``model_cfg`` (a ``repro.configs``
architecture — loss and params are built for you) or an explicit
``loss_fn`` + ``params`` pair (paper nets, custom research code).
``mesh=None`` builds the single-device sequential reference step —
the same object, so A/B-ing distributed vs sequential is one argument.
``params`` may be a pytree of ``jax.ShapeDtypeStruct``s: the state is
then built from shapes alone (a restore template — for zero3 the full
parameter pytree never exists anywhere).

Every strategy in ``repro.core.strategy``'s registry — including ones
you register yourself — is reachable via ``DPConfig(strategy=name)``;
``launch/train.py``, ``examples/`` and ``benchmarks/`` all drive
training through this facade.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import restore_train_state, save_sharded_checkpoint
from repro.core.data_parallel import (
    DPConfig, make_dp_train_step, make_sequential_step,
)
from repro.core.strategy import get_strategy
from repro.core.collectives import dp_world_size
from repro.core.train_state import TrainState, host_params, init_train_state
from repro import optim as optim_lib


def _resolve_optimizer(optimizer, lr):
    if isinstance(optimizer, str):
        return optim_lib.get_optimizer(optimizer, lr)
    return optimizer


@dataclasses.dataclass
class Trainer:
    """A bound (step_fn, state) pair — see module docstring.  Build
    with :meth:`create`; ``state`` is the live :class:`TrainState`."""
    state: TrainState
    optimizer: Any
    loss_fn: Callable
    dp: DPConfig
    mesh: Any                       # None => sequential reference step
    model_cfg: Any = None           # set when created from a ModelConfig
    _step_fn: Callable = dataclasses.field(repr=False, default=None)
    _async_ckpt: Any = dataclasses.field(repr=False, default=None)

    # ---- construction ----------------------------------------------------
    @classmethod
    def create(cls, model_cfg=None, *, loss_fn=None, params=None,
               optimizer="adam", lr: float = 1e-3,
               dp: Optional[DPConfig] = None, mesh=None, key=None,
               train_cfg=None, donate: bool = False) -> "Trainer":
        """Build a ready-to-step Trainer.

        model_cfg — a ``repro.configs`` architecture config; loss comes
                    from ``repro.train.step.make_loss_fn`` and params
                    from ``init_model`` (unless ``params`` is given).
        loss_fn   — alternatively, an explicit
                    ``loss_fn(params, batch) -> scalar``; requires
                    ``params``.
        params    — parameter pytree (or ShapeDtypeStructs: a
                    zero-filled restore template).
        optimizer — ``repro.optim`` Optimizer, or a name ("adam",
                    "adamw", "sgd", "momentum", ...) resolved with `lr`.
        dp        — DPConfig; ``dp.strategy`` may be any registered
                    strategy name.
        mesh      — device mesh for the explicit-DP step, or None for
                    the single-device sequential reference.
        train_cfg — optional ``repro.train.step.TrainConfig`` used with
                    ``model_cfg`` (microbatches there are superseded by
                    ``dp.microbatches`` in the DP step).
        """
        dp = dp if dp is not None else DPConfig()
        key = key if key is not None else jax.random.PRNGKey(0)
        optimizer = _resolve_optimizer(optimizer, lr)
        if model_cfg is not None:
            if loss_fn is not None:
                raise ValueError("pass model_cfg OR loss_fn, not both")
            from repro.models import init_model
            from repro.train.step import TrainConfig, make_loss_fn
            tc = train_cfg if train_cfg is not None else TrainConfig(
                remat=False)
            base_loss = make_loss_fn(model_cfg, tc)
            loss_fn = lambda p, b: base_loss(p, b)[0]  # noqa: E731
            if params is None:
                params = init_model(model_cfg, key)
        elif loss_fn is None or params is None:
            raise ValueError(
                "Trainer.create needs model_cfg, or loss_fn + params")
        if mesh is None:
            step_fn = make_sequential_step(loss_fn, optimizer)
            state = init_train_state(optimizer, params)
        else:
            step_fn = make_dp_train_step(loss_fn, optimizer, mesh, dp,
                                         donate=donate)
            state = init_train_state(optimizer, params, mesh, dp)
        return cls(state=state, optimizer=optimizer, loss_fn=loss_fn,
                   dp=dp, mesh=mesh, model_cfg=model_cfg, _step_fn=step_fn)

    # ---- training --------------------------------------------------------
    def step(self, batch) -> dict:
        """Advance one step on `batch`; returns the metrics dict."""
        self.state, metrics = self._step_fn(self.state, batch)
        return metrics

    def lower(self, batch):
        """Lower the step for HLO inspection (explicit-DP path only)."""
        if not hasattr(self._step_fn, "lower"):
            raise AttributeError("the sequential reference step does not "
                                 "expose .lower")
        return self._step_fn.lower(self.state, batch)

    @property
    def params(self):
        """Host copy of the FULL parameter pytree, whatever the layout
        (zero3 shards are reassembled host-side — eval/debug use)."""
        return host_params(self.state)

    # ---- checkpointing ---------------------------------------------------
    def save(self, ckpt_dir, *, keep_last: Optional[int] = None,
             extra: Optional[dict] = None) -> str:
        """Write the sharded, atomic, gather-free checkpoint
        synchronously; returns the published step path.  ``keep_last``
        prunes older published steps; ``extra`` rides in ``meta.json``
        (e.g. the data cursor)."""
        return save_sharded_checkpoint(ckpt_dir, int(self.state.step),
                                       self.state, keep_last=keep_last,
                                       extra=extra)

    def save_async(self, ckpt_dir, *, keep_last: Optional[int] = None,
                   max_in_flight: int = 1,
                   extra: Optional[dict] = None) -> dict:
        """Asynchronous save: block only for the device→host shard copy,
        publish in the background (``repro.elastic.AsyncCheckpointer``,
        lazily created and cached on this trainer — a different
        ``ckpt_dir`` rebuilds it).  Returns the save receipt
        ``{"step", "blocking_s", "bytes"}``.  Call :meth:`finish_saves`
        before a planned shutdown so the final step is durable."""
        from repro.elastic import AsyncCheckpointer
        ck = self._async_ckpt
        if ck is None or ck.ckpt_dir != pathlib.Path(ckpt_dir):
            if ck is not None:
                ck.close()
            ck = AsyncCheckpointer(ckpt_dir, keep_last=keep_last,
                                   max_in_flight=max_in_flight)
            self._async_ckpt = ck
        return ck.save(self.state, extra=extra)

    def finish_saves(self, timeout: Optional[float] = None):
        """Drain the async checkpointer (publish barrier); returns its
        telemetry ``stats()`` dict, or None if :meth:`save_async` was
        never used.  Re-raises any background writer error."""
        if self._async_ckpt is None:
            return None
        self._async_ckpt.wait(timeout)
        return self._async_ckpt.stats()

    def restore(self, ckpt_dir, step: Optional[int] = None) -> int:
        """Restore into this trainer's layout, picking the store by
        what is ON DISK (``restore_train_state``): a ``.shards``
        directory goes through the sharded store — current state is the
        template; cross-layout checkpoints reshard on host — and a
        legacy ``.npz`` is loaded leaf-for-leaf into replicated leaves
        (a sharded layout raises loudly there).  Returns the restored
        step."""
        self.state, at = restore_train_state(ckpt_dir, self.state, step)
        return at

    def restore_elastic(self, ckpt_dir, step: Optional[int] = None):
        """Elastic resume: restore the newest *published* step that is
        actually readable, falling back past torn/corrupt steps
        (``repro.elastic.resume_elastic``) — this trainer may be built
        for a DIFFERENT mesh/strategy than the one that saved (the
        store reshards on host).  Returns ``(step, skipped)`` where
        ``skipped`` lists ``(step, reason)`` for abandoned steps."""
        from repro.elastic import resume_elastic
        self.state, at, skipped = resume_elastic(ckpt_dir, self.state,
                                                 step=step)
        return at, skipped

    # ---- serving ---------------------------------------------------------
    def serve(self, *, engine: str = "continuous", mesh=None, **engine_kw):
        """Serve THIS trainer's current parameters — the in-memory half
        of the train-and-serve loop (``make_engine_from_checkpoint``
        is the on-disk half).  Whatever the training layout, the full
        parameter pytree is reassembled on host (``host_params`` — for
        zero3 that is per-shard reads, no device gather) and handed to
        ``repro.serve.make_engine``: ``engine="continuous"`` builds the
        paged-cache continuous-batching scheduler, ``"legacy"`` the
        lockstep reference.  Pass ``mesh=`` (typically a serve mesh
        from ``launch.mesh``, not the training mesh — serve-mode
        shardings keep weights resident) to put the engine on a
        production topology.  Requires the trainer to have been created
        from a ``model_cfg``."""
        if self.model_cfg is None:
            raise ValueError(
                "Trainer.serve needs a model architecture; create the "
                "trainer with Trainer.create(model_cfg=...) (a custom "
                "loss_fn has no serving forward pass)")
        from repro.serve import make_engine  # lazy: serving is optional
        params = jax.tree_util.tree_map(jax.numpy.asarray,
                                        host_params(self.state))
        return make_engine(self.model_cfg, params, engine=engine,
                           mesh=mesh, **engine_kw)

    # ---- introspection ---------------------------------------------------
    def describe(self) -> dict:
        """What this trainer physically runs: strategy, layout, world
        size, and the strategy's own perf-model entries (per-device
        persistent memory; modeled step wire time)."""
        layout = self.state.layout
        strategy = get_strategy(self.dp.strategy)
        n_params = layout.total
        world = dp_world_size(self.mesh) if self.mesh is not None else 1
        mem = strategy.memory_entry(n_params, self.optimizer.state_factor,
                                    world)
        d = {
            "strategy": strategy.name,
            "sync": self.dp.sync,
            "layout": layout.to_json(),
            "world_size": world,
            "params": int(n_params),
            "memory_per_device_bytes": {k: float(v) for k, v in mem.items()},
        }
        if self.mesh is not None:
            shape = dict(self.mesh.shape)
            n_pods = int(shape.get("pod", 1))
            n_intra = int(shape.get("data", world))
            d["comm_time_s"] = float(strategy.comm_time(
                4.0 * n_params, p=world, n_intra=n_intra, n_pods=n_pods,
                microbatches=self.dp.microbatches))
        return d
