"""Elastic resize: resume a killed run under a different mesh/strategy.

Losing a pod changes the world size; waiting for it to come back wastes
the rest.  Because the sharded checkpoint store reshards across layouts
on restore (``restore_sharded_checkpoint``'s canonical-flat path — any
registered strategy, any shard count, bucket-major or contiguous), an
elastic resume is just: build a fresh trainer for the NEW topology,
then restore the newest *published* step into its state template.

:func:`resume_elastic` adds the survival policy on top of the plain
restore: it walks ``published_steps`` newest-first and, when a step's
data turns out to be torn/corrupt (``CorruptCheckpointError`` — e.g. a
truncated shard file from a dying disk), falls back to the previous
published step instead of dying, reporting every step it skipped.  The
atomic-publish protocol makes this safe: a *published* directory name
guarantees the rename happened, so an unreadable member is data
corruption, not a half-write — and older steps are independent.
"""
from __future__ import annotations

from typing import Optional

from repro.checkpoint.store import (
    CorruptCheckpointError, published_steps, restore_train_state,
)


def resume_elastic(ckpt_dir, template, *, step: Optional[int] = None,
                   max_fallbacks: Optional[int] = None):
    """Restore the newest usable published step into ``template`` (a
    TrainState of ANY registered layout — the cross-layout reshard is
    the store's).  Returns ``(state, step, skipped)`` where ``skipped``
    is a list of ``(step, reason)`` for every newer published step that
    had to be abandoned as corrupt.

    ``step=``            resume at/below a specific step instead of the newest.
    ``max_fallbacks=``   bound how many corrupt steps to skip (None: all).

    Raises ``FileNotFoundError`` when nothing is published, and
    ``CorruptCheckpointError`` when every candidate step is unreadable
    (carrying the per-step reasons)."""
    steps = published_steps(ckpt_dir)
    if step is not None:
        steps = [s for s in steps if s <= step]
    if not steps:
        raise FileNotFoundError(
            f"no published checkpoint in {ckpt_dir}"
            + (f" at or below step {step}" if step is not None else ""))
    skipped = []
    for s in reversed(steps):
        if max_fallbacks is not None and len(skipped) > max_fallbacks:
            break
        try:
            state, at = restore_train_state(ckpt_dir, template, s)
            return state, at, skipped
        except CorruptCheckpointError as e:
            skipped.append((s, str(e)))
    raise CorruptCheckpointError(
        f"every candidate step in {ckpt_dir} is unreadable: "
        + "; ".join(f"step {s}: {r.splitlines()[0]}" for s, r in skipped))
