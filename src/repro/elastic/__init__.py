"""Elastic fault-tolerant training.

The source paper assumes a fixed set of MPI ranks for the whole run;
at multi-pod scale preemption is routine and checkpoint I/O cannot sit
on the step path.  This package turns the gather-free sharded
checkpoint store (``repro.checkpoint``) into a survival mechanism:

* :class:`AsyncCheckpointer` — device→host snapshot at a step
  boundary (the only blocking part), write + atomic publish on a
  background thread, bounded in-flight queue with last-publish-wins;
* :class:`FaultInjector` / :class:`FaultPlan` — deterministic
  preemption: kill the process hard at a chosen step;
* :func:`resume_elastic` — resume the latest *published* step into a
  template of ANY registered layout/mesh shape (the existing
  cross-layout restore), falling back past corrupt steps.

See ``docs/elastic.md`` for the lifecycle and the kill/resize
walkthrough.
"""
from repro.elastic.async_ckpt import AsyncCheckpointer
from repro.elastic.faults import (FAULT_EXIT_CODE, FaultInjector, FaultPlan,
                                  SimulatedFault)
from repro.elastic.resize import resume_elastic

__all__ = ["AsyncCheckpointer", "FAULT_EXIT_CODE", "FaultInjector",
           "FaultPlan", "SimulatedFault", "resume_elastic"]
