"""Deterministic fault injection: preempt a training run at a chosen
step.

The paper's fault-tolerance story (§2.2: ULFM survives a rank failure)
is only testable if failures are *reproducible*.  A
:class:`FaultInjector` kills the process at an exact step boundary —
by default with ``os._exit``, the closest userspace analogue of a
preemption/SIGKILL: no ``atexit`` handlers, no thread joins, no
buffered-file flushing, so a mid-write background checkpointer leaves
exactly the torn ``tmp-`` staging state a real kill would.  The tests
drive it subprocess-based, like the existing 8-device checkpoint
crash-safety tests: spawn a run with ``REPRO_FAULT_STEP`` set, assert
the exit code, then resume from what was *published*.

``mode="raise"`` throws :class:`SimulatedFault` instead — an in-process
soft failure for exercising recovery paths under pytest without a
subprocess.
"""
from __future__ import annotations

import dataclasses
import os
import sys
from typing import Optional

#: default exit status for an injected kill — distinct from Python
#: tracebacks (1) and shell "command not found" (127) so the test
#: harness can assert the fault fired rather than the run crashing
FAULT_EXIT_CODE = 113

ENV_STEP = "REPRO_FAULT_STEP"
ENV_MODE = "REPRO_FAULT_MODE"


class SimulatedFault(RuntimeError):
    """Raised by ``mode="raise"`` injectors at the planned step."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """When and how to die.  ``kill_at_step`` is compared against the
    step index passed to ``after_step`` — the fault fires at the FIRST
    boundary where ``step >= kill_at_step``, so a plan outlives
    restarts/resumes without re-counting."""
    kill_at_step: int
    mode: str = "exit"                 # "exit" (hard, os._exit) | "raise"
    exit_code: int = FAULT_EXIT_CODE

    def __post_init__(self):
        if self.mode not in ("exit", "raise"):
            raise ValueError(f"FaultPlan.mode must be 'exit' or 'raise', "
                             f"got {self.mode!r}")


class FaultInjector:
    """Call :meth:`after_step` at every step boundary; the process dies
    when the planned step is reached.  Fires at most once."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired = False

    @classmethod
    def from_env(cls, env=None) -> Optional["FaultInjector"]:
        """Build from ``REPRO_FAULT_STEP`` (and optional
        ``REPRO_FAULT_MODE``); None when no fault is configured — so a
        launcher can unconditionally write
        ``injector = FaultInjector.from_env()``."""
        env = os.environ if env is None else env
        raw = env.get(ENV_STEP, "")
        if not raw:
            return None
        step = int(raw)
        if step < 0:
            return None
        return cls(FaultPlan(step, mode=env.get(ENV_MODE, "exit")))

    def after_step(self, step: int):
        """Die iff ``step`` has reached the plan.  ``mode="exit"``
        flushes stdout/stderr first (the run's printed losses are test
        evidence) but nothing else — background threads are abandoned
        mid-flight, like a real preemption."""
        if self.fired or step < self.plan.kill_at_step:
            return
        self.fired = True
        if self.plan.mode == "raise":
            raise SimulatedFault(
                f"injected fault at step {step} "
                f"(planned: {self.plan.kill_at_step})")
        print(f"FAULT: killing at step {step}", flush=True)
        sys.stderr.flush()
        os._exit(self.plan.exit_code)
