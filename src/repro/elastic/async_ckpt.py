"""Async checkpointer daemon: snapshot on the step path, publish off it.

A synchronous ``save_sharded_checkpoint`` holds the step loop for the
whole device→host copy *and* the file write + atomic publish.  Only the
first half has to block — the shards must be copied out before the next
optimizer update mutates them (donated buffers) — so
:class:`AsyncCheckpointer` splits the save exactly along the
``snapshot_train_state`` / ``write_state_snapshot`` seam of the store:

  1. ``save(state)`` runs the blocking device→host copy (one
     ``np.asarray`` per addressable shard, NO gather — the per-worker
     shard format of ``save_sharded_checkpoint``) and enqueues the
     frozen :class:`~repro.checkpoint.store.StateSnapshot`;
  2. a single daemon thread drains the queue, writing + atomically
     publishing each snapshot (stale ``tmp-`` sweep and ``keep_last``
     retention ride the same publish);
  3. the queue is bounded (``max_in_flight``): when the writer falls
     behind, the *oldest* queued snapshot is dropped so the newest
     always publishes — last-publish-wins.  A preempted run therefore
     resumes from the last *published* step, which may trail the last
     *requested* step; ``stats()["steps_behind"]`` is that gap.

``wait()`` is the clean-shutdown barrier (drain the queue, re-raise any
writer error); ``close()`` stops the daemon.  Telemetry: per-save
blocking seconds (the device→host copy — the only step-path cost),
per-write publish seconds, bytes, drop/publish counts.

MaxText ships a *standalone checkpointer process* as the degenerate
case of exactly this split; here the daemon is a thread because the
snapshot is already plain host memory.
"""
from __future__ import annotations

import collections
import pathlib
import threading
import time
from typing import Callable, Optional

from repro.checkpoint.store import (
    StateSnapshot, snapshot_train_state, write_state_snapshot,
)


class AsyncCheckpointer:
    """See module docstring.  ``writer`` is the publish function the
    daemon calls (``write_state_snapshot(ckpt_dir, snap, keep_last=)``
    signature) — tests substitute a delayed writer to pin down the
    queue semantics."""

    def __init__(self, ckpt_dir, *, keep_last: Optional[int] = None,
                 max_in_flight: int = 1,
                 writer: Optional[Callable] = None):
        if max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}")
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep_last = keep_last
        self.max_in_flight = int(max_in_flight)
        self._writer = writer if writer is not None else write_state_snapshot
        self._cond = threading.Condition()
        self._pending: "collections.deque[StateSnapshot]" = \
            collections.deque()
        self._writing = False
        self._closed = False
        self._error: Optional[BaseException] = None
        # telemetry (all guarded by _cond)
        self._saves = 0
        self._published = 0
        self._dropped = 0
        self._bytes_published = 0
        self._last_requested_step: Optional[int] = None
        self._last_published_step: Optional[int] = None
        self._last_blocking_s: Optional[float] = None
        self._last_write_s: Optional[float] = None
        self._total_blocking_s = 0.0
        self._total_write_s = 0.0
        self._thread = threading.Thread(
            target=self._run, name="async-ckpt", daemon=True)
        self._thread.start()

    # ---- step-path API ---------------------------------------------------
    def save(self, state, step: Optional[int] = None, *,
             extra: Optional[dict] = None) -> dict:
        """Snapshot ``state`` (blocking: the device→host copy only) and
        enqueue it for background publish.  Returns a small record of
        the blocking cost (``{"step", "blocking_s", "bytes"}``).  If
        the bounded queue is full, the oldest *queued* snapshot is
        dropped — the one being written always completes (its publish
        is already the newest durable state)."""
        self._check_error()
        if self._closed:
            raise RuntimeError("AsyncCheckpointer is closed")
        at = int(state.step) if step is None else int(step)
        t0 = time.monotonic()
        snap = snapshot_train_state(state, at, extra=extra)
        blocking_s = time.monotonic() - t0
        with self._cond:
            while len(self._pending) >= self.max_in_flight:
                victim = self._pending.popleft()   # last-publish-wins
                self._dropped += 1
                del victim
            self._pending.append(snap)
            self._saves += 1
            self._last_requested_step = at
            self._last_blocking_s = blocking_s
            self._total_blocking_s += blocking_s
            self._cond.notify_all()
        return {"step": at, "blocking_s": blocking_s,
                "bytes": snap.nbytes}

    def wait(self, timeout: Optional[float] = None):
        """Barrier: block until every queued snapshot is published (or
        ``timeout`` seconds elapse -> TimeoutError).  Re-raises any
        background writer error.  Call before a planned shutdown so the
        final step is durable."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while (self._pending or self._writing) and self._error is None:
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"async checkpoint publish still pending after "
                        f"{timeout}s (queued={len(self._pending)}, "
                        f"writing={self._writing})")
                self._cond.wait(remaining)
        self._check_error()

    def close(self, *, drain: bool = True):
        """Stop the daemon.  ``drain=True`` (default) publishes
        everything still queued first; ``drain=False`` abandons queued
        snapshots (the in-progress write still completes)."""
        if drain and self._error is None:
            try:
                self.wait()
            except RuntimeError:
                pass                       # surfaced via _check_error below
        with self._cond:
            if not drain:
                self._dropped += len(self._pending)
                self._pending.clear()
            self._closed = True
            self._cond.notify_all()
        self._thread.join()
        self._check_error()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc[0] is None)
        return False

    # ---- telemetry -------------------------------------------------------
    def stats(self) -> dict:
        """Save latency / bytes / steps-behind telemetry.
        ``steps_behind`` = last requested − last published step: how
        much training a crash right now would lose on top of the steps
        since the last ``save()``."""
        with self._cond:
            if self._last_requested_step is None:
                behind = None                 # nothing requested yet
            elif self._last_published_step is None:
                behind = self._last_requested_step   # nothing durable yet
            else:
                behind = (self._last_requested_step
                          - self._last_published_step)
            return {
                "saves": self._saves,
                "published": self._published,
                "dropped": self._dropped,
                "queued": len(self._pending) + int(self._writing),
                "bytes_published": self._bytes_published,
                "last_requested_step": self._last_requested_step,
                "last_published_step": self._last_published_step,
                "steps_behind": behind,
                "last_blocking_s": self._last_blocking_s,
                "last_write_s": self._last_write_s,
                "total_blocking_s": self._total_blocking_s,
                "total_write_s": self._total_write_s,
            }

    # ---- daemon ----------------------------------------------------------
    def _run(self):
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if not self._pending:
                    return                  # closed + drained
                snap = self._pending.popleft()
                self._writing = True
            try:
                t0 = time.monotonic()
                self._writer(self.ckpt_dir, snap,
                             keep_last=self.keep_last)
                write_s = time.monotonic() - t0
                with self._cond:
                    self._writing = False
                    self._published += 1
                    self._bytes_published += snap.nbytes
                    self._last_published_step = snap.step
                    self._last_write_s = write_s
                    self._total_write_s += write_s
                    self._cond.notify_all()
            except BaseException as e:       # surface on the step path
                with self._cond:
                    self._writing = False
                    self._error = e
                    self._cond.notify_all()
                return

    def _check_error(self):
        if self._error is not None:
            raise RuntimeError(
                "async checkpoint writer failed; the LAST PUBLISHED "
                "step is still consistent on disk") from self._error
