"""Serving: prefill / decode step factories + the legacy batched engine.

``decode_step`` is what the decode_32k / long_500k dry-run shapes lower:
ONE new token per sequence against a KV cache of ``seq_len``.  Cache
layout and sharding come from sharding.rules (seq dim over "model" so
32k-per-sequence caches fit per-chip HBM; batch over "data"/"pod").

``ServeEngine`` is the host-side lockstep loop: greedy or sampled over
fixed slots, ONE blocking host round-trip per token (it syncs on
``bool(done.all())`` every step).  It is retained as the equivalence
reference for ``serve.scheduler.ContinuousScheduler`` — the
continuous-batching engine with the fused device-side decode loop —
and as the benchmark baseline for the host-sync story.

``make_engine`` / ``make_engine_from_checkpoint`` are the constructor
surface the launcher and ``Trainer.serve`` use: the latter serves any
checkpoint the training stack wrote (sharded ANY layout, or legacy
npz) via the read-only restore in ``checkpoint.store`` — no optimizer
state, no gather on device.  Both take ``mesh=`` and thread it into
the engine: the production path is a mesh-native continuous engine
(model-sharded paged pool, expert-parallel MoE decode); ``mesh=None``
keeps the host path byte-for-byte as before.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import apply_model, init_cache
from repro.serve.sampling import SamplingConfig, sample
from repro.serve.scheduler import ContinuousScheduler
from repro.sharding import ctx as shctx


def make_prefill_step(cfg):
    def prefill(params, batch, cache):
        out = apply_model(cfg, params, batch, mode="prefill", cache=cache,
                          cache_pos=0, last_only=True)
        # next-token logits at the last position of each sequence
        return out["logits"][:, -1], out["cache"]
    return prefill


def make_decode_step(cfg):
    def decode(params, tokens, cache, cache_pos):
        out = apply_model(cfg, params, {"tokens": tokens}, mode="decode",
                          cache=cache, cache_pos=cache_pos)
        return out["logits"][:, -1], out["cache"]
    return decode


class ServeEngine:
    """Batched generation over fixed slots: greedy or sampled
    (temperature / top-k / nucleus via SamplingConfig).  Lockstep: a
    new batch cannot start until every slot retires, and every token
    costs a blocking host sync (`host_syncs` counts them)."""

    def __init__(self, cfg, params, *, batch_size, max_len,
                 dtype=jnp.bfloat16, eos_id: Optional[int] = None,
                 sampling: SamplingConfig = SamplingConfig(),
                 seed: int = 0, mesh: object = None):
        self.cfg = cfg
        self.mesh = mesh
        self._topo = (None if mesh is None
                      else shctx.ServeTopology.from_mesh(mesh))
        if mesh is not None:
            from repro.sharding.rules import (ShardingConfig, cache_shardings,
                                              param_shardings)
            scfg = ShardingConfig.for_mode("serve")
            params = jax.device_put(
                params,
                param_shardings(cfg, mesh, jax.eval_shape(lambda: params),
                                scfg))
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.eos_id = eos_id
        self.sampling = sampling
        self._key = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, batch_size, max_len, dtype)
        if mesh is not None:
            # slab cache uses the decode cache layout (seq over "model")
            self.cache = jax.device_put(
                self.cache,
                cache_shardings(cfg, mesh,
                                jax.eval_shape(lambda: self.cache),
                                batch_size, scfg))
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg))
        self._sample = jax.jit(
            functools.partial(sample, sc=sampling))
        self.host_syncs = 0
        self.dispatches = 0

    def _next(self, logits):
        self._key, sub = jax.random.split(self._key)
        self.dispatches += 1
        return self._sample(logits, sub)[:, None]

    def generate(self, prompts, max_new_tokens: int):
        """prompts: (B, S0) int32 — same length (pad upstream)."""
        if self._topo is not None:
            with shctx.serve_topology(self._topo):
                return self._generate(prompts, max_new_tokens)
        return self._generate(prompts, max_new_tokens)

    def _generate(self, prompts, max_new_tokens: int):
        logits, self.cache = self._prefill(
            self.params, {"tokens": prompts}, self.cache)
        self.dispatches += 1
        pos = prompts.shape[1]
        tok = self._next(logits)
        outs = [tok]
        done = jnp.zeros((prompts.shape[0],), bool)
        if self.eos_id is not None:
            done = done | (tok[:, 0] == self.eos_id)
        for _ in range(max_new_tokens - 1):
            logits, self.cache = self._decode(self.params, tok, self.cache,
                                              pos)
            self.dispatches += 1
            pos += 1
            tok = self._next(logits)
            if self.eos_id is not None:
                # retired slots must stop leaking live samples into the
                # output: pin them to eos_id (pad) once done
                tok = jnp.where(done[:, None], jnp.int32(self.eos_id), tok)
                done = done | (tok[:, 0] == self.eos_id)
                outs.append(tok)
                self.host_syncs += 1          # the per-token round-trip
                if bool(done.all()):
                    break
            else:
                outs.append(tok)
        return jnp.concatenate(outs, axis=1)


# --------------------------------------------------------------------------
# constructor surface (launcher / Trainer.serve)
# --------------------------------------------------------------------------

def make_engine(cfg, params, *, engine="continuous", batch_size=4,
                max_len=256, dtype=jnp.float32, eos_id=None,
                sampling: SamplingConfig = SamplingConfig(), seed=0,
                mesh=None, **kw):
    """Build a serving engine over an in-memory param pytree.

    engine="continuous" — paged-cache ContinuousScheduler (extra kw:
    page_size, num_pages, prefill_chunk, decode_chunk, pad_id,
    prefix_cache, tenant_quota, spec_decode — speculative decode with
    k-token MTP draft-verify chunks, greedy-only, ``mtp_depth > 0``
    archs); engine="legacy" — the lockstep ServeEngine reference.

    mesh=None serves on the host path; pass a serve mesh (e.g.
    ``launch.mesh.make_serve_mesh`` / ``make_production_mesh``) and
    params + KV land model-sharded with every compiled call running
    under the scoped serve topology.
    """
    if engine == "continuous":
        return ContinuousScheduler(cfg, params, slots=batch_size,
                                   max_len=max_len, dtype=dtype,
                                   eos_id=eos_id, sampling=sampling,
                                   seed=seed, mesh=mesh, **kw)
    if engine == "legacy":
        if kw:
            raise TypeError(f"legacy engine takes no {sorted(kw)}")
        return ServeEngine(cfg, params, batch_size=batch_size,
                           max_len=max_len, dtype=dtype, eos_id=eos_id,
                           sampling=sampling, seed=seed, mesh=mesh)
    raise ValueError(f"unknown engine {engine!r} "
                     "(expected 'continuous' or 'legacy')")


def make_engine_from_checkpoint(ckpt_dir, cfg, *, step=None, key=None,
                                **engine_kw):
    """Close the train-and-serve loop: serve the params of a checkpoint
    written by the training stack — sharded (any registered layout:
    replicated/zero1/zero2/zero3/custom) or legacy npz — restored
    read-only on host (``checkpoint.restore_serve_params``), no
    optimizer state, no device gather.  The restore template is the
    FULL ``init_model`` tree, so ``mtp_depth > 0`` archs carry their
    trained ``params["mtp"]`` head into serving — that is what
    ``spec_decode=k`` drafts from.  Returns the engine."""
    from repro.checkpoint import restore_serve_params  # lazy: keep
    from repro.models import init_model                # serve import light

    key = key if key is not None else jax.random.PRNGKey(0)
    template = jax.eval_shape(functools.partial(init_model, cfg), key)
    params, at = restore_serve_params(ckpt_dir, template, step)
    params = jax.tree_util.tree_map(jnp.asarray, params)
    eng = make_engine(cfg, params, **engine_kw)
    eng.restored_step = at
    return eng
