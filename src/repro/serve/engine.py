"""Serving: prefill / decode step factories + the legacy batched engine.

``decode_step`` is what the decode_32k / long_500k dry-run shapes lower:
ONE new token per sequence against a KV cache of ``seq_len``.  Cache
layout and sharding come from sharding.rules (seq dim over "model" so
32k-per-sequence caches fit per-chip HBM; batch over "data"/"pod").

``ServeEngine`` is the host-side lockstep loop: greedy or sampled over
fixed slots, ONE blocking host round-trip per token (it syncs on
``bool(done.all())`` every step).  It is retained as the equivalence
reference for ``serve.scheduler.ContinuousScheduler`` — the
continuous-batching engine with the fused device-side decode loop —
and as the benchmark baseline for the host-sync story.

``make_engine`` / ``make_engine_from_checkpoint`` are the constructor
surface the launcher and ``Trainer.serve`` use: the latter serves any
checkpoint the training stack wrote (sharded ANY layout, or legacy
npz) via the read-only restore in ``checkpoint.store`` — no optimizer
state, no mesh, no gather on device.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import apply_model, init_cache
from repro.serve.sampling import SamplingConfig, sample
from repro.serve.scheduler import ContinuousScheduler


def make_prefill_step(cfg):
    def prefill(params, batch, cache):
        out = apply_model(cfg, params, batch, mode="prefill", cache=cache,
                          cache_pos=0, last_only=True)
        # next-token logits at the last position of each sequence
        return out["logits"][:, -1], out["cache"]
    return prefill


def make_decode_step(cfg):
    def decode(params, tokens, cache, cache_pos):
        out = apply_model(cfg, params, {"tokens": tokens}, mode="decode",
                          cache=cache, cache_pos=cache_pos)
        return out["logits"][:, -1], out["cache"]
    return decode


class ServeEngine:
    """Batched generation over fixed slots: greedy or sampled
    (temperature / top-k / nucleus via SamplingConfig).  Lockstep: a
    new batch cannot start until every slot retires, and every token
    costs a blocking host sync (`host_syncs` counts them)."""

    def __init__(self, cfg, params, *, batch_size, max_len,
                 dtype=jnp.bfloat16, eos_id: Optional[int] = None,
                 sampling: SamplingConfig = SamplingConfig(),
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.eos_id = eos_id
        self.sampling = sampling
        self._key = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, batch_size, max_len, dtype)
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg))
        self._sample = jax.jit(
            functools.partial(sample, sc=sampling))
        self.host_syncs = 0
        self.dispatches = 0

    def _next(self, logits):
        self._key, sub = jax.random.split(self._key)
        self.dispatches += 1
        return self._sample(logits, sub)[:, None]

    def generate(self, prompts, max_new_tokens: int):
        """prompts: (B, S0) int32 — same length (pad upstream)."""
        logits, self.cache = self._prefill(
            self.params, {"tokens": prompts}, self.cache)
        self.dispatches += 1
        pos = prompts.shape[1]
        tok = self._next(logits)
        outs = [tok]
        done = jnp.zeros((prompts.shape[0],), bool)
        if self.eos_id is not None:
            done = done | (tok[:, 0] == self.eos_id)
        for _ in range(max_new_tokens - 1):
            logits, self.cache = self._decode(self.params, tok, self.cache,
                                              pos)
            self.dispatches += 1
            pos += 1
            tok = self._next(logits)
            if self.eos_id is not None:
                # retired slots must stop leaking live samples into the
                # output: pin them to eos_id (pad) once done
                tok = jnp.where(done[:, None], jnp.int32(self.eos_id), tok)
                done = done | (tok[:, 0] == self.eos_id)
                outs.append(tok)
                self.host_syncs += 1          # the per-token round-trip
                if bool(done.all()):
                    break
            else:
                outs.append(tok)
        return jnp.concatenate(outs, axis=1)


# --------------------------------------------------------------------------
# constructor surface (launcher / Trainer.serve)
# --------------------------------------------------------------------------

def make_engine(cfg, params, *, engine="continuous", batch_size=4,
                max_len=256, dtype=jnp.float32, eos_id=None,
                sampling: SamplingConfig = SamplingConfig(), seed=0,
                **kw):
    """Build a serving engine over an in-memory param pytree.

    engine="continuous" — paged-cache ContinuousScheduler (extra kw:
    page_size, num_pages, prefill_chunk, decode_chunk, pad_id);
    engine="legacy" — the lockstep ServeEngine reference.
    """
    if engine == "continuous":
        return ContinuousScheduler(cfg, params, slots=batch_size,
                                   max_len=max_len, dtype=dtype,
                                   eos_id=eos_id, sampling=sampling,
                                   seed=seed, **kw)
    if engine == "legacy":
        if kw:
            raise TypeError(f"legacy engine takes no {sorted(kw)}")
        return ServeEngine(cfg, params, batch_size=batch_size,
                           max_len=max_len, dtype=dtype, eos_id=eos_id,
                           sampling=sampling, seed=seed)
    raise ValueError(f"unknown engine {engine!r} "
                     "(expected 'continuous' or 'legacy')")


def make_engine_from_checkpoint(ckpt_dir, cfg, *, step=None, key=None,
                                **engine_kw):
    """Close the train-and-serve loop: serve the params of a checkpoint
    written by the training stack — sharded (any registered layout:
    replicated/zero1/zero2/zero3/custom) or legacy npz — restored
    read-only on host (``checkpoint.restore_serve_params``), no
    optimizer state, no device gather.  Returns the engine."""
    from repro.checkpoint import restore_serve_params  # lazy: keep
    from repro.models import init_model                # serve import light

    key = key if key is not None else jax.random.PRNGKey(0)
    template = jax.eval_shape(functools.partial(init_model, cfg), key)
    params, at = restore_serve_params(ckpt_dir, template, step)
    params = jax.tree_util.tree_map(jnp.asarray, params)
    eng = make_engine(cfg, params, **engine_kw)
    eng.restored_step = at
    return eng
