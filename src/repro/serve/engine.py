"""Serving: prefill / decode step factories + a small batched engine.

``decode_step`` is what the decode_32k / long_500k dry-run shapes lower:
ONE new token per sequence against a KV cache of ``seq_len``.  Cache
layout and sharding come from sharding.rules (seq dim over "model" so
32k-per-sequence caches fit per-chip HBM; batch over "data"/"pod").

``ServeEngine`` is the host-side continuous-batching loop used by the
examples: greedy sampling, per-slot position tracking, EOS retirement.
It is deliberately simple (static batch slots) but exercises the same
compiled steps a production frontend would.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import apply_model, init_cache
from repro.serve.sampling import SamplingConfig, sample


def make_prefill_step(cfg):
    def prefill(params, batch, cache):
        out = apply_model(cfg, params, batch, mode="prefill", cache=cache,
                          cache_pos=0, last_only=True)
        # next-token logits at the last position of each sequence
        return out["logits"][:, -1], out["cache"]
    return prefill


def make_decode_step(cfg):
    def decode(params, tokens, cache, cache_pos):
        out = apply_model(cfg, params, {"tokens": tokens}, mode="decode",
                          cache=cache, cache_pos=cache_pos)
        return out["logits"][:, -1], out["cache"]
    return decode


class ServeEngine:
    """Batched generation over fixed slots: greedy or sampled
    (temperature / top-k / nucleus via SamplingConfig)."""

    def __init__(self, cfg, params, *, batch_size, max_len,
                 dtype=jnp.bfloat16, eos_id: Optional[int] = None,
                 sampling: SamplingConfig = SamplingConfig(),
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.eos_id = eos_id
        self.sampling = sampling
        self._key = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, batch_size, max_len, dtype)
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg))
        self._sample = jax.jit(
            functools.partial(sample, sc=sampling))

    def _next(self, logits):
        self._key, sub = jax.random.split(self._key)
        return self._sample(logits, sub)[:, None]

    def generate(self, prompts, max_new_tokens: int):
        """prompts: (B, S0) int32 — same length (pad upstream)."""
        logits, self.cache = self._prefill(
            self.params, {"tokens": prompts}, self.cache)
        pos = prompts.shape[1]
        tok = self._next(logits)
        outs = [tok]
        done = jnp.zeros((prompts.shape[0],), bool)
        for _ in range(max_new_tokens - 1):
            logits, self.cache = self._decode(self.params, tok, self.cache,
                                              pos)
            pos += 1
            tok = self._next(logits)
            if self.eos_id is not None:
                done = done | (tok[:, 0] == self.eos_id)
                if bool(done.all()):
                    outs.append(tok)
                    break
            outs.append(tok)
        return jnp.concatenate(outs, axis=1)
