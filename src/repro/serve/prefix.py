"""Prefix/radix cache: page-table aliasing over the paged KV pool.

Production traffic is templated — tenants share system prompts — yet a
cache-less scheduler re-prefills the same KV pages for every request.
This module keeps a **radix tree over prompt-token pages**: each edge
is one FULL page of prompt tokens (a ``page_size``-tuple of ids), each
node holds the physical pool page that a previous request's prefill
already wrote for exactly that token prefix.  Admission walks the tree
(``match``), aliases the matched pages into the new slot's page table
(``PagedKVCache.alias`` — refcount +1 per page, zero bytes moved), and
prefills only the unmatched suffix: TTFT for templated traffic becomes
the cost of the suffix, not the prompt.

Correctness hinges on three invariants, all enforced here or in
``kvcache``:

* **Content-addressed, position-dependent.** A page's KV bytes depend
  only on the token prefix up to and including that page (per-token
  projections + causal attention over earlier, identical pages), so a
  radix match — identical token pages from position 0 — is exactly the
  condition under which aliasing is bitwise-safe.  Matching starts at
  the root: there is no mid-prompt sharing.
* **Shared pages are read-only.** Writers fork first
  (``PagedKVCache.cow_fork``): the one serving path that must write
  into a matched page — a fully-matched, page-aligned prompt
  re-prefilling its final token to obtain logits — copies the page and
  writes the private copy.  The radix tree keeps indexing the shared
  original.
* **Page 0 never enters the tree.** The trash page is never allocated,
  so no slot's owned pages (the only thing ``insert`` indexes) can
  contain it; ``insert`` asserts anyway.

The tree holds ONE reference per indexed page (taken at ``insert``,
dropped at eviction), so pages outlive the request that wrote them and
future requests can alias them.  Under pool pressure ``evict`` trims
least-recently-matched leaves — interior nodes only become evictable
once their children go, preserving the invariant that every cached
chain is rooted (a match never dangles).

Only attention/MLA architectures can use the cache: a recurrent (SSM)
mixer's state at the suffix boundary is not captured by KV pages, so
``ContinuousScheduler`` refuses ``prefix_cache=True`` for hybrids.

Mesh-safety: aliasing edits only the HOST page table, and page tables
are replicated per data-replica while pool feature axes shard over
``"model"`` (``sharding.rules.pool_spec``) — every device sees the
same table and reads its own shard of the shared page, so the radix
cache composes with ``mesh=`` serving by construction
(``tests/test_serve_mesh.py`` pins it).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

__all__ = ["PrefixCache"]


class _Node:
    __slots__ = ("page", "children", "parent", "key", "tick")

    def __init__(self, page: Optional[int], parent, key):
        self.page = page          # physical pool page (None at the root)
        self.children = {}        # page-token tuple -> _Node
        self.parent = parent
        self.key = key
        self.tick = 0


class PrefixCache:
    """Radix tree over prompt pages, backed by a ``PagedKVCache``.

    The cache does not own device memory: it indexes pages the pool
    already holds and manages their lifetime purely through the pool's
    refcounts (one reference per indexed page).
    """

    def __init__(self, kv):
        self.kv = kv
        self.root = _Node(None, None, None)
        self._tick = 0
        self._nodes = 0
        # telemetry: admission-level hit accounting
        self.hits = 0             # lookups that matched >= 1 page
        self.misses = 0
        self.hit_tokens = 0       # prompt tokens covered by matches
        self.lookup_tokens = 0    # prompt tokens seen by lookups
        self.evictions = 0

    # ---- lookup / admission ---------------------------------------------
    def _keys(self, prompt) -> List[tuple]:
        ps = self.kv.page_size
        prompt = np.asarray(prompt).reshape(-1)
        return [tuple(int(t) for t in prompt[i:i + ps])
                for i in range(0, len(prompt) - ps + 1, ps)]

    def match(self, prompt) -> Tuple[int, List[int]]:
        """Longest cached page-chain equal to the prompt's leading full
        pages.  Returns ``(n_tokens_matched, pages)`` — the pages are
        LIVE (refcount >= 1, held by the tree); alias them into a slot
        before anything can evict them."""
        self._tick += 1
        node, pages = self.root, []
        for key in self._keys(prompt):
            child = node.children.get(key)
            if child is None:
                break
            child.tick = self._tick
            pages.append(child.page)
            node = child
        n_tok = len(pages) * self.kv.page_size
        self.lookup_tokens += len(np.asarray(prompt).reshape(-1))
        self.hit_tokens += n_tok
        if pages:
            self.hits += 1
        else:
            self.misses += 1
        return n_tok, pages

    def insert(self, prompt, slot_pages) -> int:
        """Index the prompt's full pages (``slot_pages`` = the slot's
        owned pages, in block order, after its prefill completed).
        Existing chains are kept — if two identical prompts prefilled
        before either inserted, the first chain wins and the second
        request's duplicate pages simply retire with its slot.  Returns
        the number of NEW nodes (references taken)."""
        self._tick += 1
        node, added = self.root, 0
        for i, key in enumerate(self._keys(prompt)):
            child = node.children.get(key)
            if child is None:
                page = int(slot_pages[i])
                if page == 0:
                    raise ValueError("page 0 (trash) can never enter the "
                                     "radix tree")
                self.kv.retain(page)
                child = _Node(page, node, key)
                node.children[key] = child
                self._nodes += 1
                added += 1
            child.tick = self._tick
            node = child
        return added

    # ---- eviction --------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def evict_one(self) -> bool:
        """Drop the least-recently-matched LEAF (deepest page of its
        chain): release the tree's reference so the page returns to the
        free list unless a live slot still aliases it.  Returns False
        when the tree is empty."""
        leaves = self._leaves()
        if not leaves:
            return False
        victim = min(leaves, key=lambda n: n.tick)
        del victim.parent.children[victim.key]
        self.kv.release(victim.page)
        self._nodes -= 1
        self.evictions += 1
        return True

    def evict(self, need_pages: int) -> int:
        """Evict until the pool has ``need_pages`` free (or the tree is
        dry).  Returns pages actually freed to the pool — evicting a
        page a live slot still aliases only drops the tree's reference,
        so callers re-check ``kv.free_pages``."""
        freed0 = self.kv.free_pages
        while self.kv.free_pages < need_pages and self.evict_one():
            pass
        return self.kv.free_pages - freed0

    # ---- introspection ---------------------------------------------------
    @property
    def nodes(self) -> int:
        return self._nodes

    def pages(self) -> List[int]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            out.append(n.page)
            stack.extend(n.children.values())
        return out

    def stats(self) -> dict:
        return {
            "nodes": self._nodes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "hit_rate": (self.hit_tokens / self.lookup_tokens
                         if self.lookup_tokens else 0.0),
        }
