"""Paged KV cache: fixed-size blocks, per-slot page tables, alloc/free.

The static-slot engine reserves ``slots x max_len`` of KV HBM up front,
so one long-context slot pays for its worst case even while it is
short.  Here attention/MLA K/V live in ONE token-major pool per layer
(``models.init_cache(pool=(num_pages, page_size))``) and each serving
slot owns only the pages it has been allocated; the per-slot *page
table* maps logical token positions to physical pool slots and the
model decode path reads through it (``models.attention.PagedView``).

Layout
------
* pool leaf      — ``(num_pages * page_size, kv_heads, head_dim)``
                   (MLA: ``(N, r)``), no batch axis;
* page table     — ``(slots, table_width)`` int32, ``table_width =
                   ceil(max_len / page_size)``;
* page 0         — reserved trash page: never allocated, the write sink
                   for idle slots (all-zero table rows) and padded
                   prefill lanes;
* SSM states     — recurrent state is O(1) in context, so mamba/rwkv
                   leaves stay per-slot ``(slots, ...)`` and are zeroed
                   when a slot is (re)admitted.

Allocation is plain host-side bookkeeping (a free list); the device
only ever sees the table.  ``alloc``/``free`` happen on request
admit/retire in ``serve.scheduler``.

Pages are REFCOUNTED so the prefix/radix cache (``serve.prefix``) can
alias one physical page into many page tables: ``alloc`` hands out
pages at refcount 1, ``alias`` maps already-written pages into another
slot's table (+1 each), ``retain``/``release`` are the raw ops (the
radix tree itself holds a reference on every page it indexes), and
``free`` DECREMENTS — a page returns to the free list only when its
last reference drops.  ``cow_fork`` is the copy-on-write: when a slot
must write into a page it shares (a fully-matched prompt re-writing
its final token), the block is re-pointed at a fresh page whose bytes
are device-copied from the shared one; the shared page's bytes are
never touched.  Page 0 (trash) is never allocated, aliased, or
refcounted.

Mesh sharding: pass ``mesh=`` and the pooled leaves are allocated with
a ``NamedSharding`` from ``sharding.rules.pool_spec`` — feature axes
(heads / head_dim / MLA latent) over ``"model"``, the token axis whole
per data-replica, per-slot SSM leaves and the page table replicated.
Pool bytes per device then drop ~1/model_size
(``pool_bytes_per_device`` / ``pool_bytes_by_device`` record it); the
host-mesh path (``mesh=None``) is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache
from repro.models.attention import PagedView

__all__ = ["PagedView", "PagedKVCache"]


def _tree_shapes(cfg, slots, max_len, dtype, pool):
    return jax.eval_shape(
        lambda: init_cache(cfg, slots, max_len, dtype, pool=pool))


@dataclasses.dataclass
class PagedKVCache:
    """Device pool + host page bookkeeping for one serving batch."""
    cfg: object
    slots: int
    max_len: int
    page_size: int = 16
    num_pages: Optional[int] = None      # default: slots*max_len worth + trash
    dtype: object = jnp.float32
    mesh: object = None                  # None: host path (unsharded pool)

    def __post_init__(self):
        if self.max_len % self.page_size:
            raise ValueError(
                f"max_len={self.max_len} must be a multiple of "
                f"page_size={self.page_size} (the gather width is the "
                "table span; keep it page-aligned)")
        self.table_width = self.max_len // self.page_size
        if self.num_pages is None:
            self.num_pages = self.slots * self.table_width + 1
        if self.num_pages < 2:
            raise ValueError("need at least one real page beyond the "
                             "reserved trash page 0")
        pool = (self.num_pages, self.page_size)
        self.cache = init_cache(self.cfg, self.slots, self.max_len,
                                self.dtype, pool=pool)
        # which leaves are per-slot (SSM state) vs pooled, and on WHICH
        # axis the slot dim sits (scanned super-block leaves carry a
        # leading n_rep axis): probed via eval_shape against slots+1 —
        # shape-sniffing would confuse slots==pool sizes
        a = _tree_shapes(self.cfg, self.slots, self.max_len, self.dtype, pool)
        b = _tree_shapes(self.cfg, self.slots + 1, self.max_len, self.dtype,
                         pool)

        def slot_axis(x, y):
            for i, (m, n) in enumerate(zip(x.shape, y.shape)):
                if m != n:
                    return i
            return -1                         # pooled leaf

        self.slot_axis = jax.tree_util.tree_map(slot_axis, a, b)
        if self.mesh is not None:
            # pooled leaves land model-sharded on the serve mesh; the
            # per-slot leaves' NamedSharding is an explicit replicated
            # placement (tests sweep addressable shards per device)
            from repro.sharding.rules import pool_shardings
            self.shardings = pool_shardings(self.cfg, self.mesh, a,
                                            self.slot_axis)
            self.cache = jax.tree_util.tree_map(jax.device_put, self.cache,
                                                self.shardings)
        else:
            self.shardings = None
        self._table = np.zeros((self.slots, self.table_width), np.int32)
        self._free = list(range(self.num_pages - 1, 0, -1))  # stack, no 0
        self._owned = {s: [] for s in range(self.slots)}
        self._refs: dict = {}                # page -> refcount (live only)

    # ---- host bookkeeping -----------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return sum(len(v) for v in self._owned.values())

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_alloc(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= len(self._free)

    def alloc(self, slot: int, n_tokens: int) -> None:
        """Ensure `slot` owns pages for a TOTAL of `n_tokens` tokens:
        tops up incrementally past its current allocation (a no-op when
        already covered); updates the slot's table row."""
        have = len(self._owned[slot]) * self.page_size
        need = self.pages_needed(max(0, n_tokens - have))
        if need > len(self._free):
            raise MemoryError(
                f"paged KV pool exhausted: slot {slot} needs {need} more "
                f"pages, {len(self._free)} free of {self.num_pages - 1}")
        if len(self._owned[slot]) + need > self.table_width:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceeds max_len="
                f"{self.max_len}")
        for _ in range(need):
            p = self._free.pop()
            self._refs[p] = 1
            self._table[slot, len(self._owned[slot])] = p
            self._owned[slot].append(p)

    def free(self, slot: int) -> None:
        """Drop the slot's reference on every page it maps and point
        its table row at the trash page, so any in-flight writes land
        harmlessly.  Shared (aliased) pages survive under their other
        references; exclusively-owned pages return to the free list."""
        for p in reversed(self._owned[slot]):
            self.release(p)
        self._owned[slot] = []
        self._table[slot] = 0

    # ---- refcounts / prefix aliasing ------------------------------------
    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def retain(self, page: int) -> None:
        """Add a reference to a live page (the radix tree holds one per
        indexed page; ``alias`` calls this per mapped page)."""
        if page == 0:
            raise ValueError("page 0 is the trash sink; never retained")
        if self._refs.get(page, 0) <= 0:
            raise ValueError(f"retain of dead page {page} (double-free "
                             "guard: it is not live)")
        self._refs[page] += 1

    def release(self, page: int) -> None:
        """Drop a reference; the page returns to the free list when the
        last one goes.  Releasing a dead page raises (double-free)."""
        if page == 0:
            raise ValueError("page 0 is the trash sink; never released")
        r = self._refs.get(page, 0)
        if r <= 0:
            raise ValueError(f"double free of page {page}")
        if r == 1:
            del self._refs[page]
            self._free.append(page)
        else:
            self._refs[page] = r - 1

    def alias(self, slot: int, pages) -> None:
        """Map already-written shared pages into `slot`'s table (the
        prefix-cache admission path): appended after the slot's current
        blocks, one reference taken per page.  The pages' bytes are
        NOT copied — the slot reads them through its table and must
        never write into them (``cow_fork`` first if it has to)."""
        if len(self._owned[slot]) + len(pages) > self.table_width:
            raise ValueError(
                f"slot {slot}: aliasing {len(pages)} pages past "
                f"table_width={self.table_width}")
        for p in pages:
            self.retain(p)               # rejects page 0 / dead pages
            self._table[slot, len(self._owned[slot])] = p
            self._owned[slot].append(p)

    def cow_fork(self, slot: int, block: int) -> int:
        """Copy-on-write: give `slot` a PRIVATE copy of its `block`-th
        page.  Pops a fresh page, device-copies the shared page's rows
        into it across every pooled leaf (the shared bytes are never
        written), re-points the table entry, and drops the slot's
        reference on the shared page.  Callers fork exactly when
        ``refcount(page) > 1`` — forking an exclusive page would waste
        a copy for nothing."""
        old = self._owned[slot][block]
        if not self._free:
            raise MemoryError(f"paged KV pool exhausted: COW fork of "
                              f"slot {slot} block {block} needs a free "
                              "page")
        new = self._free.pop()
        self._refs[new] = 1
        ps = self.page_size
        N = self.num_pages * ps

        def copy_page(x, ax):
            if ax >= 0:
                return x                     # per-slot leaf: not paged
            # pooled leaves are token-major (N, ...), but scanned
            # super-block leaves carry a leading n_rep axis — find the
            # pool-token axis by its size
            tok = 0 if x.shape[0] == N else 1
            assert x.shape[tok] == N, (x.shape, N)
            rows = jax.lax.slice_in_dim(x, old * ps, (old + 1) * ps,
                                        axis=tok)
            return jax.lax.dynamic_update_slice_in_dim(
                x, rows, new * ps, axis=tok)

        self.cache = jax.tree_util.tree_map(copy_page, self.cache,
                                            self.slot_axis)
        if self.shardings is not None:   # keep the pool's mesh placement
            self.cache = jax.tree_util.tree_map(jax.device_put, self.cache,
                                                self.shardings)
        self._owned[slot][block] = new
        self._table[slot, block] = new
        self.release(old)
        return new

    @staticmethod
    def _row(ax: int, slot) -> tuple:
        return (slice(None),) * ax + (slot,)

    def reset_slot_state(self, slot: int) -> None:
        """Zero the per-slot recurrent (SSM) state rows on admit — the
        previous occupant's state must not leak into a new request."""
        self.cache = jax.tree_util.tree_map(
            lambda x, ax: x.at[self._row(ax, slot)].set(0) if ax >= 0
            else x, self.cache, self.slot_axis)

    # ---- device views ----------------------------------------------------
    def table(self, rows=None):
        """Device page table — all slots, or a (len(rows), W) subset."""
        t = self._table if rows is None else self._table[list(rows)]
        return jnp.asarray(t)

    def view(self, rows=None) -> PagedView:
        return PagedView(self.table(rows), self.page_size)

    def slot_cache(self, slot: int):
        """B=1 cache view for a single-slot (prefill) model call:
        per-slot leaves are sliced to one row (on their slot axis —
        scanned-block leaves carry a leading n_rep axis), pooled leaves
        shared."""
        return jax.tree_util.tree_map(
            lambda x, ax: jax.lax.slice_in_dim(x, slot, slot + 1, axis=ax)
            if ax >= 0 else x, self.cache, self.slot_axis)

    def merge_slot_cache(self, slot: int, new_cache) -> None:
        """Write a B=1 call's result back: pooled leaves replace the
        pool (the call scattered into it), per-slot rows land at
        `slot`."""
        self.cache = jax.tree_util.tree_map(
            lambda old, new, ax: old.at[self._row(ax, slot)].set(
                jnp.squeeze(new, axis=ax)) if ax >= 0 else new,
            self.cache, new_cache, self.slot_axis)

    # ---- accounting ------------------------------------------------------
    def pool_bytes(self) -> int:
        """Resident bytes of the pooled (paged) leaves."""
        tot = 0
        for leaf, ax in zip(jax.tree_util.tree_leaves(self.cache),
                            jax.tree_util.tree_leaves(self.slot_axis)):
            if ax < 0:
                tot += leaf.size * leaf.dtype.itemsize
        return tot

    def pool_bytes_by_device(self) -> dict:
        """Resident pooled bytes per addressable device — the live-buffer
        sweep: under a serve mesh no single device holds the full pool
        (each holds ~pool_bytes/model_size)."""
        per: dict = {}
        for leaf, ax in zip(jax.tree_util.tree_leaves(self.cache),
                            jax.tree_util.tree_leaves(self.slot_axis)):
            if ax >= 0:
                continue
            if hasattr(leaf, "addressable_shards"):
                for sh in leaf.addressable_shards:
                    per[sh.device] = (per.get(sh.device, 0)
                                      + sh.data.size * leaf.dtype.itemsize)
            else:
                per[None] = per.get(None, 0) + leaf.size * leaf.dtype.itemsize
        return per

    def pool_bytes_per_device(self) -> int:
        """Max pooled bytes on any one device (== ``pool_bytes()`` on the
        host path; ~1/model_size of it under a serve mesh)."""
        per = self.pool_bytes_by_device()
        return max(per.values()) if per else 0

    def slab_bytes(self) -> int:
        """What the same slots would reserve as a static slab
        (slots x max_len), for the HBM-saving story."""
        slab = jax.eval_shape(lambda: init_cache(
            self.cfg, self.slots, self.max_len, self.dtype))
        paged = _tree_shapes(self.cfg, self.slots, self.max_len,
                             self.dtype, (self.num_pages, self.page_size))
        tot = 0
        for s, p in zip(jax.tree_util.tree_leaves(slab),
                        jax.tree_util.tree_leaves(paged)):
            if s.shape != p.shape:        # pooled in the paged build
                tot += s.size * np.dtype(s.dtype).itemsize
        return tot
