from repro.serve.engine import (
    make_prefill_step, make_decode_step, ServeEngine, make_engine,
    make_engine_from_checkpoint,
)
from repro.serve.frontdoor import FrontDoor, StreamHandle
from repro.serve.kvcache import PagedKVCache, PagedView
from repro.serve.prefix import PrefixCache
from repro.serve.sampling import SamplingConfig, sample, masked_sample
from repro.serve.scheduler import ContinuousScheduler, ServeRequest

__all__ = [
    "make_prefill_step", "make_decode_step", "ServeEngine",
    "make_engine", "make_engine_from_checkpoint",
    "FrontDoor", "StreamHandle",
    "PagedKVCache", "PagedView", "PrefixCache",
    "SamplingConfig", "sample", "masked_sample",
    "ContinuousScheduler", "ServeRequest",
]
