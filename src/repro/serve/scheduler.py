"""Continuous-batching scheduler over the paged KV cache.

Replaces ``ServeEngine``'s lockstep ``generate`` for production-shaped
serving: a request queue, slot admission the moment a slot retires,
chunked prefill interleaved with decode, and a FUSED device-side decode
loop (``jax.lax.scan`` over sample→decode with on-device EOS masking)
that costs ONE dispatch + ONE host sync per ``decode_chunk`` tokens —
the legacy engine pays a blocking host round-trip per token.

Request lifecycle::

    QUEUED     submit() appended it; waiting for a slot + pages
    PREFILL    admitted: pages allocated, SSM state zeroed, prompt fed
               in `prefill_chunk`-token chunks (B=1 calls that scatter
               into the shared pool), first token sampled from the last
               chunk's logits
    DECODE     slot participates in the fused batched decode loop
    RETIRED    EOS emitted (device-detected) or token budget reached
               (host-detected): pages freed, table row -> trash, the
               next queued request admits into the slot

Greedy outputs are bitwise-identical to the legacy slab engine per
request (same einsum shapes, same masking value; extra gather width
only ever adds exactly-zero softmax terms), which
``tests/test_serve.py`` pins both lockstep and staggered.

Mesh serving: pass ``mesh=`` (a ``(data, model)`` serve mesh — the
production topology) and the engine becomes mesh-native: params are
placed with the serve-mode parameter shardings, the paged pool is
allocated model-sharded (``sharding.rules.pool_spec``), every compiled
call runs under the scoped serve topology (``sharding.ctx.
serve_topology``) so activation constraints and the expert-parallel
MoE ``shard_map`` dispatch engage, and the pool's sharding is pinned
through prefill and the fused loop with explicit constraints.  The
host-sync discipline is UNCHANGED — still one blocking sync per decode
chunk; scheduling stays host-side bookkeeping either way.

Not supported here (use ``ServeEngine``/``apply_model`` directly):
encoder-decoder and vision-frontend architectures.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import apply_model
from repro.models.attention import PagedView
from repro.serve.kvcache import PagedKVCache
from repro.serve.sampling import SamplingConfig, masked_sample, sample
from repro.sharding import ctx as shctx

__all__ = ["ServeRequest", "ContinuousScheduler"]


@dataclasses.dataclass
class ServeRequest:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: Optional[float] = None    # time-to-first-token timestamp
    t_done: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit


class ContinuousScheduler:
    """Continuous batching over ``slots`` fixed batch lanes.

    cfg/params   — model config + host/device param pytree.
    slots        — decode batch width (lanes).
    max_len      — per-slot logical context bound (page-aligned).
    page_size    — tokens per KV page.
    num_pages    — pool size; default slots*max_len/page_size + trash,
                   i.e. no saving — size it DOWN to the live-token
                   budget to realise the paged-HBM win.
    eos_id       — on-device EOS detection; None = budget-only.
    pad_id       — what retired slots emit (default: eos_id or 0).
    prefill_chunk/decode_chunk — scheduling granularity: prompt tokens
                   per prefill call; decoded tokens per fused loop.
    mesh         — optional serve mesh; when set, params and the paged
                   pool are placed model-sharded and every compiled call
                   runs under the scoped serve topology.
    """

    def __init__(self, cfg, params, *, slots, max_len, dtype=jnp.float32,
                 eos_id: Optional[int] = None, pad_id: Optional[int] = None,
                 sampling: SamplingConfig = SamplingConfig(), seed: int = 0,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 32, decode_chunk: int = 8,
                 mesh: object = None):
        if cfg.is_encoder_decoder or cfg.frontend != "none":
            raise ValueError("continuous batching drives decoder-only "
                             "text architectures")
        self.cfg = cfg
        self.mesh = mesh
        self._topo = (None if mesh is None
                      else shctx.ServeTopology.from_mesh(mesh))
        if mesh is not None:
            from repro.sharding.rules import ShardingConfig, param_shardings
            shapes = jax.eval_shape(lambda: params)
            params = jax.device_put(
                params,
                param_shardings(cfg, mesh, shapes,
                                ShardingConfig.for_mode("serve")))
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        if pad_id is None:
            pad_id = eos_id if eos_id is not None else 0
        self.pad_id = pad_id
        self.sampling = sampling
        self.prefill_chunk = prefill_chunk
        self.decode_chunk = decode_chunk
        self.kv = PagedKVCache(cfg, slots=slots, max_len=max_len,
                               page_size=page_size, num_pages=num_pages,
                               dtype=dtype, mesh=mesh)
        self._key = jax.random.PRNGKey(seed)
        self._tok = jnp.zeros((slots, 1), jnp.int32)
        self._pos = jnp.zeros((slots,), jnp.int32)
        self._done_host = np.ones((slots,), bool)      # idle == done
        self._done = jnp.asarray(self._done_host)
        self._pending: collections.deque = collections.deque()
        self._active: Dict[int, ServeRequest] = {}
        self._results: Dict[int, ServeRequest] = {}
        self._uid = 0
        # ---- telemetry ----
        self._ttft: List[float] = []   # survives run()'s result handoff
        self.host_syncs = 0            # blocking device->host pulls
        self.dispatches = 0            # compiled-call launches
        self.tokens_out = 0
        self._build_steps()

    # ------------------------------------------------------------------
    # compiled steps
    # ------------------------------------------------------------------
    def _build_steps(self):
        cfg, page_size = self.cfg, self.kv.page_size
        sc = self.sampling
        eos_id, pad_id = self.eos_id, self.pad_id
        K = self.decode_chunk
        shardings = self.kv.shardings

        def pin(cache):
            """Re-assert the pool's placement on a cache RESULT so GSPMD
            cannot drift it (pooled leaves model-sharded, per-slot
            leaves replicated — the specs are rank-stable, so they also
            fit prefill's B=1 slot_cache slices).  Host path: no-op."""
            if shardings is None:
                return cache
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, cache, shardings)

        def prefill_chunk_fn(params, cache, table_row, tokens, pos):
            """B=1: scatter one prompt chunk into the pool; logits at
            the chunk's last position.  Chunks are EXACT (full chunks
            plus a ragged tail, one compile per distinct length) — a
            padded lane would be maskable for attention but would
            corrupt the per-slot recurrent SSM state, which integrates
            every token it sees."""
            view = PagedView(table_row, page_size)
            out = apply_model(cfg, params, {"tokens": tokens},
                              mode="decode", cache=cache, cache_pos=pos,
                              paged=view)
            return pin(out["cache"]), out["logits"][:, -1]

        def first_token_fn(logits, key):
            return sample(logits, key, sc=sc)[0].astype(jnp.int32)

        def decode_loop_fn(params, cache, table, tok, pos, done, key):
            """The fused loop: K sample→decode steps on device.  Done
            (and idle) slots emit `pad_id`, freeze their position, and
            — because their table rows are zero — scatter into the
            trash page."""
            view = PagedView(table, page_size)

            def body(carry, _):
                cache, tok, pos, done, key = carry
                out = apply_model(cfg, params, {"tokens": tok},
                                  mode="decode", cache=cache,
                                  cache_pos=pos, paged=view)
                logits = out["logits"][:, -1]
                key, sub = jax.random.split(key)
                nxt = masked_sample(logits, sub, done, pad_id, sc=sc)
                pos = pos + jnp.where(done, 0, 1)
                if eos_id is not None:
                    done = done | (nxt == eos_id)
                return (pin(out["cache"]), nxt[:, None], pos, done,
                        key), nxt

            carry, toks = jax.lax.scan(
                body, (cache, tok, pos, done, key), None, length=K)
            return carry + (toks.T,)          # (..., (slots, K))

        # donate the cache through prefill and the fused loop where the
        # backend supports it (CPU doesn't; donating there only warns).
        # Safe for prefill: the pooled leaves of the passed slot_cache
        # ARE the live pool (replaced by the returned one), while the
        # per-slot leaves are eager slices — merge_slot_cache never
        # reads the donated buffers.
        donate = () if jax.default_backend() == "cpu" else (1,)

        def scoped(fn):
            """Run a compiled step under the serve topology so trace-time
            dispatch (expert-parallel MoE shard_map, paged activation
            constraints) sees the mesh.  Host path: identity."""
            if self._topo is None:
                return fn

            def run(*a):
                with shctx.serve_topology(self._topo):
                    return fn(*a)
            return run

        self._prefill_fn = scoped(
            jax.jit(prefill_chunk_fn, donate_argnums=donate))
        self._first_fn = scoped(jax.jit(first_token_fn))
        self._decode_fn = scoped(
            jax.jit(decode_loop_fn, donate_argnums=donate))

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> int:
        """Queue one request; returns its uid."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            # reject HERE: admitted-then-failed would leak the slot's
            # pages (kv.free only runs at retirement)
            raise ValueError("empty prompt (need >= 1 token to prefill)")
        if len(prompt) + max_new_tokens + self.decode_chunk > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new_tokens}) + "
                f"decode_chunk slack ({self.decode_chunk}) exceeds "
                f"max_len={self.max_len}")
        uid = self._uid
        self._uid += 1
        self._pending.append(ServeRequest(uid, prompt, max_new_tokens,
                                          t_submit=time.time()))
        return uid

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue; returns {uid: generated tokens} for the
        requests completed by THIS drain (completed requests are handed
        off, not retained — a long-lived scheduler does not accumulate
        prompt/output arrays across batches)."""
        while self._pending or self._active:
            admitted = self._admit()
            if not self._active:
                if self._pending and not admitted:
                    head = self._pending[0]
                    raise MemoryError(
                        f"request {head.uid} ({len(head.prompt)} prompt "
                        f"tokens) cannot be admitted into an empty batch "
                        f"— pool too small ({self.kv.free_pages} free "
                        f"pages)")
                continue
            self._decode_tick()
        done, self._results = self._results, {}
        return {uid: np.asarray(r.out, np.int32)
                for uid, r in done.items()}

    def generate(self, prompts: Sequence, max_new_tokens: int):
        """Convenience facade: submit all, run, return outputs in
        submit order (list of 1-D int32 arrays)."""
        uids = [self.submit(p, max_new_tokens) for p in prompts]
        results = self.run()
        return [results[u] for u in uids]

    def stats(self) -> dict:
        return {
            "host_syncs": self.host_syncs,
            "dispatches": self.dispatches,
            "tokens_out": self.tokens_out,
            "syncs_per_token": (self.host_syncs / self.tokens_out
                                if self.tokens_out else 0.0),
            "ttft_s": list(self._ttft),
            "pool_pages_in_use": self.kv.pages_in_use,
            "pool_bytes": self.kv.pool_bytes(),
            "pool_bytes_per_device": self.kv.pool_bytes_per_device(),
            "slab_bytes_equiv": self.kv.slab_bytes(),
        }

    # ------------------------------------------------------------------
    # scheduling internals
    # ------------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self._active]

    def _admit(self) -> int:
        """Admit queued requests into free slots (FIFO; head-of-line
        blocks when the pool is out of pages).  Returns #admitted."""
        n = 0
        free = self._free_slots()
        while self._pending and free:
            req = self._pending[0]
            need = (len(req.prompt) + req.max_new_tokens
                    + self.decode_chunk)
            if not self.kv.can_alloc(need):
                break
            self._pending.popleft()
            slot = free.pop(0)
            self.kv.alloc(slot, need)
            self.kv.reset_slot_state(slot)
            self._prefill(slot, req)
            n += 1
        return n

    def _prefill(self, slot: int, req: ServeRequest):
        C = self.prefill_chunk
        S = len(req.prompt)
        table_row = self.kv.table([slot])
        logits = None
        for s in range(0, S, C):
            chunk = jnp.asarray(req.prompt[None, s:s + C])
            cache, logits = self._prefill_fn(
                self.params, self.kv.slot_cache(slot), table_row, chunk,
                jnp.full((1,), s, jnp.int32))
            self.kv.merge_slot_cache(slot, cache)
            self.dispatches += 1
        self._key, sub = jax.random.split(self._key)
        first = int(self._first_fn(logits, sub))
        self.dispatches += 1
        self.host_syncs += 1
        req.t_first = time.time()
        req.out.append(first)
        self.tokens_out += 1
        if (self.eos_id is not None and first == self.eos_id) \
                or req.max_new_tokens <= 1:
            self._retire(slot, req, active=False)
            return
        self._active[slot] = req
        self._tok = self._tok.at[slot].set(first)
        self._pos = self._pos.at[slot].set(S)
        self._done_host[slot] = False
        self._done = jnp.asarray(self._done_host)

    def _retire(self, slot: int, req: ServeRequest, *, active=True):
        req.t_done = time.time()
        if req.ttft is not None:
            self._ttft.append(req.ttft)
        self.kv.free(slot)
        if active:
            del self._active[slot]
        self._done_host[slot] = True
        self._done = jnp.asarray(self._done_host)
        self._results[req.uid] = req

    def _decode_tick(self):
        out = self._decode_fn(self.params, self.kv.cache, self.kv.table(),
                              self._tok, self._pos, self._done, self._key)
        self.kv.cache, self._tok, self._pos, self._done, self._key, toks = out
        self.dispatches += 1
        toks_np = np.asarray(toks)                     # ONE sync per tick
        self.host_syncs += 1
        for slot, req in list(self._active.items()):
            finished = False
            for t in toks_np[slot]:
                req.out.append(int(t))
                self.tokens_out += 1
                if self.eos_id is not None and t == self.eos_id:
                    finished = True
                    break
                if len(req.out) >= req.max_new_tokens:
                    finished = True
                    break
            if finished:
                self._retire(slot, req)
        # device `done` may be ahead of host bookkeeping (EOS slots we
        # also retired above); re-sync the mirror we own
        self._done = jnp.asarray(self._done_host)
