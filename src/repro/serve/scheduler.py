"""Continuous-batching scheduler over the paged KV cache.

Replaces ``ServeEngine``'s lockstep ``generate`` for production-shaped
serving: a request queue, slot admission the moment a slot retires,
chunked prefill interleaved with decode, and a FUSED device-side decode
loop (``jax.lax.scan`` over sample→decode with on-device EOS masking)
that costs ONE dispatch + ONE host sync per ``decode_chunk`` tokens —
the legacy engine pays a blocking host round-trip per token.

Request lifecycle::

    QUEUED     submit() enqueued it (priority-ordered; FIFO within a
               priority); waiting for a slot + pages + tenant quota
    PREFILL    admitted: cached prefix pages ALIASED into the page
               table (``prefix_cache=True`` — refcount +1 each, zero
               bytes moved), remaining pages allocated, SSM state
               zeroed, the UNMATCHED prompt suffix fed in
               `prefill_chunk`-token chunks (B=1 calls that scatter
               into the shared pool); the LAST chunk's compiled call
               also samples the first token — chunk + sample is one
               dispatch and one host sync, no separate sampling launch
    DECODE     slot participates in the fused batched decode loop
    RETIRED    EOS emitted (device-detected) or token budget reached
               (host-detected): the slot's page references dropped
               (shared pages survive under the radix tree's reference),
               table row -> trash, the next queued request admits into
               the slot

Admission replaces pure FIFO with priority order (higher ``priority``
first, submit order within a class) and per-tenant quotas
(``tenant_quota``: at most N concurrently-active slots per tenant —
quota-blocked requests are SKIPPED, not head-of-line blockers).  The
``tick()`` quantum (one admission pass + one fused decode tick) is the
streaming front door's pump: ``serve.frontdoor.FrontDoor`` wraps
``submit``/``tick``/``take_results`` into non-blocking submission with
per-request token streams.

Greedy outputs are bitwise-identical to the legacy slab engine per
request (same einsum shapes, same masking value; extra gather width
only ever adds exactly-zero softmax terms), which
``tests/test_serve.py`` pins both lockstep and staggered.

Speculative decode (``spec_decode=k``, greedy-only, needs
``cfg.mtp_depth > 0``): the fused loop body becomes draft→verify→accept
— the MTP head drafts ``k-1`` tokens from the last accepted hidden
state, ONE verify forward scores the k-token chunk through the paged
pool (the kernels' multi-token per-query-causal path), the longest
matching prefix is accepted on device, and rejected positions roll
back by rewinding per-slot ``cache_pos`` into already-allocated page
slack.  Dispatch discipline is unchanged — still one dispatch + one
host sync per ``decode_chunk`` scan steps — but each step now emits
1..k tokens, and greedy outputs stay bitwise-equal to the
non-speculative engine because every emitted token IS the verify
argmax.  See ``docs/serving.md`` § Speculative decode.

Mesh serving: pass ``mesh=`` (a ``(data, model)`` serve mesh — the
production topology) and the engine becomes mesh-native: params are
placed with the serve-mode parameter shardings, the paged pool is
allocated model-sharded (``sharding.rules.pool_spec``), every compiled
call runs under the scoped serve topology (``sharding.ctx.
serve_topology``) so activation constraints and the expert-parallel
MoE ``shard_map`` dispatch engage, and the pool's sharding is pinned
through prefill and the fused loop with explicit constraints.  The
host-sync discipline is UNCHANGED — still one blocking sync per decode
chunk; scheduling stays host-side bookkeeping either way.

Not supported here (use ``ServeEngine``/``apply_model`` directly):
encoder-decoder and vision-frontend architectures.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import apply_model, mtp_draft
from repro.models.attention import PagedView
from repro.serve.kvcache import PagedKVCache
from repro.serve.prefix import PrefixCache
from repro.serve.sampling import (
    SamplingConfig, accept_speculative, masked_sample, sample)
from repro.sharding import ctx as shctx

__all__ = ["ServeRequest", "ContinuousScheduler"]


@dataclasses.dataclass
class ServeRequest:
    uid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    priority: int = 0                  # higher admits first
    tenant: Optional[str] = None       # per-tenant quota key
    prefix_tokens: int = 0             # prompt tokens served from cache
    spec_steps: int = 0                # verify steps this request rode
    spec_accepted: int = 0             # draft tokens accepted for it
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: Optional[float] = None    # time-to-first-token timestamp
    t_done: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit


class ContinuousScheduler:
    """Continuous batching over ``slots`` fixed batch lanes.

    cfg/params   — model config + host/device param pytree.
    slots        — decode batch width (lanes).
    max_len      — per-slot logical context bound (page-aligned).
    page_size    — tokens per KV page.
    num_pages    — pool size; default slots*max_len/page_size + trash,
                   i.e. no saving — size it DOWN to the live-token
                   budget to realise the paged-HBM win.
    eos_id       — on-device EOS detection; None = budget-only.
    pad_id       — what retired slots emit (default: eos_id or 0).
    prefill_chunk/decode_chunk — scheduling granularity: prompt tokens
                   per prefill call; decoded tokens per fused loop.
    mesh         — optional serve mesh; when set, params and the paged
                   pool are placed model-sharded and every compiled call
                   runs under the scoped serve topology.
    prefix_cache — radix-tree prefix reuse (``serve.prefix``): matched
                   prompt pages are aliased instead of re-prefilled.
                   Attention/MLA architectures only (recurrent SSM
                   state is not captured by KV pages).
    tenant_quota — max concurrently-active slots per tenant: an int
                   (every tenant) or ``{tenant: n}`` dict (unlisted
                   tenants are unquota'd).  Quotas must be >= 1.
    spec_decode  — 0 = off; k >= 2 = speculative decode with k-token
                   verify chunks (the carried token + k-1 MTP drafts
                   per scan step).  Greedy-only (temperature must be 0
                   — lossless acceptance needs argmax targets) and
                   requires ``cfg.mtp_depth > 0``.
    """

    def __init__(self, cfg, params, *, slots, max_len, dtype=jnp.float32,
                 eos_id: Optional[int] = None, pad_id: Optional[int] = None,
                 sampling: SamplingConfig = SamplingConfig(), seed: int = 0,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 32, decode_chunk: int = 8,
                 mesh: object = None, prefix_cache: bool = False,
                 tenant_quota=None, spec_decode: int = 0):
        if cfg.is_encoder_decoder or cfg.frontend != "none":
            raise ValueError("continuous batching drives decoder-only "
                             "text architectures")
        if prefix_cache and any(mix != "attn"
                                for (mix, _f) in cfg.layer_pattern()):
            raise ValueError(
                "prefix_cache=True needs an attention/MLA-only stack: a "
                "recurrent (SSM) mixer's state at the suffix boundary is "
                "not captured by KV pages, so aliased prefixes would "
                "serve with a zeroed recurrent state")
        if tenant_quota is not None:
            vals = (tenant_quota.values()
                    if isinstance(tenant_quota, dict) else [tenant_quota])
            if any(int(v) < 1 for v in vals):
                raise ValueError("tenant_quota entries must be >= 1 (a "
                                 "0 quota deadlocks admission)")
        self.cfg = cfg
        self.mesh = mesh
        self._topo = (None if mesh is None
                      else shctx.ServeTopology.from_mesh(mesh))
        if mesh is not None:
            from repro.sharding.rules import ShardingConfig, param_shardings
            shapes = jax.eval_shape(lambda: params)
            params = jax.device_put(
                params,
                param_shardings(cfg, mesh, shapes,
                                ShardingConfig.for_mode("serve")))
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        if pad_id is None:
            pad_id = eos_id if eos_id is not None else 0
        self.pad_id = pad_id
        self.sampling = sampling
        self.prefill_chunk = prefill_chunk
        self.decode_chunk = decode_chunk
        self.spec_decode = int(spec_decode or 0)
        if self.spec_decode:
            if self.spec_decode < 2:
                raise ValueError(
                    "spec_decode counts the whole verify chunk (the "
                    "carried token + the drafts); k=1 is plain decode — "
                    "pass spec_decode >= 2 or 0")
            if cfg.mtp_depth <= 0:
                raise ValueError(
                    "spec_decode needs an architecture with MTP heads "
                    "(cfg.mtp_depth > 0) to draft from; this config has "
                    "none")
            if sampling.temperature > 0:
                raise ValueError(
                    "speculative decode is greedy-only: lossless "
                    "acceptance emits the verify argmax, which only "
                    "equals the engine's output at temperature=0")
        # decode-overshoot page slack per slot, beyond prompt+budget:
        # the fused loop may overrun the budget within a tick (host
        # truncation happens after the sync), and a rejected draft
        # additionally writes up to spec_decode-1 positions past the
        # last accepted one — all of it must land in allocated pages
        self._chunk_slack = (self.decode_chunk * self.spec_decode
                             + self.spec_decode
                             if self.spec_decode else self.decode_chunk)
        self.kv = PagedKVCache(cfg, slots=slots, max_len=max_len,
                               page_size=page_size, num_pages=num_pages,
                               dtype=dtype, mesh=mesh)
        self.prefix = PrefixCache(self.kv) if prefix_cache else None
        self.tenant_quota = tenant_quota
        self._key = jax.random.PRNGKey(seed)
        self._tok = jnp.zeros((slots, 1), jnp.int32)
        self._pos = jnp.zeros((slots,), jnp.int32)
        # trunk hidden at each slot's last accepted position — the MTP
        # draft head's input (speculative decode only; dead otherwise)
        self._hid = jnp.zeros((slots, cfg.d_model), jnp.dtype(cfg.dtype))
        self._done_host = np.ones((slots,), bool)      # idle == done
        self._done = jnp.asarray(self._done_host)
        self._pending: List[tuple] = []    # heap: (-priority, uid, req)
        self._active: Dict[int, ServeRequest] = {}
        self._results: Dict[int, ServeRequest] = {}
        self._byuid: Dict[int, ServeRequest] = {}      # submit -> handoff
        self._uid = 0
        # ---- telemetry ----
        self._ttft: List[float] = []   # window: reset at each run()
        self._ttft_n_cum = 0           # cumulative across the lifetime
        self._ttft_sum_cum = 0.0
        self.host_syncs = 0            # blocking device->host pulls
        self.dispatches = 0            # compiled-call launches
        # per-phase splits of the two aggregates above (the dispatch-
        # discipline microbenchmark and `launch.serve --report` read
        # these): prefill = chunk scatters + the fused first-token
        # sample; decode = fused loop ticks
        self.prefill_dispatches = 0
        self.prefill_host_syncs = 0
        self.decode_dispatches = 0
        self.decode_host_syncs = 0
        self.tokens_out = 0
        self.prefix_tokens_saved = 0   # prompt tokens served by aliasing
        self.prompt_tokens = 0
        # speculative-decode telemetry: acceptance is accepted/offered
        # drafts over live verify steps; per-slot arrays give the
        # accepted-length profile of each lane
        self.spec_verify_steps = 0
        self.spec_draft_tokens = 0     # offered: (k-1) per live step
        self.spec_accepted_tokens = 0
        self._spec_slot_steps = np.zeros((slots,), np.int64)
        self._spec_slot_accepted = np.zeros((slots,), np.int64)
        self._build_steps()

    # ------------------------------------------------------------------
    # compiled steps
    # ------------------------------------------------------------------
    def _build_steps(self):
        cfg, page_size = self.cfg, self.kv.page_size
        sc = self.sampling
        eos_id, pad_id = self.eos_id, self.pad_id
        K = self.decode_chunk
        shardings = self.kv.shardings

        def pin(cache):
            """Re-assert the pool's placement on a cache RESULT so GSPMD
            cannot drift it (pooled leaves model-sharded, per-slot
            leaves replicated — the specs are rank-stable, so they also
            fit prefill's B=1 slot_cache slices).  Host path: no-op."""
            if shardings is None:
                return cache
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, cache, shardings)

        def prefill_chunk_fn(params, cache, table_row, tokens, pos):
            """B=1: scatter one prompt chunk into the pool; logits at
            the chunk's last position.  Chunks are EXACT (full chunks
            plus a ragged tail, one compile per distinct length) — a
            padded lane would be maskable for attention but would
            corrupt the per-slot recurrent SSM state, which integrates
            every token it sees."""
            view = PagedView(table_row, page_size)
            out = apply_model(cfg, params, {"tokens": tokens},
                              mode="decode", cache=cache, cache_pos=pos,
                              paged=view)
            return pin(out["cache"]), out["logits"][:, -1]

        def prefill_last_fn(params, cache, table_row, tokens, pos, key):
            """The FINAL prompt chunk with the first-token sample fused
            into the same compiled call: chunk scatter + logits +
            sample is one dispatch, and the returned token is the one
            host sync of the whole prefill — the decode loop's
            dispatch discipline, applied to prefill's epilogue."""
            view = PagedView(table_row, page_size)
            out = apply_model(cfg, params, {"tokens": tokens},
                              mode="decode", cache=cache, cache_pos=pos,
                              paged=view)
            first = sample(out["logits"][:, -1], key,
                           sc=sc)[0].astype(jnp.int32)
            return pin(out["cache"]), first, out["hidden"][:, -1]

        def decode_loop_fn(params, cache, table, tok, pos, done, key):
            """The fused loop: K sample→decode steps on device.  Done
            (and idle) slots emit `pad_id`, freeze their position, and
            — because their table rows are zero — scatter into the
            trash page."""
            view = PagedView(table, page_size)

            def body(carry, _):
                cache, tok, pos, done, key = carry
                out = apply_model(cfg, params, {"tokens": tok},
                                  mode="decode", cache=cache,
                                  cache_pos=pos, paged=view)
                logits = out["logits"][:, -1]
                key, sub = jax.random.split(key)
                nxt = masked_sample(logits, sub, done, pad_id, sc=sc)
                pos = pos + jnp.where(done, 0, 1)
                if eos_id is not None:
                    done = done | (nxt == eos_id)
                return (pin(out["cache"]), nxt[:, None], pos, done,
                        key), nxt

            carry, toks = jax.lax.scan(
                body, (cache, tok, pos, done, key), None, length=K)
            return carry + (toks.T,)          # (..., (slots, K))

        spec_k = self.spec_decode

        def spec_loop_fn(params, cache, table, tok, pos, hid, done):
            """Draft→verify→accept fused loop: K scan steps, each
            emitting 1..k tokens for one model dispatch.  Per step: the
            MTP head drafts k-1 tokens from `hid` (the trunk hidden at
            the last accepted position), ONE verify forward scores the
            k-token chunk [tok, drafts] at positions pos..pos+k-1
            through the paged pool (per-query-causal multi-token path),
            and the longest matching prefix of the greedy targets is
            accepted.  Rollback is a cache_pos REWIND: rejected
            positions' K/V stay written in the slot's allocated slack,
            masked out by `kv_positions <= q_positions`, and the next
            chunk (k wide, starting at pos+acc+1) overwrites every
            stale position before any query can reach it — no page
            frees, no extra host syncs."""
            view = PagedView(table, page_size)
            lanes = jnp.arange(tok.shape[0])

            def body(carry, _):
                cache, tok, pos, hid, done = carry
                drafts, _ = mtp_draft(cfg, params, hid[:, None, :], tok,
                                      spec_k - 1)
                chunk = jnp.concatenate([tok, drafts], axis=1)   # (B, k)
                out = apply_model(cfg, params, {"tokens": chunk},
                                  mode="decode", cache=cache,
                                  cache_pos=pos, paged=view)
                tgt = jnp.argmax(out["logits"],
                                 axis=-1).astype(jnp.int32)      # (B, k)
                emit, n_emit, n_acc, done_new = accept_speculative(
                    tgt, chunk, done, pad_id, eos_id)
                pos = pos + jnp.where(done, 0, n_acc + 1)
                nxt = tgt[lanes, jnp.maximum(n_emit - 1, 0)]
                nxt = jnp.where(done_new, jnp.int32(pad_id), nxt)[:, None]
                hid = jnp.where(done_new[:, None], hid,
                                out["hidden"][lanes, n_acc])
                return (pin(out["cache"]), nxt, pos, hid,
                        done_new), (emit, n_emit)

            carry, (toks, counts) = jax.lax.scan(
                body, (cache, tok, pos, hid, done), None, length=K)
            # toks (K, B, k) -> (B, K, k); counts (K, B) -> (B, K)
            return carry + (jnp.transpose(toks, (1, 0, 2)), counts.T)

        # donate the cache through prefill and the fused loop where the
        # backend supports it (CPU doesn't; donating there only warns).
        # Safe for prefill: the pooled leaves of the passed slot_cache
        # ARE the live pool (replaced by the returned one), while the
        # per-slot leaves are eager slices — merge_slot_cache never
        # reads the donated buffers.
        donate = () if jax.default_backend() == "cpu" else (1,)

        def scoped(fn):
            """Run a compiled step under the serve topology so trace-time
            dispatch (expert-parallel MoE shard_map, paged activation
            constraints) sees the mesh.  Host path: identity."""
            if self._topo is None:
                return fn

            def run(*a):
                with shctx.serve_topology(self._topo):
                    return fn(*a)
            return run

        self._prefill_fn = scoped(
            jax.jit(prefill_chunk_fn, donate_argnums=donate))
        self._prefill_last_fn = scoped(
            jax.jit(prefill_last_fn, donate_argnums=donate))
        self._decode_fn = scoped(
            jax.jit(decode_loop_fn, donate_argnums=donate))
        self._spec_decode_fn = (
            scoped(jax.jit(spec_loop_fn, donate_argnums=donate))
            if spec_k else None)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, priority: int = 0,
               tenant: Optional[str] = None) -> int:
        """Queue one request; returns its uid.  Non-blocking: no device
        work happens until ``run()``/``tick()``.  Higher ``priority``
        admits first (submit order within a class); ``tenant`` keys the
        per-tenant quota."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            # reject HERE: admitted-then-failed would leak the slot's
            # pages (kv.free only runs at retirement)
            raise ValueError("empty prompt (need >= 1 token to prefill)")
        if len(prompt) + max_new_tokens + self._chunk_slack > self.max_len:
            # the slack term covers decode-tick overshoot — and, under
            # spec_decode, rejected-draft writes past the last accepted
            # position — so the fused loop can NEVER write beyond the
            # slot's allocated pages
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new_tokens}) + "
                f"decode slack ({self._chunk_slack}"
                f"{', spec_decode' if self.spec_decode else ''}) exceeds "
                f"max_len={self.max_len}")
        uid = self._uid
        self._uid += 1
        req = ServeRequest(uid, prompt, max_new_tokens, priority=priority,
                           tenant=tenant, t_submit=time.time())
        heapq.heappush(self._pending, (-priority, uid, req))
        self._byuid[uid] = req
        return uid

    def request(self, uid: int) -> ServeRequest:
        """Live view of a submitted request (the streaming front door
        reads ``req.out`` incrementally as ticks sync); valid until the
        request's result is handed off."""
        return self._byuid[uid]

    def tick(self) -> bool:
        """One scheduling quantum: an admission pass, then — if any
        slot is active — ONE fused decode tick (one dispatch + one host
        sync).  This is the streaming front door's pump.  Returns
        whether work remains (pending or active)."""
        admitted = self._admit()
        if self._active:
            self._decode_tick()
        elif self._pending and not admitted:
            # nothing active and nothing admissible: the best pending
            # request can never be served, even after prefix eviction
            req = min(self._pending)[2]
            raise MemoryError(
                f"request {req.uid} ({len(req.prompt)} prompt tokens) "
                f"cannot be admitted into an empty batch — pool too "
                f"small ({self.kv.free_pages} free pages)")
        return bool(self._active or self._pending)

    def take_results(self) -> Dict[int, ServeRequest]:
        """Hand off completed requests (and drop the uid index — a
        long-lived scheduler does not accumulate request arrays)."""
        done, self._results = self._results, {}
        for uid in done:
            self._byuid.pop(uid, None)
        return done

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue; returns {uid: generated tokens} for the
        requests completed by THIS drain.  The TTFT stats window resets
        here: ``stats()["ttft_s"]`` covers one drain, never re-reports
        earlier requests (cumulative counters keep the lifetime view).
        """
        self._ttft = []
        while self.tick():
            pass
        return {uid: np.asarray(r.out, np.int32)
                for uid, r in self.take_results().items()}

    def generate(self, prompts: Sequence, max_new_tokens: int):
        """Convenience facade: submit all, run, return outputs in
        submit order (list of 1-D int32 arrays)."""
        uids = [self.submit(p, max_new_tokens) for p in prompts]
        results = self.run()
        return [results[u] for u in uids]

    def stats(self) -> dict:
        st = {
            "host_syncs": self.host_syncs,
            "dispatches": self.dispatches,
            "prefill_dispatches": self.prefill_dispatches,
            "prefill_host_syncs": self.prefill_host_syncs,
            "decode_dispatches": self.decode_dispatches,
            "decode_host_syncs": self.decode_host_syncs,
            "tokens_out": self.tokens_out,
            "syncs_per_token": (self.host_syncs / self.tokens_out
                                if self.tokens_out else 0.0),
            "ttft_s": list(self._ttft),          # window: last/current run
            "ttft_count_cum": self._ttft_n_cum,  # lifetime counters
            "ttft_sum_cum_s": self._ttft_sum_cum,
            "prompt_tokens": self.prompt_tokens,
            "prefix_hit_tokens": self.prefix_tokens_saved,
            "prefix_hit_rate": (self.prefix_tokens_saved
                                / self.prompt_tokens
                                if self.prompt_tokens else 0.0),
            "pool_pages_in_use": self.kv.pages_in_use,
            "pool_bytes": self.kv.pool_bytes(),
            "pool_bytes_per_device": self.kv.pool_bytes_per_device(),
            "slab_bytes_equiv": self.kv.slab_bytes(),
        }
        if self.prefix is not None:
            st["prefix_cache"] = self.prefix.stats()
        if self.spec_decode:
            steps = self.spec_verify_steps
            st["spec_decode"] = {
                "k": self.spec_decode,
                "verify_steps": steps,
                "draft_tokens": self.spec_draft_tokens,
                "accepted_tokens": self.spec_accepted_tokens,
                # acceptance = accepted / offered drafts (budget- and
                # EOS-truncated steps count what the host consumed)
                "acceptance": (self.spec_accepted_tokens
                               / self.spec_draft_tokens
                               if self.spec_draft_tokens else 0.0),
                # emitted tokens per verify step = 1 + accepted
                "tokens_per_step": ((self.spec_accepted_tokens + steps)
                                    / steps if steps else 0.0),
                "slot_verify_steps": self._spec_slot_steps.tolist(),
                "slot_accepted_tokens":
                    self._spec_slot_accepted.tolist(),
                "slot_accepted_len": [
                    1.0 + (a / s) if s else 0.0
                    for a, s in zip(self._spec_slot_accepted,
                                    self._spec_slot_steps)],
            }
        return st

    # ------------------------------------------------------------------
    # scheduling internals
    # ------------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self._active]

    def _quota_of(self, tenant) -> Optional[int]:
        q = self.tenant_quota
        if q is None:
            return None
        if isinstance(q, dict):
            v = q.get(tenant)
            return None if v is None else int(v)
        return int(q)

    def _at_quota(self, tenant) -> bool:
        q = self._quota_of(tenant)
        if q is None:
            return False
        return sum(1 for r in self._active.values()
                   if r.tenant == tenant) >= q

    def _next_admissible(self) -> Optional[ServeRequest]:
        """Pop the highest-priority pending request whose tenant is
        under quota; quota-blocked requests are skipped (put back), not
        head-of-line blockers."""
        blocked = []
        req = None
        while self._pending:
            item = heapq.heappop(self._pending)
            if self._at_quota(item[2].tenant):
                blocked.append(item)
                continue
            req = item[2]
            break
        for item in blocked:
            heapq.heappush(self._pending, item)
        return req

    def _admit(self) -> int:
        """Admit queued requests into free slots in priority order.
        The free-slot set is recomputed every iteration: a prefill that
        retires at its very first token (EOS, or a 1-token budget)
        frees its slot MID-PASS, and the next queued request admits
        this tick instead of waiting out a full decode chunk.  Returns
        #admitted."""
        n = 0
        while self._pending:
            free = self._free_slots()
            if not free:
                break
            req = self._next_admissible()
            if req is None:                 # everything quota-blocked
                break
            if not self._try_admit(free[0], req):
                # pool pressure: the BEST admissible request waits, and
                # nothing below it may jump the page queue
                heapq.heappush(self._pending,
                               (-req.priority, req.uid, req))
                break
            n += 1
        return n

    def _try_admit(self, slot: int, req: ServeRequest) -> bool:
        """Alias + COW-fork + alloc + prefill one request into `slot`.
        Returns False (slot left clean) when the pool lacks pages even
        after prefix eviction."""
        S = len(req.prompt)
        matched, pages = (self.prefix.match(req.prompt)
                          if self.prefix is not None else (0, []))
        # always prefill >= 1 token — the last chunk's logits seed the
        # first sampled token
        start = min(matched, S - 1)
        # a fully-matched page-aligned prompt must re-write its final
        # token into a page it shares: copy-on-write fork of that page
        fork = bool(pages) and matched >= S
        total = self.kv.pages_needed(S + req.max_new_tokens
                                     + self._chunk_slack)
        fresh = total - len(pages) + (1 if fork else 0)
        # alias FIRST: the matched pages are now referenced by the slot,
        # so evicting their radix nodes below cannot free them under us
        self.kv.alias(slot, pages)
        if fresh > self.kv.free_pages and self.prefix is not None:
            self.prefix.evict(fresh)
        if fresh > self.kv.free_pages:
            self.kv.free(slot)              # roll the aliases back
            return False
        if fork:
            self.kv.cow_fork(slot, len(pages) - 1)
        self.kv.alloc(slot, S + req.max_new_tokens + self._chunk_slack)
        self.kv.reset_slot_state(slot)
        req.prefix_tokens = start
        self.prefix_tokens_saved += start
        self.prompt_tokens += S
        self._prefill(slot, req, start)
        return True

    def _prefill(self, slot: int, req: ServeRequest, start: int = 0):
        C = self.prefill_chunk
        S = len(req.prompt)
        table_row = self.kv.table([slot])
        starts = list(range(start, S, C))      # non-empty: start <= S-1
        for s in starts[:-1]:
            chunk = jnp.asarray(req.prompt[None, s:s + C])
            cache, _ = self._prefill_fn(
                self.params, self.kv.slot_cache(slot), table_row, chunk,
                jnp.full((1,), s, jnp.int32))
            self.kv.merge_slot_cache(slot, cache)
            self.dispatches += 1
            self.prefill_dispatches += 1
        # last chunk: sampling fused into the same compiled call —
        # no separate first-token launch
        s = starts[-1]
        self._key, sub = jax.random.split(self._key)
        chunk = jnp.asarray(req.prompt[None, s:s + C])
        cache, first_dev, h_last = self._prefill_last_fn(
            self.params, self.kv.slot_cache(slot), table_row, chunk,
            jnp.full((1,), s, jnp.int32), sub)
        self.kv.merge_slot_cache(slot, cache)
        self.dispatches += 1
        self.prefill_dispatches += 1
        if self.prefix is not None:
            # index the prompt's FULL pages (decode never writes them:
            # its first write position S lands in the next block)
            full = S // self.kv.page_size
            if full:
                self.prefix.insert(req.prompt, self.kv._owned[slot][:full])
        first = int(first_dev)                 # prefill's ONE host sync
        self.host_syncs += 1
        self.prefill_host_syncs += 1
        req.t_first = time.time()
        req.out.append(first)
        self.tokens_out += 1
        if (self.eos_id is not None and first == self.eos_id) \
                or req.max_new_tokens <= 1:
            self._retire(slot, req, active=False)
            return
        self._active[slot] = req
        self._tok = self._tok.at[slot].set(first)
        self._pos = self._pos.at[slot].set(S)
        if self.spec_decode:
            # seed the draft head: trunk hidden at the last prompt
            # position pairs with `first` exactly like train-mode MTP
            self._hid = self._hid.at[slot].set(h_last[0])
        self._done_host[slot] = False
        self._done = jnp.asarray(self._done_host)

    def _retire(self, slot: int, req: ServeRequest, *, active=True):
        req.t_done = time.time()
        if req.ttft is not None:
            self._ttft.append(req.ttft)
            self._ttft_n_cum += 1
            self._ttft_sum_cum += req.ttft
        self.kv.free(slot)
        if active:
            del self._active[slot]
        self._done_host[slot] = True
        self._done = jnp.asarray(self._done_host)
        self._results[req.uid] = req

    def _decode_tick(self):
        if self.spec_decode:
            self._spec_decode_tick()
            return
        out = self._decode_fn(self.params, self.kv.cache, self.kv.table(),
                              self._tok, self._pos, self._done, self._key)
        self.kv.cache, self._tok, self._pos, self._done, self._key, toks = out
        self.dispatches += 1
        self.decode_dispatches += 1
        toks_np = np.asarray(toks)                     # ONE sync per tick
        self.host_syncs += 1
        self.decode_host_syncs += 1
        for slot, req in list(self._active.items()):
            finished = False
            for t in toks_np[slot]:
                req.out.append(int(t))
                self.tokens_out += 1
                if self.eos_id is not None and t == self.eos_id:
                    finished = True
                    break
                if len(req.out) >= req.max_new_tokens:
                    finished = True
                    break
            if finished:
                self._retire(slot, req)
        # device `done` may be ahead of host bookkeeping (EOS slots we
        # also retired above); re-sync the mirror we own
        self._done = jnp.asarray(self._done_host)

    def _spec_decode_tick(self):
        """The speculative twin of the fused tick: same discipline (one
        dispatch, one host sync), but each of the ``decode_chunk`` scan
        steps emits 1..k tokens.  ``toks`` is (slots, K, k) with each
        step's emitted tokens left-packed; ``counts`` (slots, K) says
        how many are real (0 on done/idle lanes)."""
        out = self._spec_decode_fn(
            self.params, self.kv.cache, self.kv.table(), self._tok,
            self._pos, self._hid, self._done)
        (self.kv.cache, self._tok, self._pos, self._hid, self._done,
         toks, counts) = out
        self.dispatches += 1
        self.decode_dispatches += 1
        toks_np = np.asarray(toks)                     # ONE sync per tick
        counts_np = np.asarray(counts)                 # (same sync event)
        self.host_syncs += 1
        self.decode_host_syncs += 1
        k = self.spec_decode
        for slot, req in list(self._active.items()):
            finished = False
            for step in range(counts_np.shape[1]):
                cnt = int(counts_np[slot, step])
                if cnt <= 0:            # lane went done in a prior step
                    break
                req.spec_steps += 1
                req.spec_accepted += cnt - 1
                self.spec_verify_steps += 1
                self.spec_draft_tokens += k - 1
                self.spec_accepted_tokens += cnt - 1
                self._spec_slot_steps[slot] += 1
                self._spec_slot_accepted[slot] += cnt - 1
                for t in toks_np[slot, step, :cnt]:
                    req.out.append(int(t))
                    self.tokens_out += 1
                    if self.eos_id is not None and t == self.eos_id:
                        finished = True
                        break
                    if len(req.out) >= req.max_new_tokens:
                        finished = True
                        break
                if finished:
                    break
            if finished:
                self._retire(slot, req)
        self._done = jnp.asarray(self._done_host)
