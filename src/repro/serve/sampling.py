"""Token sampling: greedy / temperature / top-k / nucleus (top-p)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0     # 0 = greedy
    top_k: int = 0               # 0 = off
    top_p: float = 1.0           # 1 = off


def masked_sample(logits, key, done, pad_id, sc: SamplingConfig):
    """Sample next tokens with retired lanes pinned to ``pad_id`` —
    the on-device EOS-masking step of the fused decode loop (retired
    slots keep emitting pad instead of leaking live samples)."""
    t = sample(logits, key, sc)
    return jnp.where(done, jnp.int32(pad_id), t.astype(jnp.int32))


def filter_logits(logits, sc: SamplingConfig):
    """Apply temperature / top-k / nucleus filtering; returns the
    filtered (B, V) logits ``sample`` draws from (exposed so property
    tests can check the kept set directly).

    The top-k and top-p passes COMPOSE: top-k masks its tail to -inf
    first, so the nucleus pass must be robust to non-finite logits and
    to float cumsum never reaching ``top_p`` (probabilities over the
    k survivors sum to 1 only up to rounding).  Two guards:

    * ``cutoff_idx`` is clamped into the FINITE region — without it a
      cumsum that tops out at 1-eps < top_p lands the cutoff on a
      -inf tail entry, which degenerates to "keep everything" and
      silently disables the nucleus.
    * ties at the cutoff logit break DETERMINISTICALLY (stable
      descending sort; lower token id first): the kept set is exactly
      the first ``cutoff_idx+1`` sorted entries, never "every token
      that happens to equal the cutoff value" (value-threshold keeps
      tied tokens OUTSIDE the nucleus and inflates it).
    """
    if sc.temperature <= 0.0:
        return logits
    logits = logits / sc.temperature
    if sc.top_k > 0:
        kth = jax.lax.top_k(logits, sc.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sc.top_p < 1.0:
        V = logits.shape[-1]
        order = jnp.argsort(logits, axis=-1, stable=True, descending=True)
        sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p (always keep top-1),
        # clamped to the finite region so the cutoff can never land in a
        # -inf tail left by the top-k pass
        cutoff_idx = jnp.sum(cum < sc.top_p, axis=-1)
        n_finite = jnp.sum(jnp.isfinite(sorted_logits), axis=-1)
        cutoff_idx = jnp.minimum(cutoff_idx,
                                 jnp.maximum(n_finite - 1, 0))
        keep_sorted = jnp.arange(V)[None, :] <= cutoff_idx[:, None]
        inv = jnp.argsort(order, axis=-1, stable=True)   # rank of token i
        keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
        logits = jnp.where(keep, logits, -jnp.inf)
    return logits


def sample(logits, key, sc: SamplingConfig):
    """logits: (B, V) fp32 -> token ids (B,)."""
    if sc.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, filter_logits(logits, sc), axis=-1)


def accept_speculative(targets, chunk, done, pad_id, eos_id):
    """Longest-matching-prefix acceptance for greedy speculative decode.

    ``chunk`` (B, k) int32 is what the verify forward scored:
    ``[carried_token, draft_1, ..., draft_{k-1}]``.  ``targets`` (B, k)
    int32 are the greedy argmax of the verify logits at those positions
    — by construction exactly what the non-speculative engine would
    emit, so emitting a prefix of ``targets`` is lossless regardless of
    draft quality.  Draft ``i`` is accepted iff drafts ``1..i`` all
    matched their targets (``chunk[:, 1:] == targets[:, :-1]``
    cumulative-product); the carried token's target always emits.

    Done lanes are pinned to ``pad_id`` (the multi-token analogue of
    :func:`masked_sample`), and an EOS inside the accepted window
    truncates emission AT the EOS — no post-EOS draft tokens leak out.

    Returns ``(emit, n_emit, n_acc, done_new)``:
      emit     (B, k) int32 — emitted tokens left-packed at their chunk
               index, ``pad_id`` elsewhere
      n_emit   (B,)  int32 — emitted count (0 for done lanes, else >= 1)
      n_acc    (B,)  int32 — accepted draft count in [0, k-1]; the slot's
               ``cache_pos`` advances by ``n_acc + 1``
      done_new (B,)  bool  — done | EOS emitted this step
    """
    B, k = targets.shape
    if k > 1:
        match = (chunk[:, 1:] == targets[:, :-1]).astype(jnp.int32)
        n_acc = jnp.cumprod(match, axis=1).sum(axis=1)
    else:
        n_acc = jnp.zeros((B,), jnp.int32)
    live = (jnp.arange(k)[None, :] <= n_acc[:, None]) & ~done[:, None]
    if eos_id is not None:
        is_eos = (targets == eos_id) & live
        done_new = done | is_eos.any(axis=1)
        eos_before = jnp.cumsum(is_eos, axis=1) - is_eos
        live &= eos_before == 0
    else:
        done_new = done
    emit = jnp.where(live, targets, jnp.int32(pad_id))
    n_emit = live.sum(axis=1).astype(jnp.int32)
    return emit, n_emit, n_acc.astype(jnp.int32), done_new
