"""Token sampling: greedy / temperature / top-k / nucleus (top-p)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0     # 0 = greedy
    top_k: int = 0               # 0 = off
    top_p: float = 1.0           # 1 = off


def masked_sample(logits, key, done, pad_id, sc: SamplingConfig):
    """Sample next tokens with retired lanes pinned to ``pad_id`` —
    the on-device EOS-masking step of the fused decode loop (retired
    slots keep emitting pad instead of leaking live samples)."""
    t = sample(logits, key, sc)
    return jnp.where(done, jnp.int32(pad_id), t.astype(jnp.int32))


def filter_logits(logits, sc: SamplingConfig):
    """Apply temperature / top-k / nucleus filtering; returns the
    filtered (B, V) logits ``sample`` draws from (exposed so property
    tests can check the kept set directly).

    The top-k and top-p passes COMPOSE: top-k masks its tail to -inf
    first, so the nucleus pass must be robust to non-finite logits and
    to float cumsum never reaching ``top_p`` (probabilities over the
    k survivors sum to 1 only up to rounding).  Two guards:

    * ``cutoff_idx`` is clamped into the FINITE region — without it a
      cumsum that tops out at 1-eps < top_p lands the cutoff on a
      -inf tail entry, which degenerates to "keep everything" and
      silently disables the nucleus.
    * ties at the cutoff logit break DETERMINISTICALLY (stable
      descending sort; lower token id first): the kept set is exactly
      the first ``cutoff_idx+1`` sorted entries, never "every token
      that happens to equal the cutoff value" (value-threshold keeps
      tied tokens OUTSIDE the nucleus and inflates it).
    """
    if sc.temperature <= 0.0:
        return logits
    logits = logits / sc.temperature
    if sc.top_k > 0:
        kth = jax.lax.top_k(logits, sc.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sc.top_p < 1.0:
        V = logits.shape[-1]
        order = jnp.argsort(logits, axis=-1, stable=True, descending=True)
        sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p (always keep top-1),
        # clamped to the finite region so the cutoff can never land in a
        # -inf tail left by the top-k pass
        cutoff_idx = jnp.sum(cum < sc.top_p, axis=-1)
        n_finite = jnp.sum(jnp.isfinite(sorted_logits), axis=-1)
        cutoff_idx = jnp.minimum(cutoff_idx,
                                 jnp.maximum(n_finite - 1, 0))
        keep_sorted = jnp.arange(V)[None, :] <= cutoff_idx[:, None]
        inv = jnp.argsort(order, axis=-1, stable=True)   # rank of token i
        keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
        logits = jnp.where(keep, logits, -jnp.inf)
    return logits


def sample(logits, key, sc: SamplingConfig):
    """logits: (B, V) fp32 -> token ids (B,)."""
    if sc.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, filter_logits(logits, sc), axis=-1)
