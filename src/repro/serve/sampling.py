"""Token sampling: greedy / temperature / top-k / nucleus (top-p)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0     # 0 = greedy
    top_k: int = 0               # 0 = off
    top_p: float = 1.0           # 1 = off


def masked_sample(logits, key, done, pad_id, sc: SamplingConfig):
    """Sample next tokens with retired lanes pinned to ``pad_id`` —
    the on-device EOS-masking step of the fused decode loop (retired
    slots keep emitting pad instead of leaking live samples)."""
    t = sample(logits, key, sc)
    return jnp.where(done, jnp.int32(pad_id), t.astype(jnp.int32))


def sample(logits, key, sc: SamplingConfig):
    """logits: (B, V) fp32 -> token ids (B,)."""
    if sc.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / sc.temperature
    if sc.top_k > 0:
        kth = jax.lax.top_k(logits, sc.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if sc.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p (always keep top-1)
        cutoff_idx = jnp.sum(cum < sc.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None],
                                     axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1)
