"""Async front door: non-blocking submit, streaming token handles.

``ContinuousScheduler.generate`` is a batch interface — submit
everything, drain, get arrays back.  Production traffic wants the
opposite shape: requests arrive one at a time, the caller must not
block behind other tenants, and tokens should surface as they decode.
``FrontDoor`` is that surface over the scheduler's ``tick()`` quantum:

    fd = FrontDoor(scheduler)
    h = fd.submit(prompt, max_new_tokens=128, tenant="acme", priority=1)
    for tok in h:              # yields as each decode chunk syncs
        emit(tok)

``submit`` costs no device work (the prompt is queued; prefill happens
on the first pump).  A ``StreamHandle`` is an iterator over the
request's tokens: iterating PUMPS the scheduler (one ``tick`` — an
admission pass plus one fused decode tick) until new tokens sync, so
tokens arrive in ``decode_chunk``-sized bursts after a first-token
burst at prefill — the one-host-sync-per-chunk dispatch discipline is
unchanged, streaming just reads each sync's tokens as they land.
Pumping is cooperative and single-threaded: whichever handle (or
``pump()``/``drain()`` call) runs the tick advances EVERY in-flight
request, so interleaved consumers see each other's tokens appear
between their own.

Priorities and per-tenant quotas are the scheduler's
(``priority``/``tenant`` forward to ``ContinuousScheduler.submit``;
quotas come from its ``tenant_quota`` or the ``quotas=`` override
here).  Completed requests are harvested off the scheduler
(``take_results``) into the handles, so a long-lived front door never
lets the scheduler accumulate result arrays.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

__all__ = ["FrontDoor", "StreamHandle"]


class StreamHandle:
    """Iterator over one request's generated tokens.

    ``__next__`` pumps the scheduler until a new token is available (or
    the request finished); ``available()`` is the non-blocking read;
    ``result()`` drains to completion and returns the full output.
    """

    def __init__(self, fd: "FrontDoor", req):
        self._fd = fd
        self._req = req
        self._cursor = 0

    @property
    def uid(self) -> int:
        return self._req.uid

    @property
    def done(self) -> bool:
        return self._req.t_done is not None

    @property
    def ttft(self) -> Optional[float]:
        return self._req.ttft

    def available(self) -> List[int]:
        """Tokens that have synced since the last read — no pumping."""
        new = self._req.out[self._cursor:]
        self._cursor += len(new)
        return list(new)

    def __iter__(self) -> Iterator[int]:
        return self

    def __next__(self) -> int:
        while self._cursor >= len(self._req.out):
            if self.done:
                raise StopIteration
            self._fd.pump()
        tok = self._req.out[self._cursor]
        self._cursor += 1
        return int(tok)

    def result(self) -> np.ndarray:
        """Drain until this request completes; full output (the tokens
        already streamed included)."""
        while not self.done:
            self._fd.pump()
        return np.asarray(self._req.out, np.int32)


class FrontDoor:
    """Multi-tenant submission surface over a ``ContinuousScheduler``.

    quotas — optional per-tenant admission quota override (an int for
    every tenant, or ``{tenant: n}``), installed onto the scheduler.
    """

    def __init__(self, scheduler, *, quotas=None):
        self.sched = scheduler
        if quotas is not None:
            if isinstance(quotas, dict):
                if any(int(v) < 1 for v in quotas.values()):
                    raise ValueError("tenant quotas must be >= 1")
            elif int(quotas) < 1:
                raise ValueError("tenant quotas must be >= 1")
            scheduler.tenant_quota = quotas
        self._handles: Dict[int, StreamHandle] = {}

    # ---- submission ------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, tenant=None,
               priority: int = 0) -> StreamHandle:
        """Queue a request and return its streaming handle — no device
        work until the first pump."""
        uid = self.sched.submit(prompt, max_new_tokens, priority=priority,
                                tenant=tenant)
        h = StreamHandle(self, self.sched.request(uid))
        self._handles[uid] = h
        return h

    # ---- pumping ---------------------------------------------------------
    def pump(self) -> bool:
        """One scheduler tick (admission pass + one fused decode tick);
        harvests any requests that completed.  Returns whether work
        remains."""
        more = self.sched.tick()
        for uid in self.sched.take_results():
            self._handles.pop(uid, None)   # handle keeps its req alive
        return more

    def drain(self) -> None:
        """Pump until every submitted request has completed."""
        while self.pump():
            pass

    @property
    def in_flight(self) -> int:
        return len(self._handles)

    def stats(self) -> dict:
        return self.sched.stats()
