"""Decode-path microbenchmark with dispatch discipline + XLA-flag sweep.

Times the scheduler's three compiled phases in isolation, per
(arch, batch, page_size, decode_kernel, flash block sizes):

    prefill   one ``prefill_chunk``-token B=1 scatter call
    insert    the fused LAST prefill chunk (chunk + first-token sample
              in one dispatch — request admission's epilogue)
    ar_step   one fused ``decode_chunk``-token ``lax.scan`` tick
              (``decode_chunk`` tokens per dispatch + host sync)
    spec_step one fused SPECULATIVE tick (``spec_decode=k``): same
              dispatch discipline, each scan step verifies a k-token
              MTP draft chunk.  These rows ride on a briefly-TRAINED
              smoke model (repeated-token stream) so the measured
              acceptance is honestly high; they also record measured
              acceptance, tokens/dispatch vs the non-speculative
              engine, and the modeled expected-tokens term
              (``perf_model.spec_expected_tokens``) — baseline flag
              config only.

and sweeps XLA flag configurations: ``XLA_FLAGS`` must be set before
backend init, so the parent process re-execs this file as a CHILD per
flag config (``--child``) and merges the rows.  ``xla_gpu_*`` flags
parse fine on CPU (inert there; the sweep exists so the SAME harness
autotunes on real accelerators).

Output (``BENCH_decode.json`` at the repo root):

    {"meta": {...}, "rows": [{arch, phase, decode_kernel, batch,
        page_size, block_q, block_kv, flags, tokens, time_s}, ...],
     "best": {arch: winning ar_step row}}

``core.perf_model.calibrate_kernel_time`` reads the rows to give
``decode_step_time`` its measured ``kernel_time_s`` floor; the "best"
entries name the (flags, kernel, page_size, blocks) combination a
deployment should pin.

Run:  PYTHONPATH=src python benchmarks/decode_microbench.py [--quick]
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

REPO = pathlib.Path(__file__).resolve().parent.parent

# flag configs swept (SNIPPETS exemplar set: latency-hiding scheduler,
# collective-combining thresholds, pipelined collectives, while-loop
# double buffering).  "baseline" is the backend default.
FLAG_CONFIGS = {
    "baseline": "",
    "latency-hiding": (
        "--xla_gpu_enable_latency_hiding_scheduler=true "
        "--xla_gpu_enable_pipelined_all_gather=true "
        "--xla_gpu_enable_pipelined_reduce_scatter=true "
        "--xla_gpu_enable_pipelined_all_reduce=true"),
    "combine-double-buffer": (
        "--xla_gpu_all_reduce_combine_threshold_bytes=134217728 "
        "--xla_gpu_all_gather_combine_threshold_bytes=1073741824 "
        "--xla_gpu_reduce_scatter_combine_threshold_bytes=33554432 "
        "--xla_gpu_enable_while_loop_double_buffering=true"),
}

ARCHS = ("qwen3-1.7b", "deepseek-moe-16b")
BATCH = 4
PREFILL_CHUNK = 16
DECODE_CHUNK = 8
MAX_LEN = 64
# (page_size, [block pairs]): the block sweep runs at the default page
# size only — block_q/block_kv shape the prefill-side attention chunking
# while page_size shapes the pool, and the grid stays affordable.
SWEEP = [(8, [(None, None)]),
         (16, [(128, 256), (256, 512)])]


def _best_of(fn, repeats):
    """Min wall time over `repeats` timed calls (one untimed warmup
    compiles); the result is block_until_ready'd inside the window."""
    import jax
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_arch(arch, flags_name, repeats, quick):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import smoke_config
    from repro.kernels import set_flash_blocks
    from repro.models import init_model
    from repro.serve.scheduler import ContinuousScheduler

    rows = []
    kernels = ("xla", "pallas")
    sweep = [(SWEEP[1][0], SWEEP[1][1][-1:])] if quick else SWEEP
    for page_size, blocks in sweep:
        for decode_kernel, (bq, bkv) in itertools.product(kernels, blocks):
            cfg = smoke_config(arch).with_overrides(
                dtype="float32", decode_kernel=decode_kernel)
            params = init_model(cfg, jax.random.PRNGKey(0))
            prev = set_flash_blocks(bq, bkv)
            try:
                sch = ContinuousScheduler(
                    cfg, params, slots=BATCH, max_len=MAX_LEN,
                    page_size=page_size, prefill_chunk=PREFILL_CHUNK,
                    decode_chunk=DECODE_CHUNK)
                # drive real traffic once: allocates pages, compiles and
                # exercises every phase exactly as serving does
                prompts = [np.asarray(jax.random.randint(
                    jax.random.PRNGKey(i), (PREFILL_CHUNK + 3,), 0,
                    cfg.vocab_size)) for i in range(BATCH)]
                sch.generate(prompts, DECODE_CHUNK + 2)

                toks = jnp.zeros((1, PREFILL_CHUNK), jnp.int32)
                pos0 = jnp.zeros((1,), jnp.int32)
                key = jax.random.PRNGKey(1)
                row0 = sch.kv.table([0])
                phases = {
                    "prefill": lambda: sch._prefill_fn(
                        sch.params, sch.kv.slot_cache(0), row0, toks, pos0),
                    "insert": lambda: sch._prefill_last_fn(
                        sch.params, sch.kv.slot_cache(0), row0, toks, pos0,
                        key),
                    "ar_step": lambda: sch._decode_fn(
                        sch.params, sch.kv.cache, sch.kv.table(), sch._tok,
                        sch._pos, sch._done, key),
                }
                for phase, fn in phases.items():
                    rows.append({
                        "arch": arch, "phase": phase,
                        "decode_kernel": decode_kernel, "batch": BATCH,
                        "page_size": page_size,
                        "block_q": bq, "block_kv": bkv,
                        "flags": flags_name,
                        "tokens": DECODE_CHUNK if phase == "ar_step" else 1,
                        "time_s": _best_of(fn, repeats),
                    })
                    print(f"  {arch:18s} {phase:8s} kernel={decode_kernel:6s} "
                          f"ps={page_size:2d} bq={bq} bkv={bkv} "
                          f"{rows[-1]['time_s'] * 1e3:8.2f} ms", flush=True)
            finally:
                set_flash_blocks(*prev)
    return rows


# speculative-decode rows: verify-chunk widths and the decode budget
# (large enough that tokens/dispatch converges past host truncation)
SPEC_KS = (2, 4)
SPEC_NEW = 48
SPEC_TRAIN_STEPS = 60


def _spec_trained_model(arch):
    """Train a tiny smoke variant (with an MTP head) on a repeated-token
    stream: both the main head and the MTP head learn the pattern, so
    measured acceptance is honestly high — the regime the tokens-per-
    dispatch claim is about.  Lossless greedy verify keeps the rows
    valid at ANY acceptance; training just makes them interesting."""
    import jax.numpy as jnp
    from repro.api import Trainer
    from repro.configs import smoke_config
    cfg = smoke_config(arch).with_overrides(
        dtype="float32", mtp_depth=1, d_model=64, d_ff=128,
        num_heads=2, num_kv_heads=1, head_dim=32)
    tok = jnp.full((8, 32), 7, jnp.int32)
    tr = Trainer.create(model_cfg=cfg, optimizer="adam", lr=3e-3)
    for _ in range(SPEC_TRAIN_STEPS):
        tr.step({"tokens": tok})
    return cfg, tr.params


def _bench_spec(arch, flags_name, repeats):
    import numpy as np
    from repro.core import perf_model
    from repro.serve.scheduler import ContinuousScheduler

    rows = []
    cfg0, params = _spec_trained_model(arch)
    prompts = [np.full((12,), 7, np.int32) for _ in range(BATCH)]
    kw = dict(slots=BATCH, max_len=128, page_size=16,
              prefill_chunk=PREFILL_CHUNK, decode_chunk=DECODE_CHUNK)
    for decode_kernel in ("xla", "pallas"):
        cfg = cfg0.with_overrides(decode_kernel=decode_kernel)
        base = ContinuousScheduler(cfg, params, **kw)
        ref = base.generate(prompts, SPEC_NEW)
        bst = base.stats()
        base_decode_tokens = bst["tokens_out"] - len(prompts)
        base_tpd = base_decode_tokens / bst["decode_dispatches"]
        for k in SPEC_KS:
            sch = ContinuousScheduler(cfg, params, spec_decode=k, **kw)
            outs = sch.generate(prompts, SPEC_NEW)
            assert all(np.array_equal(a, b) for a, b in zip(ref, outs)), \
                "speculative decode diverged from the greedy reference"
            st = sch.stats()
            sd = st["spec_decode"]
            tpd = (st["tokens_out"] - len(prompts)) / st["decode_dispatches"]
            t = _best_of(lambda: sch._spec_decode_fn(
                sch.params, sch.kv.cache, sch.kv.table(), sch._tok,
                sch._pos, sch._hid, sch._done), repeats)
            rows.append({
                "arch": arch, "phase": "spec_step",
                "decode_kernel": decode_kernel, "batch": BATCH,
                "page_size": kw["page_size"],
                "block_q": None, "block_kv": None, "flags": flags_name,
                "spec_k": k,
                # device-emitted tokens per dispatch (what the tick
                # produces); tokens_per_dispatch is host-consumed
                "tokens": DECODE_CHUNK * sd["tokens_per_step"],
                "time_s": t,
                "acceptance": sd["acceptance"],
                "tokens_per_step": sd["tokens_per_step"],
                "modeled_tokens_per_step":
                    perf_model.spec_expected_tokens(sd["acceptance"], k),
                "tokens_per_dispatch": tpd,
                "base_tokens_per_dispatch": base_tpd,
                "dispatch_drop": tpd / base_tpd,
            })
            print(f"  {arch:18s} spec_step k={k} "
                  f"kernel={decode_kernel:6s} "
                  f"acceptance={sd['acceptance']:.2f} "
                  f"tok/dispatch={tpd:.1f} (base {base_tpd:.1f}, "
                  f"drop {tpd / base_tpd:.2f}x) "
                  f"{t * 1e3:8.2f} ms", flush=True)
    return rows


def child_main(args):
    rows = []
    for arch in args.archs:
        rows += _bench_arch(arch, args.flags_name, args.repeats, args.quick)
    if args.flags_name == "baseline":
        rows += _bench_spec(args.archs[0], args.flags_name, args.repeats)
    pathlib.Path(args.child_out).write_text(json.dumps(rows))


def parent_main(args):
    import jax
    all_rows = []
    names = (list(FLAG_CONFIGS)[:2] if args.quick else list(FLAG_CONFIGS))
    for name in names:
        print(f"== XLA flags: {name} "
              f"[{FLAG_CONFIGS[name] or 'backend default'}]", flush=True)
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            out = f.name
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                            + FLAG_CONFIGS[name]).strip()
        cmd = [sys.executable, __file__, "--child", "--flags-name", name,
               "--child-out", out, "--repeats", str(args.repeats),
               "--archs", *args.archs] + (["--quick"] if args.quick else [])
        subprocess.run(cmd, check=True, env=env, cwd=str(REPO))
        all_rows += json.loads(pathlib.Path(out).read_text())
        os.unlink(out)

    best = {}
    for arch in args.archs:
        cand = [r for r in all_rows
                if r["arch"] == arch and r["phase"] == "ar_step"]
        best[arch] = min(cand, key=lambda r: r["time_s"])
    doc = {
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "batch": BATCH, "prefill_chunk": PREFILL_CHUNK,
            "decode_chunk": DECODE_CHUNK,
            "flag_configs": {n: FLAG_CONFIGS[n] for n in names},
            "repeats": args.repeats,
            "unix_time": time.time(),
        },
        "rows": all_rows,
        "best": best,
    }
    outp = pathlib.Path(args.out)
    outp.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"\nwrote {len(all_rows)} rows -> {outp}")
    for arch, b in best.items():
        per_tok = b["time_s"] / b["tokens"]
        print(f"best[{arch}]: flags={b['flags']} kernel={b['decode_kernel']} "
              f"ps={b['page_size']} bq={b['block_q']} bkv={b['block_kv']} "
              f"-> {per_tok * 1e3:.2f} ms/token")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=str(REPO / "BENCH_decode.json"))
    ap.add_argument("--archs", nargs="+", default=list(ARCHS))
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="2 flag configs, default page size, one block pair")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--flags-name", default="baseline",
                    help=argparse.SUPPRESS)
    ap.add_argument("--child-out", default="", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.child:
        child_main(args)
    else:
        parent_main(args)


if __name__ == "__main__":
    main()
