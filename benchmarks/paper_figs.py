"""Shared machinery for the figure-for-figure paper benchmarks.

The paper's figures are strong-scaling speedup curves on an InfiniBand
Haswell cluster.  This container has ONE cpu core, so wall-clock
speedup from emulated devices is physically impossible; each benchmark
therefore reports, per worker count p:

  * measured  — per-step wall time of the actual sync-DP implementation
                on p emulated host devices (overhead-inclusive; on one
                core this stays ~flat, it validates the code path);
  * modeled   — the paper's §3.3.2 performance model calibrated with
                (i) the measured single-worker per-sample compute time
                and (ii) the exact gradient-bytes of the network, on the
                paper's InfiniBand fabric — THE reproduction of the
                figure;
  * modeled_tpu — the same on TPU v5e ICI (the port target).

Each figure function returns rows: (p, measured_us, model_speedup_ib,
model_speedup_tpu) and checks the paper's headline number for its
figure where one is quoted.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.core import perf_model

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WORKER_CODE = """
import os, sys, time, json
import jax, jax.numpy as jnp
import numpy as np
from repro.api import Trainer
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import PAPER_NETS
from repro.core import DPConfig, get_strategy
from repro.data import make_dataset
from repro.models import init_paper_net, apply_paper_net
from repro import optim

net = PAPER_NETS[{net!r}]
p = {p}
strategy = {strategy!r}
mesh_shape = {mesh_shape!r}
mesh_axes = ('pod', 'data')[-len(mesh_shape):] if len(mesh_shape) > 1 \\
    else ('data',)
as_images = net.kind == 'cnn'
ds = make_dataset(net.dataset, n={n}, as_images=as_images)
mesh = make_mesh(mesh_shape, mesh_axes,
                 axis_types=auto_axis_types(len(mesh_shape)))
key = jax.random.PRNGKey(0)
params = init_paper_net(net, key)

def loss_fn(pp, b):
    lg = apply_paper_net(net, pp, b['x'])
    n = lg.shape[0]
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(n), b['y']])

sharded = get_strategy(strategy).sharded
opt = optim.adam(1e-3) if sharded else optim.sgd(0.05)
dp = DPConfig(sync='grads', strategy=strategy, overlap={overlap!r},
              bucket_bytes={bucket_bytes}, microbatches={microbatches})
trainer = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt,
                         dp=dp, mesh=mesh)

def floats_per_device(tree):
    return sum(s.data.size for l in jax.tree_util.tree_leaves(tree)
               for s in l.addressable_shards[:1])

opt_floats = floats_per_device(trainer.state.opt_state)
param_floats = floats_per_device(trainer.state.params)
bs = {batch}
x = jnp.asarray(ds.x[:bs]); y = jnp.asarray(ds.y[:bs])
batch = {{'x': x, 'y': y}}
m = trainer.step(batch)   # compile
jax.block_until_ready(m['loss'])
t0 = time.perf_counter()
iters = {iters}
for i in range(iters):
    m = trainer.step(batch)
jax.block_until_ready(m['loss'])
dt = (time.perf_counter() - t0) / iters
print(json.dumps({{'us_per_step': dt * 1e6, 'loss': float(m['loss']),
                   'opt_floats_per_device': int(opt_floats),
                   'param_floats_per_device': int(param_floats)}}))
"""


def run_dp_worker(net_name: str, p: int, *, batch=256, iters=10, n=2048,
                  strategy="flat", overlap=False, bucket_bytes=64 * 2 ** 20,
                  microbatches=1, mesh_shape=None):
    """Time the DP train step on `p` emulated devices in a subprocess,
    driven through the Trainer facade.  ``mesh_shape`` defaults to the
    flat ``(p,)`` data mesh; pass e.g. ``(2, p // 2)`` for a pod×data
    mesh (zero1_hier)."""
    mesh_shape = tuple(mesh_shape) if mesh_shape else (p,)
    assert int(np.prod(mesh_shape)) == p, (mesh_shape, p)
    assert len(mesh_shape) <= 2, f"mesh_shape is (p,) or (pods, data), " \
                                 f"got {mesh_shape}"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = _WORKER_CODE.format(net=net_name, p=p, batch=batch, iters=iters,
                               n=n, strategy=strategy, overlap=overlap,
                               bucket_bytes=bucket_bytes,
                               microbatches=microbatches,
                               mesh_shape=mesh_shape)
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    import json
    return json.loads(proc.stdout.strip().splitlines()[-1])


def net_comm_bytes(net):
    if net.kind == "dnn":
        return perf_model.dnn_comm_bytes(net.layer_sizes)
    # cnn: conv + fc params
    n = 0
    cin = net.image_channels
    h, w = net.image_hw
    for cout in net.conv_channels:
        n += 5 * 5 * cin * cout + cout
        cin = cout
        h, w = h // 2, w // 2
    n += h * w * cin * net.fc_size + net.fc_size
    n += net.fc_size * net.num_classes + net.num_classes
    return 4 * n


def net_flops_per_sample(net):
    if net.kind == "dnn":
        return perf_model.dnn_flops_per_sample(net.layer_sizes)
    f = 0.0
    cin = net.image_channels
    h, w = net.image_hw
    for cout in net.conv_channels:
        f += 2.0 * h * w * 5 * 5 * cin * cout
        cin = cout
        h, w = h // 2, w // 2
    f += 2.0 * h * w * cin * net.fc_size
    f += 2.0 * net.fc_size * net.num_classes
    return 3.0 * f                       # fwd + bwd


def figure(net, *, ps, samples, baseline_p=1, batch=256, iters=10):
    """Run + model one paper figure; returns list of row dicts."""
    rows = []
    measured = {}
    for p in ps:
        r = run_dp_worker(net.name, p, batch=batch, iters=iters)
        measured[p] = r["us_per_step"]

    # calibrate the model from the p=1 measured step time
    t1 = measured[ps[0]] * 1e-6 / (batch / ps[0] if False else batch)
    flops_rate = net_flops_per_sample(net) / t1     # effective FLOP/s/core
    kw = dict(samples=samples,
              flops_per_sample=net_flops_per_sample(net),
              comm_bytes=net_comm_bytes(net),
              syncs_per_epoch=samples / batch)      # per-step gradient sync

    curve_ib = perf_model.speedup_curve(
        ps, flops_rate=flops_rate, fabric=perf_model.INFINIBAND_FDR, **kw)
    curve_tpu = perf_model.speedup_curve(
        ps, flops_rate=flops_rate, fabric=perf_model.TPU_V5E_ICI, **kw)
    base_ib = curve_ib[baseline_p]["speedup"]
    for p in ps:
        rows.append({
            "p": p,
            "measured_us_per_step": measured[p],
            "model_speedup_ib": curve_ib[p]["speedup"] / base_ib,
            "model_speedup_tpu": curve_tpu[p]["speedup"]
            / curve_tpu[baseline_p]["speedup"],
            "model_comm_frac_ib": curve_ib[p]["t_comm"]
            / (curve_ib[p]["t_comm"] + curve_ib[p]["t_compute"]),
        })
    return rows


def render(name, rows, note=""):
    out = [f"# {name}"]
    out.append("p,measured_us_per_step,model_speedup_ib,model_speedup_tpu,"
               "model_comm_frac_ib")
    for r in rows:
        out.append(f"{r['p']},{r['measured_us_per_step']:.0f},"
                   f"{r['model_speedup_ib']:.2f},"
                   f"{r['model_speedup_tpu']:.2f},"
                   f"{r['model_comm_frac_ib']:.3f}")
    if note:
        out.append(f"# {note}")
    return "\n".join(out)
