"""§Perf hillclimb driver.

Lowers + compiles ONE (arch, shape) pair with config/run-config
overrides, reports the three roofline terms + per-kind collective
breakdown, and appends the iteration to
benchmarks/results/perf_iters.json.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb \
      --arch deepseek-coder-33b --shape prefill_32k \
      --tag pad_heads --cfg pad_heads_to=64

Override syntax: --cfg k=v [repeatable], --run k=v (TrainConfig fields).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.configs import INPUT_SHAPES, config_for_shape  # noqa: E402
from repro.launch import dryrun as dr  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.analysis import analyse_pair, V5E  # noqa: E402
from repro.roofline.hlocost import stablehlo_cost  # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def _parse_kv(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def measure(arch, shape_name, cfg_over=None, run_over=None, mesh=None):
    """Lower+compile with overrides; return roofline entry dict."""
    mesh = mesh or make_production_mesh()
    cfg_over, run_over = cfg_over or {}, run_over or {}

    orig_cfs = dr.config_for_shape
    orig_rc = dr.run_config

    def patched_cfs(a, s):
        cfg = orig_cfs(a, s)
        return cfg.with_overrides(**{k: v for k, v in cfg_over.items()
                                     if not k.startswith("moe.")}) \
            if cfg_over else cfg

    def patched_cfs2(a, s):
        cfg = patched_cfs(a, s)
        moekw = {k[4:]: v for k, v in cfg_over.items()
                 if k.startswith("moe.")}
        if moekw and cfg.moe is not None:
            cfg = cfg.with_overrides(
                moe=dataclasses.replace(cfg.moe, **moekw))
        return cfg

    def patched_rc(a, s):
        tc = orig_rc(a, s)
        return dataclasses.replace(tc, **run_over) if run_over else tc

    dr.config_for_shape = patched_cfs2
    dr.run_config = patched_rc
    try:
        t0 = time.time()
        lowered, cfg, tc = dr.lower_pair(arch, shape_name, mesh)
        lower_s = time.time() - t0
        cost = stablehlo_cost(lowered.as_text())
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
        hlo = compiled.as_text()
        coll = dr.collective_bytes_from_hlo(hlo)
        mem = compiled.memory_analysis()
        entry = {
            "flops_global": cost["flops"],
            "dot_bytes_global": cost["dot_bytes"],
            "collective_bytes": coll,
            "flops": cost["flops"] / V5E.chips,
            "bytes_accessed": cost["dot_bytes"] / V5E.chips,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "args_gb": mem.argument_size_in_bytes / 1e9,
            "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        }
    finally:
        dr.config_for_shape = orig_cfs
        dr.run_config = orig_rc
    row = analyse_pair(arch, shape_name, entry)
    row["collective_by_kind"] = {k: v / V5E.ici_bw
                                 for k, v in coll.items()}
    row["temp_gb"] = entry["temp_gb"]
    row["args_gb"] = entry["args_gb"]
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--cfg", action="append", default=[])
    ap.add_argument("--run", action="append", default=[])
    ap.add_argument("--note", default="")
    args = ap.parse_args()

    row = measure(args.arch, args.shape, _parse_kv(args.cfg),
                  _parse_kv(args.run))
    row["tag"] = args.tag
    row["cfg_over"] = _parse_kv(args.cfg)
    row["run_over"] = _parse_kv(args.run)
    row["note"] = args.note

    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "perf_iters.json"
    hist = json.loads(path.read_text()) if path.exists() else []
    hist.append(row)
    path.write_text(json.dumps(hist, indent=1))
    print(json.dumps({k: v for k, v in row.items()
                      if k not in ("cfg_over", "run_over")}, indent=1))


if __name__ == "__main__":
    main()
