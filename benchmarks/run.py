"""Benchmark driver — one entry per paper table/figure plus the
roofline table.  Prints ``name,us_per_call,derived`` CSV rows (derived =
the figure's headline metric: modeled speedup at the figure's max core
count on the paper's InfiniBand fabric; paper's reported value in the
trailing comment where the paper quotes one).

The serving rows (serve_throughput, traffic_replay, spec_decode_k*)
are additionally written machine-readable to ``BENCH_serve.json`` at
the repo root, so the serving perf trajectory is diffable across PRs
the way ``BENCH_decode.json`` tracks the kernel sweep.

Run:  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import json
import pathlib
import sys
import time as _time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.configs.paper_nets import PAPER_NETS  # noqa: E402
from benchmarks import paper_figs  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parent.parent
RESULTS = pathlib.Path(__file__).resolve().parent / "results"

# (bench name, net, ps, baseline_p, paper headline, paper value)
FIGURES = [
    ("fig1_mnist_dnn", "mnist-dnn", (1, 2, 4, 8), 1,
     "paper: 11.6x @ 32 cores", 11.6),
    ("fig2_mnist_cnn", "mnist-cnn", (1, 2, 4), 1,
     "paper: 1.92x @ 64c vs 16c", 1.92),
    ("fig3_adult", "adult-dnn", (1, 2, 4, 8), 1,
     "paper: speedup vs 5-core base", None),
    ("fig4_acoustic", "acoustic-dnn", (1, 2, 4, 8), 1,
     "paper: tapering at 32 cores", None),
    ("fig5_cifar10_dnn", "cifar10-dnn", (1, 2, 4, 8), 1,
     "paper: 2.97x @ 16c, 3.37x @ 64c", 3.37),
    ("fig6_cifar10_cnn", "cifar10-cnn", (1, 2, 4), 1,
     "paper: modest improvements", None),
    ("fig7_higgs", "higgs-dnn", (1, 2, 4, 8), 1,
     "paper: 2.6x @ 80c vs 20c", 2.6),
]


def bench_figures(quick=False):
    rows = []
    for name, net_name, ps, base, note, _paper in FIGURES:
        net = PAPER_NETS[net_name]
        if quick:
            ps = ps[:2]
        samples = 2048 if net.kind == "dnn" else 1024
        iters = 5 if net.kind == "cnn" else 10
        fig_rows = paper_figs.figure(net, ps=ps, samples=samples,
                                     baseline_p=base, batch=256,
                                     iters=iters)
        RESULTS.mkdir(parents=True, exist_ok=True)
        (RESULTS / f"{name}.csv").write_text(
            paper_figs.render(name, fig_rows, note))
        us1 = fig_rows[0]["measured_us_per_step"]
        sp = fig_rows[-1]["model_speedup_ib"]
        derived = f"model_speedup_p{fig_rows[-1]['p']}={sp:.2f} ({note})"
        rows.append((name, us1, derived))
        print(f"{name},{us1:.0f},{derived}", flush=True)
    return rows


def bench_ps_vs_allreduce():
    """Paper §3.3.2: async parameter server (rejected) vs sync allreduce —
    convergence at equal gradient count."""
    import time

    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.core.param_server import make_ps_trainer

    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (64,))
    X = jax.random.normal(jax.random.PRNGKey(1), (1024, 64))
    yv = X @ w_true

    def loss_fn(p, b):
        xb, yb = b
        return jnp.mean((xb @ p["w"] - yb) ** 2)

    params = {"w": jnp.zeros((64,))}
    opt = optim.sgd(0.02)
    ticks = 256
    batches = (X.reshape(ticks, 4, 64), yv.reshape(ticks, 4))

    ps_tr = make_ps_trainer(loss_fn, opt, num_workers=8)
    t0 = time.perf_counter()
    p_ps, _, _ = ps_tr(params, opt.init(params), batches)
    us = (time.perf_counter() - t0) * 1e6 / ticks

    p_sq, s_sq = params, opt.init(params)
    for i in range(ticks):
        g = jax.grad(loss_fn)(p_sq, (batches[0][i], batches[1][i]))
        p_sq, s_sq = opt.update(g, s_sq, p_sq)
    l_ps = float(loss_fn(p_ps, (X, yv)))
    l_sq = float(loss_fn(p_sq, (X, yv)))
    derived = (f"final_loss async={l_ps:.4f} sync={l_sq:.4f} "
               "(sync wins => paper §3.3.2)")
    print(f"ps_vs_allreduce,{us:.0f},{derived}", flush=True)
    return [("ps_vs_allreduce", us, derived)]


def bench_roofline():
    from repro.roofline.analysis import full_table, render_markdown
    RESULTS.mkdir(parents=True, exist_ok=True)
    if not (RESULTS / "dryrun_single.json").exists():
        derived = ("skipped: no dryrun results — run "
                   "`python -m repro.launch.dryrun` first")
        print(f"roofline_table,0,{derived}", flush=True)
        return [("roofline_table", 0.0, derived)]
    rows = full_table()                      # optimized (default code path)
    (RESULTS / "roofline.md").write_text(render_markdown(rows))
    base_path = RESULTS / "dryrun_single_baseline.json"
    derived = ""
    if base_path.exists():
        base = {(r["arch"], r["shape"]): r
                for r in full_table(base_path)}
        (RESULTS / "roofline_baseline.md").write_text(
            render_markdown(sorted(base.values(),
                                   key=lambda r: (r["arch"], r["shape"]))))
        gains = []
        for r in rows:
            b = base.get((r["arch"], r["shape"]))
            if not b:
                continue
            tb = max(b["t_compute"], b["t_memory"], b["t_collective"])
            to = max(r["t_compute"], r["t_memory"], r["t_collective"])
            if tb > 0 and to > 0 and tb / to > 1.05:
                gains.append((tb / to, r["arch"], r["shape"]))
        gains.sort(reverse=True)
        derived = " top_gains=" + ";".join(
            f"{a}/{s}={g:.1f}x" for g, a, s in gains[:3])
    best = max(rows, key=lambda r: r["roofline_mfu"])
    derived = (f"pairs={len(rows)} best_rMFU={best['arch']}/{best['shape']}"
               f"={best['roofline_mfu']:.3f}" + derived)
    print(f"roofline_table,0,{derived}", flush=True)
    return [("roofline_table", 0.0, derived)]


def bench_collective_strategies():
    """Beyond-paper: wire-volume model of flat vs hierarchical multi-pod
    allreduce for a 33B fp32 gradient set."""
    from repro.core import perf_model
    v = 4 * 33.3e9
    t_flat = perf_model.flat_multipod_comm_time(v, n_intra=16, n_pods=2)
    t_hier = perf_model.hierarchical_comm_time(v, n_intra=16, n_pods=2)
    derived = (f"33B fp32 grads: flat={t_flat:.2f}s hierarchical="
               f"{t_hier:.2f}s ({t_flat / t_hier:.1f}x)")
    print(f"collective_strategies,0,{derived}", flush=True)
    return [("collective_strategies", 0.0, derived)]


def bench_zero1(quick=False):
    """Beyond-paper: ZeRO-1 sharded-optimizer DP on 8 emulated devices —
    measured per-step time + per-device optimizer floats vs the
    replicated flat strategy, and the modeled memory/wire story for a
    33B-param Adam run on a 16-way v5e data axis."""
    from benchmarks import paper_figs
    from repro.core import perf_model

    p = 8
    iters = 2 if quick else 10
    z1 = paper_figs.run_dp_worker("mnist-dnn", p, batch=256, iters=iters,
                                  strategy="zero1")
    flat = paper_figs.run_dp_worker("mnist-dnn", p, batch=256, iters=iters,
                                    strategy="flat")
    # measured state: flat uses sgd (0 moments) so compare shard counts to
    # the model instead of to each other
    rep = perf_model.dp_memory_report(33.3e9, 2, 16)
    t_ar = perf_model.epoch_time(16, samples=1, flops_per_sample=0,
                                 flops_rate=1, comm_bytes=4 * 33.3e9,
                                 fabric=perf_model.TPU_V5E_ICI)[1]
    t_z1 = perf_model.zero1_comm_time(4 * 33.3e9, p=16,
                                      fabric=perf_model.TPU_V5E_ICI)
    derived = (f"opt_floats/dev zero1={z1['opt_floats_per_device']} "
               f"(~1/{p} of replicated) "
               f"model_33B_adam: state/dev {rep['opt_state_replicated']/2**30:.0f}GiB"
               f"->{rep['opt_state_zero1']/2**30:.0f}GiB, "
               f"wire allreduce={t_ar:.2f}s zero1={t_z1:.2f}s")
    print(f"zero1_dp,{z1['us_per_step']:.0f},{derived}", flush=True)
    return [("zero1_dp", z1["us_per_step"], derived),
            ("flat_dp_ref", flat["us_per_step"], "sgd flat baseline")]


def bench_zero23(quick=False):
    """Beyond-paper: the rest of the ZeRO ladder on 8 emulated devices.
    zero2 keeps only the 1/p gradient shard between reduce-scatters;
    zero3 holds params themselves sharded between steps (measured via
    per-device param floats), at the price of re-gathering parameter
    buckets every step — the modeled numbers show the memory/wire trade
    for a 33B-param Adam run on a 16-way v5e data axis."""
    from benchmarks import paper_figs
    from repro.core import perf_model

    p = 8
    iters = 2 if quick else 10
    z2 = paper_figs.run_dp_worker("mnist-dnn", p, batch=256, iters=iters,
                                  strategy="zero2", microbatches=4)
    z3 = paper_figs.run_dp_worker("mnist-dnn", p, batch=256, iters=iters,
                                  strategy="zero3")
    rep = perf_model.dp_memory_report(33.3e9, 2, 16)
    v = 4 * 33.3e9
    t1 = perf_model.zero1_comm_time(v, p=16)
    t2 = perf_model.zero2_comm_time(v, p=16, microbatches=4)
    t3 = perf_model.zero3_comm_time(v, p=16)
    derived2 = (f"grad shard persists: model_33B_adam total/dev "
                f"{rep['total_zero1']/2**30:.0f}GiB->"
                f"{rep['total_zero2']/2**30:.0f}GiB, wire mb=4 "
                f"z1={t1:.2f}s z2={t2:.2f}s")
    derived3 = (f"param_floats/dev={z3['param_floats_per_device']} "
                f"(~1/{p} of replicated) model_33B_adam total/dev "
                f"{rep['total_replicated']/2**30:.0f}GiB->"
                f"{rep['total_zero3']/2**30:.0f}GiB "
                f"(x{1/rep['ratio_zero3']:.1f}), wire z3={t3:.2f}s")
    print(f"zero2_dp,{z2['us_per_step']:.0f},{derived2}", flush=True)
    print(f"zero3_dp,{z3['us_per_step']:.0f},{derived3}", flush=True)
    return [("zero2_dp", z2["us_per_step"], derived2),
            ("zero3_dp", z3["us_per_step"], derived3)]


def bench_zero1_hier(quick=False):
    """Beyond-paper: multi-pod hierarchical ZeRO-1 (registry strategy
    "zero1_hier") on an emulated (2,4) pod×data mesh — measured per-step
    time + 1/8 per-device optimizer floats, and the modeled DCN story
    for a 33B fp32 gradient set on a 2-pod × 16-way v5e data axis: the
    cross-pod link only ever carries the 1/n_intra shard, vs the full
    ring volume a flat zero1 over pod×data would push through DCN."""
    from benchmarks import paper_figs
    from repro.core import perf_model

    p = 8
    iters = 2 if quick else 10
    zh = paper_figs.run_dp_worker("mnist-dnn", p, batch=256, iters=iters,
                                  strategy="zero1_hier", mesh_shape=(2, 4))
    v = 4 * 33.3e9
    t_hier = perf_model.zero1_hier_comm_time(v, n_intra=16, n_pods=2)
    t_flat = perf_model.zero1_flat_multipod_comm_time(v, n_intra=16,
                                                      n_pods=2)
    derived = (f"opt_floats/dev={zh['opt_floats_per_device']} (~1/{p}) "
               f"model_33B@2x16 v5e: zero1-over-DCN={t_flat:.2f}s "
               f"zero1_hier={t_hier:.2f}s ({t_flat / t_hier:.1f}x — DCN "
               f"carries 1/16 of the volume)")
    print(f"zero1_hier_dp,{zh['us_per_step']:.0f},{derived}", flush=True)
    return [("zero1_hier_dp", zh["us_per_step"], derived)]


def bench_ckpt_overhead(quick=False):
    """Beyond-paper: checkpoint overhead, sync vs async (ISSUE 9).
    Measured: wall time of a synchronous ``save_sharded_checkpoint``
    vs the step-path blocking portion of an ``AsyncCheckpointer.save``
    (device→host copy only) for a ~8 MiB host state.  Modeled: the 33B
    fp32 train state (params+grads+adam ≈ 16 bytes/param) through
    ``perf_model.ckpt_overhead`` — step overhead at every-50-steps
    cadence and the publish lag the resize driver may fall behind."""
    import tempfile
    import time

    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.core import init_train_state, perf_model
    from repro.checkpoint import save_sharded_checkpoint
    from repro.elastic import AsyncCheckpointer

    n = (1 << 18) if quick else (1 << 21)
    params = {"w": jax.numpy.arange(n, dtype=jnp.float32)}
    st = init_train_state(optim.adam(1e-3), params)
    iters = 2 if quick else 5
    d_sync = tempfile.mkdtemp()
    t0 = time.perf_counter()
    for i in range(iters):
        save_sharded_checkpoint(d_sync, i, st)
    sync_s = (time.perf_counter() - t0) / iters
    with AsyncCheckpointer(tempfile.mkdtemp()) as ck:
        blocked = 0.0
        for i in range(iters):
            blocked += ck.save(st, i)["blocking_s"]
            ck.wait()                     # publish off the clock
        async_s = blocked / iters

    # the store is gather-free: each of the 64 workers snapshots and
    # writes only its 1/64 shard of the ~16 B/param fp32 train state
    model = perf_model.ckpt_overhead(16.0 * 33.3e9 / 64, step_time_s=2.0,
                                     every=50)
    derived = (f"measured {4 * n / 2**20:.0f}MiB: sync={sync_s * 1e3:.1f}ms "
               f"async_blocked={async_s * 1e3:.1f}ms "
               f"({sync_s / max(async_s, 1e-9):.1f}x); "
               f"model_33B/64w@every50: "
               f"sync={100 * model['sync_overhead']:.2f}% "
               f"async={100 * model['async_overhead']:.2f}% of step time, "
               f"publish_lag={model['publish_lag_s']:.1f}s "
               f"(~{model['publish_lag_steps']:.1f} steps behind)")
    print(f"ckpt_overhead,{1e6 * async_s:.0f},{derived}", flush=True)
    return [("ckpt_overhead", 1e6 * async_s, derived)]


def bench_overlap(quick=False):
    """Beyond-paper: bucket-level overlap scheduler (core.overlap) —
    measured overlapped vs serialized sync on 8 emulated devices (one
    CPU core, so wall clock only validates the code path; the modeled
    numbers are the claim), plus the perf_model overlap story for a
    33B fp32 gradient set on a 16-way v5e data axis."""
    from benchmarks import paper_figs
    from repro.core import perf_model

    p, bb = 8, 1 << 16
    iters = 2 if quick else 10
    ovl = paper_figs.run_dp_worker("mnist-dnn", p, batch=256, iters=iters,
                                   strategy="bucketed", overlap=True,
                                   bucket_bytes=bb)
    ser = paper_figs.run_dp_worker("mnist-dnn", p, batch=256, iters=iters,
                                   strategy="bucketed", overlap="serial",
                                   bucket_bytes=bb)
    # modeled: 33B fp32 grads, backward ~2x forward at 50% MFU on v5e
    v = 4 * 33.3e9
    t_comp = 0.35
    kw = dict(p=16, n_buckets=32, fabric=perf_model.TPU_V5E_ICI,
              strategy="flat")
    t_ser = perf_model.serial_step_time(t_comp, v, **kw)
    t_ovl = perf_model.overlapped_step_time(t_comp, v, **kw)
    derived = (f"measured us/step ovl={ovl['us_per_step']:.0f} "
               f"serial={ser['us_per_step']:.0f}; model_33B@16xv5e: "
               f"serial={t_ser:.3f}s overlapped={t_ovl:.3f}s "
               f"({t_ser / t_ovl:.2f}x)")
    print(f"overlap_sched,{ovl['us_per_step']:.0f},{derived}", flush=True)
    return [("overlap_sched", ovl["us_per_step"], derived),
            ("overlap_serial_ref", ser["us_per_step"], "barrier-chained")]


def bench_serve_throughput(quick=False):
    """Beyond-paper: the serving subsystem — continuous batching over
    the paged KV cache with the FUSED device-side decode loop vs the
    legacy lockstep engine's per-token host round-trip.  Measured on
    the reduced config (CPU: the dispatch/sync discipline IS the
    story), plus the modeled v5e decode roofline for the 33B config."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, smoke_config
    from repro.core import perf_model
    from repro.models import init_model
    from repro.serve import ContinuousScheduler, ServeEngine

    # a deliberately tiny decode step: on CPU the per-step model compute
    # would otherwise swamp the per-token dispatch+sync cost this
    # benchmark isolates (at real accelerator scale decode is
    # HBM-bound and the host round-trip is the whole stall)
    cfg = smoke_config("qwen3-1.7b").with_overrides(
        dtype="float32", d_model=64, d_ff=128, num_heads=2,
        num_kv_heads=1, head_dim=32)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    # decode-heavy shape: the fused loop's win is per decoded token, so
    # short generations under-report it (prefill + tick-boundary
    # overhead amortise over decode_chunk-sized ticks)
    batch, new = 4, (48 if quick else 96)
    prompts = jax.random.randint(key, (batch, 16), 0, cfg.vocab_size)
    max_len = -(-(16 + new + 16) // 16) * 16
    # eos_id that never fires: the legacy engine then pays its genuine
    # per-token `bool(done.all())` sync; the fused loop masks on device
    eos = cfg.vocab_size - 1

    leg = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      dtype=jnp.float32, eos_id=eos)
    sch = ContinuousScheduler(cfg, params, slots=batch, max_len=max_len,
                              page_size=16, eos_id=eos,
                              prefill_chunk=16, decode_chunk=16)

    def run_legacy():
        t0 = time.perf_counter()
        out = np.asarray(leg.generate(prompts, new))
        return out, time.perf_counter() - t0

    def run_sched():
        t0 = time.perf_counter()
        outs = sch.generate(list(np.asarray(prompts)), new)
        return outs, time.perf_counter() - t0

    run_legacy(), run_sched()     # warm: compile both engines' steps
    leg.host_syncs = sch.host_syncs = 0
    sch.tokens_out = 0
    t_leg = t_sch = float("inf")
    n_runs = 2 if quick else 4
    for _ in range(n_runs):                  # interleaved best-of: the
        leg_out, t = run_legacy()            # CPU box is noisy
        t_leg = min(t_leg, t)
        sch_outs, t = run_sched()
        t_sch = min(t_sch, t)

    def _trim(row):
        idx = np.where(row == eos)[0]
        return row[:idx[0] + 1] if len(idx) else row

    assert all(np.array_equal(o, _trim(r)[:len(o)])
               for o, r in zip(sch_outs, leg_out)), \
        "continuous scheduler diverged from the legacy engine (greedy)"
    n_tok = batch * new
    tps_leg, tps_sch = n_tok / t_leg, n_tok / t_sch
    st = sch.stats()
    ttft = min(st["ttft_s"]) if st["ttft_s"] else 0.0
    # modeled: 33B bf16 on one v5e slice, 32-way batch @ 8k context
    full = get_config("deepseek-coder-33b")
    pb = 2.0 * full.param_count()
    kvs = perf_model.kv_bytes_per_token(full) * 8192
    mod = perf_model.decode_tokens_per_s(pb, kvs, batch=32,
                                         flops_per_token=2.0 * full.param_count())
    derived = (f"tok/s legacy={tps_leg:.1f} fused={tps_sch:.1f} "
               f"({tps_sch / tps_leg:.1f}x) syncs/token "
               f"legacy={leg.host_syncs / (n_runs * n_tok):.2f} "
               f"fused={st['syncs_per_token']:.3f} ttft={ttft * 1e3:.0f}ms; "
               f"model_33B@v5e: {mod:.0f} tok/s/chip (HBM-bound)")
    print(f"serve_throughput,{1e6 * t_sch / n_tok:.0f},{derived}",
          flush=True)
    # mesh_serve: modeled 671B-MoE decode on the production serve mesh
    # (model=16) — resident bytes one device streams per fused step,
    # expert-parallel vs replicated expert dispatch.  The paged pool is
    # per-device too (pool_spec shards its feature axes over "model").
    v3 = get_config("deepseek-v3-671b")
    ctxs = [8192] * 32                     # 32 slots @ 8k live context
    mp = 16
    ep = perf_model.mesh_decode_bytes_per_device(
        v3, ctxs, 16, model_parallel=mp, expert_parallel=True)
    rep = perf_model.mesh_decode_bytes_per_device(
        v3, ctxs, 16, model_parallel=mp, expert_parallel=False)
    pool_dev = perf_model.paged_pool_bytes(
        ctxs, 16, perf_model.kv_bytes_per_token(v3)) / mp
    step_ep = perf_model.decode_step_time(
        ep - pool_dev, pool_dev / len(ctxs), batch=len(ctxs),
        flops_per_token=2.0 * v3.param_count(True) / mp)
    mesh_derived = (f"671B@model={mp}: bytes/device "
                    f"EP={ep / 2**30:.1f}GiB repl={rep / 2**30:.1f}GiB "
                    f"({rep / ep:.1f}x), pool/device="
                    f"{pool_dev / 2**20:.0f}MiB, "
                    f"{len(ctxs) / step_ep:.0f} tok/s/chip EP")
    print(f"mesh_serve,{1e6 * step_ep:.0f},{mesh_derived}", flush=True)
    return [("serve_throughput", 1e6 * t_sch / n_tok, derived),
            ("serve_legacy_ref", 1e6 * t_leg / n_tok,
             "per-token host-sync lockstep engine"),
            ("mesh_serve", 1e6 * step_ep, mesh_derived)]


def bench_traffic_replay(quick=False):
    """Multi-tenant front door under replayed traffic: Poisson
    arrivals over a Zipf-shared prompt catalog (production prompt
    streams repeat — system preambles, few-shot templates), prefix
    cache ON vs OFF on the same arrival schedule.  Reports p50/p99
    TTFT and goodput; greedy outputs must be bitwise identical, the
    cache only changes WHEN tokens arrive, never WHICH."""
    import time

    import jax
    import numpy as np

    from repro.configs import smoke_config
    from repro.core import perf_model
    from repro.models import init_model
    from repro.serve import ContinuousScheduler, FrontDoor

    cfg = smoke_config("qwen3-1.7b").with_overrides(
        dtype="float32", d_model=64, d_ff=128, num_heads=2,
        num_kv_heads=1, head_dim=32)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)

    # Zipf-shared catalog: few long prompts, heavily skewed reuse
    S, new, ps, chunk = 384, 8, 16, 16
    n_cat = 6
    n_req = 16 if quick else 32
    rng = np.random.default_rng(7)
    catalog = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(100 + i), (S,), 0, cfg.vocab_size))
        for i in range(n_cat)]
    zipf = 1.0 / np.arange(1, n_cat + 1) ** 1.2
    zipf /= zipf.sum()
    picks = rng.choice(n_cat, size=n_req, p=zipf)
    # Poisson arrivals: exponential inter-arrival gaps
    gaps = rng.exponential(scale=0.03, size=n_req)
    arrivals = np.cumsum(gaps)
    max_len = -(-(S + new + 8) // ps) * ps

    def replay(prefix_cache):
        sch = ContinuousScheduler(cfg, params, slots=4, max_len=max_len,
                                  page_size=ps, prefill_chunk=chunk,
                                  decode_chunk=8, num_pages=288,
                                  prefix_cache=prefix_cache)
        fd = FrontDoor(sch)
        # warm: compile every chunk shape AND (cache run) populate the
        # radix tree — the replay below measures steady-state serving.
        # The second pass replays one prompt as a HIT, so the cached
        # run's 1-token prefill shape and COW-fork copy also compile
        # outside the timed window
        for p in catalog:
            fd.submit(p, new)
        fd.drain()
        fd.submit(catalog[0], new)
        fd.drain()
        sch.prefix_tokens_saved = sch.prompt_tokens = 0   # replay-only stats
        t0 = time.perf_counter()
        handles = []
        i = 0
        while i < n_req or fd.in_flight:
            now = time.perf_counter() - t0
            while i < n_req and arrivals[i] <= now:
                handles.append(fd.submit(catalog[picks[i]], new))
                i += 1
            if not fd.pump() and i < n_req:
                time.sleep(max(0.0, arrivals[i]
                               - (time.perf_counter() - t0)))
        wall = time.perf_counter() - t0
        outs = [np.asarray(h._req.out, np.int32) for h in handles]
        ttfts = np.asarray([h.ttft for h in handles])
        return outs, ttfts, wall, fd.stats()

    outs_off, ttft_off, wall_off, _ = replay(False)
    outs_on, ttft_on, wall_on, st = replay(True)
    for a, b in zip(outs_on, outs_off):
        assert np.array_equal(a, b), \
            "prefix cache changed greedy outputs (must be bitwise)"
    p50_on, p99_on = np.percentile(ttft_on, [50, 99])
    p50_off, p99_off = np.percentile(ttft_off, [50, 99])
    hit = st["prefix_hit_rate"]
    assert hit >= 0.8, f"prefix hit rate {hit:.0%} < 80%"
    assert p50_off / p50_on >= 5.0, \
        (f"p50 TTFT speedup {p50_off / p50_on:.1f}x < 5x "
         f"(on={p50_on * 1e3:.1f}ms off={p50_off * 1e3:.1f}ms)")
    tok = sum(len(o) for o in outs_on)
    # modeled: the same hit rate through the roofline TTFT term
    fpt = 2.0 * cfg.param_count()
    mod = (perf_model.ttft_model(S, flops_per_token=fpt)
           / perf_model.ttft_model(S, flops_per_token=fpt,
                                   prefix_hit_rate=hit))
    derived = (f"p50 TTFT on={p50_on * 1e3:.1f}ms off="
               f"{p50_off * 1e3:.1f}ms ({p50_off / p50_on:.1f}x, "
               f"modeled {mod:.1f}x at hit={hit:.0%}) p99 on="
               f"{p99_on * 1e3:.1f}ms off={p99_off * 1e3:.1f}ms "
               f"goodput on={tok / wall_on:.1f} off="
               f"{tok / wall_off:.1f} tok/s")
    print(f"traffic_replay,{1e6 * p50_on:.0f},{derived}", flush=True)
    return [("traffic_replay", 1e6 * p50_on, derived)]


def bench_spec_decode(quick=False):
    """Speculative decode: MTP draft-verify fused into the one-sync
    scan.  A tiny smoke model is briefly TRAINED on a repeated-token
    stream (``decode_microbench._spec_trained_model``) so measured
    acceptance is honestly high, then the same prompts run through the
    non-speculative engine and ``spec_decode=k`` for k in {2, 4}:
    outputs must match bitwise (lossless greedy verify), and the rows
    record measured acceptance, tokens per dispatch vs baseline, and
    the modeled expected-tokens term
    (``perf_model.spec_expected_tokens``)."""
    import time

    import numpy as np

    from repro.core import perf_model
    from repro.serve import ContinuousScheduler
    from benchmarks import decode_microbench as dm

    cfg, params = dm._spec_trained_model("qwen3-1.7b")
    new = 24 if quick else 48
    prompts = [np.full((12,), 7, np.int32) for _ in range(4)]
    kw = dict(slots=4, max_len=128, page_size=16, prefill_chunk=16,
              decode_chunk=8)
    base = ContinuousScheduler(cfg, params, **kw)
    base.generate(prompts, new)                      # warm/compile
    t0 = time.perf_counter()
    ref = base.generate(prompts, new)
    t_base = time.perf_counter() - t0
    bst = base.stats()
    base_tpd = ((bst["tokens_out"] // 2 - len(prompts))
                / (bst["decode_dispatches"] // 2))
    rows = []
    for k in (2, 4):
        sch = ContinuousScheduler(cfg, params, spec_decode=k, **kw)
        sch.generate(prompts, new)                   # warm/compile
        t0 = time.perf_counter()
        outs = sch.generate(prompts, new)
        t = time.perf_counter() - t0
        assert all(np.array_equal(a, b) for a, b in zip(ref, outs)), \
            "speculative decode diverged from the greedy reference"
        st = sch.stats()
        sd = st["spec_decode"]
        tpd = ((st["tokens_out"] // 2 - len(prompts))
               / (st["decode_dispatches"] // 2))
        n_tok = sum(len(o) for o in outs)
        name = f"spec_decode_k{k}"
        derived = (f"acceptance={sd['acceptance']:.2f} tok/dispatch="
                   f"{tpd:.1f} (base {base_tpd:.1f}, "
                   f"{tpd / base_tpd:.2f}x) modeled E="
                   f"{perf_model.spec_expected_tokens(sd['acceptance'], k):.2f} "
                   f"wall {n_tok / t:.0f} vs {n_tok / t_base:.0f} tok/s")
        print(f"{name},{1e6 * t / n_tok:.0f},{derived}", flush=True)
        rows.append({"name": name, "us_per_call": 1e6 * t / n_tok,
                     "derived": derived, "spec_k": k,
                     "acceptance": sd["acceptance"],
                     "tokens_per_step": sd["tokens_per_step"],
                     "tokens_per_dispatch": tpd,
                     "base_tokens_per_dispatch": base_tpd,
                     "dispatch_drop": tpd / base_tpd})
    return rows


def _write_bench_serve(tuple_rows, dict_rows, quick):
    """Consolidated machine-readable serving trajectory: one JSON doc
    per run at the repo root, rows from serve_throughput /
    traffic_replay (name, us_per_call, derived) plus the structured
    spec-decode rows."""
    import jax
    doc = {
        "meta": {"backend": jax.default_backend(),
                 "device_count": jax.device_count(),
                 "quick": bool(quick), "unix_time": _time.time()},
        "rows": ([{"name": n, "us_per_call": us, "derived": d}
                  for (n, us, d) in tuple_rows] + dict_rows),
    }
    out = REPO / "BENCH_serve.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"# wrote {len(doc['rows'])} serving rows -> {out}", flush=True)


def main():
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")
    bench_roofline()
    serve_rows = []
    serve_rows += bench_serve_throughput(quick=quick)
    serve_rows += bench_traffic_replay(quick=quick)
    _write_bench_serve(serve_rows, bench_spec_decode(quick=quick), quick)
    bench_collective_strategies()
    bench_overlap(quick=quick)
    bench_zero1(quick=quick)
    bench_zero23(quick=quick)
    bench_zero1_hier(quick=quick)
    bench_ckpt_overhead(quick=quick)
    bench_ps_vs_allreduce()
    bench_figures(quick=quick)


if __name__ == "__main__":
    main()
