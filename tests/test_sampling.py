"""Sampling filters: top-k x top-p composition.

Regression tests for two interaction bugs: a float cumsum that never
reaches ``top_p`` over the top-k survivors used to land the nucleus
cutoff in the -inf tail (silently disabling it), and value-threshold
tie handling let tokens OUTSIDE the nucleus in (non-deterministic
kept-set size).  ``filter_logits`` exposes the kept set directly.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import SamplingConfig
from repro.serve.sampling import filter_logits, sample

V = 16


def _kept(logits, k, p, temp=1.0):
    sc = SamplingConfig(temperature=temp, top_k=k, top_p=p)
    out = np.asarray(filter_logits(jnp.asarray(logits, jnp.float32), sc))
    return np.isfinite(out), out


def _rand_logits(seed, b=3):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                        (b, V))) * 3.0


# --------------------------------------------------------------------------
# property grid: every (k, p) combination on random logits
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,p", list(itertools.product(
    [0, 1, 3, 8, V], [0.1, 0.5, 0.9, 0.99, 1.0])))
def test_grid_kept_set_properties(k, p):
    logits = _rand_logits(k * 31 + int(p * 100))
    keep, out = _kept(logits, k, p)
    x = logits.astype(np.float64)
    for b in range(logits.shape[0]):
        kept_idx = np.where(keep[b])[0]
        # non-empty, and values pass through unmasked (just 1/T-scaled)
        assert len(kept_idx) >= 1
        np.testing.assert_allclose(out[b][keep[b]], logits[b][keep[b]],
                                   rtol=1e-6)
        if k > 0:
            assert len(kept_idx) <= k          # nucleus never grows top-k
            kth = np.sort(x[b])[-k]
            assert (x[b][kept_idx] >= kth).all()
        # the kept set is a PREFIX of the stable descending order:
        # every dropped token is strictly worse than every kept one, or
        # tied with a HIGHER token id (deterministic tie-break)
        worst_kept = x[b][kept_idx].min()
        worst_id = kept_idx[x[b][kept_idx] == worst_kept].max()
        for j in np.where(~keep[b])[0]:
            assert (x[b][j] < worst_kept
                    or (x[b][j] == worst_kept and j > worst_id))
        if p < 1.0:
            # smallest set: kept mass >= p (up to float slack) or the
            # whole finite region is kept
            kmask = (x[b] >= np.sort(x[b])[-k]) if k > 0 else \
                np.ones(V, bool)
            e = np.exp(x[b] - x[b][kmask].max()) * kmask
            probs = e / e.sum()
            mass = probs[kept_idx].sum()
            if len(kept_idx) < kmask.sum():
                assert mass >= p - 1e-5
                # minimality: dropping the worst kept breaks the bound
                assert mass - probs[worst_id] < p + 1e-5


def test_topk_alone_keeps_exactly_k():
    logits = _rand_logits(0)
    keep, _ = _kept(logits, 4, 1.0)
    assert (keep.sum(-1) == 4).all()


# --------------------------------------------------------------------------
# regression: cutoff clamped into the finite region
# --------------------------------------------------------------------------

def test_cutoff_never_lands_in_topk_masked_tail():
    """top-k first, then a top_p so close to 1 that float cumsum over
    the k survivors tops out below it: the unclamped cutoff walks into
    the -inf tail and keeps EVERYTHING (nucleus silently off).  The
    clamp pins it to the last finite entry instead."""
    logits = np.tile(np.linspace(5.0, -5.0, V), (2, 1))
    keep, out = _kept(logits, 3, 0.999999999)
    assert (keep.sum(-1) == 3).all()           # the top-k set, nothing more
    assert np.isneginf(out[~keep]).all()


def test_top_p_greater_than_mass_of_one_keeps_top1():
    logits = np.zeros((1, V))
    logits[0, 5] = 50.0                        # ~all mass on one token
    keep, _ = _kept(logits, 0, 0.5)
    assert keep.sum() == 1 and keep[0, 5]


# --------------------------------------------------------------------------
# regression: deterministic tie-break at the nucleus boundary
# --------------------------------------------------------------------------

def test_adversarial_ties_break_by_token_id():
    """Four tokens tied at the top, nucleus sized to cut INSIDE the
    tied group: the kept set must be the lowest token ids among the
    tied (stable descending sort), never 'every token equal to the
    cutoff value' — and re-running never changes the set."""
    logits = np.full((1, V), -10.0)
    tied = [2, 5, 11, 13]
    for t in tied:
        logits[0, t] = 4.0                     # each gets ~1/4 of the mass
    keep1, _ = _kept(logits, 0, 0.6)           # needs 3 of the 4
    keep2, _ = _kept(logits, 0, 0.6)
    np.testing.assert_array_equal(keep1, keep2)
    assert sorted(np.where(keep1[0])[0]) == [2, 5, 11]


def test_tied_group_with_topk_composes():
    logits = np.full((1, V), -10.0)
    for t in range(8):
        logits[0, t] = 1.0                     # ids 0..7 tied
    # top-k is a VALUE threshold: all 8 tied tokens survive k=4; the
    # nucleus then needs 5 of the 8 (5/8 >= 0.6) — lowest ids first
    keep, _ = _kept(logits, 4, 0.6)
    assert sorted(np.where(keep[0])[0]) == [0, 1, 2, 3, 4]


# --------------------------------------------------------------------------
# sampling facade
# --------------------------------------------------------------------------

def test_greedy_ignores_filters():
    logits = _rand_logits(4)
    sc = SamplingConfig(temperature=0.0, top_k=2, top_p=0.1)
    got = np.asarray(sample(jnp.asarray(logits), jax.random.PRNGKey(0), sc))
    np.testing.assert_array_equal(got, logits.argmax(-1))


def test_sampled_tokens_come_from_kept_set():
    logits = _rand_logits(5)
    sc = SamplingConfig(temperature=0.7, top_k=5, top_p=0.8)
    keep, _ = _kept(logits, 5, 0.8, temp=0.7)
    for s in range(20):
        toks = np.asarray(sample(jnp.asarray(logits),
                                 jax.random.PRNGKey(s), sc))
        assert all(keep[b, t] for b, t in enumerate(toks))
