"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core.collectives import _flatten_concat, _unflatten
from repro.core import perf_model
from repro.data.pipeline import ShardedLoader
from repro.kernels import ops, ref
from repro.models.layers import apply_rope, rmsnorm, init_rmsnorm
from repro.train.loss import cross_entropy, IGNORE

SETTINGS = dict(deadline=None, max_examples=20,
                suppress_health_check=[HealthCheck.too_slow])


# --------------------------------------------------------------------------
# collectives: flatten/unflatten roundtrip over arbitrary pytrees
# --------------------------------------------------------------------------

@st.composite
def pytrees(draw):
    n = draw(st.integers(1, 5))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    tree = {}
    for i in range(n):
        shape = tuple(draw(st.lists(st.integers(1, 7), min_size=0,
                                    max_size=3)))
        tree[f"leaf{i}"] = jnp.asarray(
            rng.standard_normal(shape), jnp.float32)
    return tree


@given(pytrees())
@settings(**SETTINGS)
def test_flatten_concat_roundtrip(tree):
    flat, spec = _flatten_concat(tree)
    back = _unflatten(flat, spec)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# RoPE is norm-preserving and relative
# --------------------------------------------------------------------------

@given(st.integers(0, 1000), st.integers(2, 8))
@settings(**SETTINGS)
def test_rope_preserves_norm(pos, half):
    hd = 2 * half
    x = jax.random.normal(jax.random.PRNGKey(pos), (1, 1, 1, hd))
    r = apply_rope(x, jnp.array([[pos]]), 10_000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(r)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)


@given(st.integers(0, 300), st.integers(1, 50))
@settings(**SETTINGS)
def test_rope_is_relative(base, delta):
    """<rope(q,p1), rope(k,p2)> depends only on p1-p2."""
    key = jax.random.PRNGKey(base)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(base + 1), (1, 1, 1, 32))

    def dot_at(p1, p2):
        qr = apply_rope(q, jnp.array([[p1]]), 10_000.0)
        kr = apply_rope(k, jnp.array([[p2]]), 10_000.0)
        return float(jnp.sum(qr * kr))

    a = dot_at(base + delta, base)
    b = dot_at(delta, 0)
    np.testing.assert_allclose(a, b, atol=1e-3)


# --------------------------------------------------------------------------
# rmsnorm: scale invariance
# --------------------------------------------------------------------------

@given(st.floats(0.1, 100.0))
@settings(**SETTINGS)
def test_rmsnorm_scale_invariant(scale):
    p = init_rmsnorm(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16))
    np.testing.assert_allclose(np.asarray(rmsnorm(p, x)),
                               np.asarray(rmsnorm(p, x * scale)),
                               atol=1e-4)


# --------------------------------------------------------------------------
# WKV6 chunked == naive for arbitrary chunkings
# --------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(1, 16), st.integers(0, 100))
@settings(**SETTINGS)
def test_wkv6_chunked_any_chunking(T, chunk, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    B, H, K = 1, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, T, H, K)) for i in range(3))
    wl = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)))
    u = jax.random.normal(ks[4], (H, K))
    s0 = jax.random.normal(ks[5], (B, H, K, K))
    y1, s1 = ref.wkv6_ref(r, k, v, wl, u, s0)
    y2, s2 = ops.wkv6_chunked(r, k, v, wl, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


# --------------------------------------------------------------------------
# mamba chunked == naive
# --------------------------------------------------------------------------

@given(st.integers(1, 32), st.integers(1, 16), st.integers(0, 100))
@settings(**SETTINGS)
def test_mamba_chunked_any_chunking(T, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    Bb, dI, dS = 1, 8, 4
    x = jax.random.normal(ks[0], (Bb, T, dI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, T, dI)))
    A = -jnp.exp(jax.random.normal(ks[2], (dI, dS)))
    B = jax.random.normal(ks[3], (Bb, T, dS))
    C = jax.random.normal(ks[4], (Bb, T, dS))
    D = jax.random.normal(ks[5], (dI,))
    h0 = jax.random.normal(ks[6], (Bb, dI, dS))
    y1, h1 = ref.mamba_ref(x, dt, A, B, C, D, h0)
    y2, h2 = ops.mamba_chunked(x, dt, A, B, C, D, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


# --------------------------------------------------------------------------
# data pipeline: shards partition the epoch
# --------------------------------------------------------------------------

@given(st.integers(8, 64), st.integers(1, 8), st.integers(0, 10))
@settings(**SETTINGS)
def test_loader_batches_partition_epoch(n, bs, seed):
    data = {"x": np.arange(n)[:, None].astype(np.float32)}
    loader = ShardedLoader(data, batch_size=bs, seed=seed)
    seen = []
    for batch in loader.epoch(0):
        seen.extend(batch["x"][:, 0].astype(int).tolist())
    # drop-last: k*bs samples, all distinct
    assert len(seen) == (n // bs) * bs
    assert len(set(seen)) == len(seen)
    # deterministic given (seed, epoch)
    again = []
    for batch in loader.epoch(0):
        again.extend(batch["x"][:, 0].astype(int).tolist())
    assert seen == again


# --------------------------------------------------------------------------
# loss: masked CE
# --------------------------------------------------------------------------

@given(st.integers(0, 50))
@settings(**SETTINGS)
def test_cross_entropy_ignores_masked(seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (2, 6, 11))
    labels = jax.random.randint(key, (2, 6), 0, 11)
    masked = labels.at[:, -2:].set(IGNORE)
    want = cross_entropy(logits[:, :-2], labels[:, :-2])
    got = cross_entropy(logits, masked)
    np.testing.assert_allclose(float(want), float(got), rtol=1e-6)


# --------------------------------------------------------------------------
# paper performance model sanity
# --------------------------------------------------------------------------

@given(st.integers(1, 6))
@settings(**SETTINGS)
def test_perf_model_compute_scales_inverse_p(logp):
    p = 2 ** logp
    kw = dict(samples=60000,
              flops_per_sample=perf_model.dnn_flops_per_sample(
                  (784, 200, 100, 10)),
              flops_rate=1e10,
              comm_bytes=perf_model.dnn_comm_bytes((784, 200, 100, 10)),
              fabric=perf_model.INFINIBAND_FDR)
    t1c, _ = perf_model.epoch_time(1, **kw)
    tpc, tpm = perf_model.epoch_time(p, **kw)
    np.testing.assert_allclose(tpc, t1c / p, rtol=1e-9)
    assert tpm >= 0.0


def test_hierarchical_beats_flat_multipod():
    v = 4 * 50e6  # 50M params fp32
    t_h = perf_model.hierarchical_comm_time(v, n_intra=16, n_pods=2)
    t_f = perf_model.flat_multipod_comm_time(v, n_intra=16, n_pods=2)
    assert t_h < t_f
