"""Paged flash-decode kernel tier: kernel-vs-oracle numerics, the
paged_read invariants the kernel's masking contract relies on, greedy
pallas==xla equality on host and on the (2, 4) serve mesh, the fused-
sampling dispatch discipline, and the perf-model calibration hooks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices

from repro.configs import smoke_config
from repro.core import perf_model
from repro.kernels.paged_decode import (paged_flash_decode,
                                        paged_flash_decode_mla)
from repro.models import init_model
from repro.models.attention import (PagedView, masked_attention,
                                    paged_read, _paged_append)
from repro.serve import ContinuousScheduler, make_engine

KEY = jax.random.PRNGKey(11)


def _cfg(arch="qwen3-1.7b", **kw):
    return smoke_config(arch).with_overrides(dtype="float32", **kw)


def _prompts(cfg, lengths, seed=0):
    return [np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + i), (L,), 0, cfg.vocab_size))
        for i, L in enumerate(lengths)]


# --------------------------------------------------------------------------
# kernel vs oracle (standalone, host)
# --------------------------------------------------------------------------

def _random_paged(key, B, W, ps, n_pages, feat, trash_fill=1e4):
    """A token-major pool with POISONED trash page (page 0) and poisoned
    unallocated pages, plus a per-slot table allocating a prefix of each
    row.  Returns (pool, table, alloc_pages per slot)."""
    ks = jax.random.split(key, 3)
    pool = jax.random.normal(ks[0], (n_pages * ps,) + feat, jnp.float32)
    # poison page 0 (trash) AND every never-referenced page: only the
    # mask keeps them out of the output
    pool = pool.at[:ps].set(trash_fill)
    alloc = [int(x) for x in
             jax.random.randint(ks[1], (B,), 1, W + 1)]           # >=1 page
    table = np.zeros((B, W), np.int32)
    nxt = 1
    for b in range(B):
        for w in range(alloc[b]):
            table[b, w] = nxt
            nxt += 1
    assert nxt <= n_pages
    return pool, jnp.asarray(table), alloc


GQA_CASES = [
    # B, S, h, hk, hd, ps, W, window
    (2, 1, 4, 2, 64, 16, 4, 0),        # decode step, GQA
    (3, 1, 4, 4, 32, 8, 5, 0),         # MHA
    (1, 12, 4, 1, 64, 16, 3, 0),       # prefill chunk, MQA
    (2, 7, 8, 2, 32, 8, 6, 20),        # sliding window
    (2, 5, 2, 2, 64, 32, 2, 0),        # big pages, ragged chunk
]


@pytest.mark.parametrize("case", GQA_CASES)
def test_gqa_kernel_matches_oracle(case):
    B, S, h, hk, hd, ps, W, window = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 997), 3)
    kp, table, alloc = _random_paged(ks[0], B, W, ps, W * B + 2, (hk, hd))
    vp, _, _ = _random_paged(ks[1], B, W, ps, W * B + 2, (hk, hd))
    vp = jnp.where(jnp.arange(vp.shape[0])[:, None, None] < ps, 1e4, vp)
    q = jax.random.normal(ks[2], (B, S, h, hd), jnp.float32)
    # each slot's positions live inside its allocated pages
    pos = jnp.asarray([[a * ps - S + s for s in range(S)]
                       for a in alloc], jnp.int32)
    view = PagedView(table, ps)
    k_full, kv_pos = paged_read(kp, view)
    v_full, _ = paged_read(vp, view)
    want = masked_attention(q, k_full, v_full, q_positions=pos,
                            kv_positions=kv_pos, window=window)
    got = paged_flash_decode(q, kp, vp, table, pos, page_size=ps,
                             window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


MLA_CASES = [
    # B, S, h, r, rope, ps, W, window
    (2, 1, 4, 32, 16, 16, 4, 0),
    (1, 9, 4, 32, 16, 8, 5, 0),
    (2, 4, 2, 64, 8, 8, 6, 24),
]


@pytest.mark.parametrize("case", MLA_CASES)
def test_mla_kernel_matches_oracle(case):
    B, S, h, r, rope, ps, W, window = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 991), 4)
    ckv, table, alloc = _random_paged(ks[0], B, W, ps, W * B + 2, (r,))
    krp, _, _ = _random_paged(ks[1], B, W, ps, W * B + 2, (rope,))
    q_lat = jax.random.normal(ks[2], (B, S, h, r), jnp.float32)
    q_rope = jax.random.normal(ks[3], (B, S, h, rope), jnp.float32)
    pos = jnp.asarray([[a * ps - S + s for s in range(S)]
                       for a in alloc], jnp.int32)
    scale = 0.125
    view = PagedView(table, ps)
    ckv_c, kv_pos = paged_read(ckv, view)
    krp_c, _ = paged_read(krp, view)
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_c)
              + jnp.einsum("bshk,btk->bhst", q_rope, krp_c)) * scale
    mask = kv_pos[None, None, :] <= pos[:, :, None]
    if window:
        mask &= kv_pos[None, None, :] > pos[:, :, None] - window
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    want = jnp.einsum("bhst,btr->bshr", probs, ckv_c)
    got = paged_flash_decode_mla(q_lat, q_rope, ckv, krp, table, pos,
                                 page_size=ps, scale=scale, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_kernel_trash_poison_never_leaks():
    """Flood the trash page and every unallocated page with 1e8: the
    kernel output must stay identical to the zero-filled-pool output —
    visibility masking alone isolates unwritten storage."""
    B, S, h, hk, hd, ps, W = 2, 3, 4, 2, 32, 8, 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, h, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (6 * ps, hk, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (6 * ps, hk, hd), jnp.float32)
    table = jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]], jnp.int32)
    pos = jnp.asarray([[2 * ps - S + s for s in range(S)],
                       [ps - S + s for s in range(S)]], jnp.int32)
    written = jnp.zeros((6 * ps,), bool).at[ps:4 * ps].set(True)
    clean = lambda p: jnp.where(written[:, None, None], p, 0.0)
    poison = lambda p: jnp.where(written[:, None, None], p, 1e8)
    a = paged_flash_decode(q, clean(kp), clean(vp), table, pos,
                           page_size=ps)
    b = paged_flash_decode(q, poison(kp), poison(vp), table, pos,
                           page_size=ps)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# paged_read invariants (the gather the kernel fuses away)
# --------------------------------------------------------------------------

def test_paged_read_page_granular_shape_and_content():
    ps, n_pages, feat = 4, 5, (2, 3)
    pool = jnp.arange(n_pages * ps * 6, dtype=jnp.float32).reshape(
        (n_pages * ps,) + feat)
    table = jnp.asarray([[2, 1, 0], [4, 0, 0]], jnp.int32)
    out, kv_pos = paged_read(pool, PagedView(table, ps))
    assert out.shape == (2, 3 * ps) + feat          # (B, W*ps, ...)
    np.testing.assert_array_equal(np.asarray(kv_pos), np.arange(3 * ps))
    pages = np.asarray(pool).reshape((n_pages, ps) + feat)
    # whole contiguous pages, in table order
    np.testing.assert_array_equal(np.asarray(out[0, :ps]), pages[2])
    np.testing.assert_array_equal(np.asarray(out[0, ps:2 * ps]), pages[1])
    np.testing.assert_array_equal(np.asarray(out[1, :ps]), pages[4])


def test_paged_read_unallocated_blocks_gather_trash_page():
    """Unallocated table entries (0) gather the trash page verbatim —
    they are only safe because the causal mask kills those positions,
    which the poison test above pins end to end."""
    ps = 4
    pool = jnp.zeros((3 * ps, 2), jnp.float32).at[:ps].set(7.0)
    table = jnp.asarray([[1, 0]], jnp.int32)
    out, _ = paged_read(pool, PagedView(table, ps))
    np.testing.assert_array_equal(np.asarray(out[0, ps:]),
                                  np.full((ps, 2), 7.0))
    # zero-filled trash -> unallocated span gathers exact zeros
    out0, _ = paged_read(pool.at[:ps].set(0.0), PagedView(table, ps))
    assert not np.any(np.asarray(out0[0, ps:]))


def test_paged_append_trash_sink_does_not_leak():
    """A retired/idle slot's table row is all zeros: its writes land in
    the trash page (page 0) and NO allocated page changes."""
    ps = 4
    pool = jnp.arange(3 * ps * 2, dtype=jnp.float32).reshape(3 * ps, 2)
    table = jnp.asarray([[0, 0]], jnp.int32)           # trash-routed slot
    new = jnp.full((1, 2, 2), -5.0)
    pos = jnp.asarray([[5, 6]], jnp.int32)             # page 1 of the slot
    out = _paged_append(pool, PagedView(table, ps), pos, new)
    np.testing.assert_array_equal(np.asarray(out[ps:]),
                                  np.asarray(pool[ps:]))
    assert np.any(np.asarray(out[:ps]) != np.asarray(pool[:ps]))


# --------------------------------------------------------------------------
# greedy equality: pallas == xla through the engine (host)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-moe-16b",
                                  "deepseek-v3-671b"])
def test_host_engine_pallas_matches_xla(arch):
    cfg = _cfg(arch)
    params = init_model(cfg, jax.random.PRNGKey(3))
    prompts = _prompts(cfg, (7, 12, 5, 9), seed=10)
    ref = make_engine(cfg, params, engine="continuous", batch_size=2,
                      max_len=64).generate(prompts, 8)
    got = make_engine(cfg.with_overrides(decode_kernel="pallas"), params,
                      engine="continuous", batch_size=2,
                      max_len=64).generate(prompts, 8)
    for i, (r, g) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(r, g, err_msg=f"request {i}")


MESH_PALLAS_SNIPPET = """
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import init_model
from repro.launch.mesh import make_serve_mesh
from repro.serve import make_engine

cfg = smoke_config({arch!r}).with_overrides(dtype="float32")
params = init_model(cfg, jax.random.PRNGKey(3))
prompts = [np.asarray(jax.random.randint(
    jax.random.PRNGKey(10 + i), (L,), 0, cfg.vocab_size))
    for i, L in enumerate((7, 12, 5, 9))]
ref = make_engine(cfg, params, engine="continuous", batch_size=2,
                  max_len=64).generate(prompts, 8)
eng = make_engine(cfg.with_overrides(decode_kernel="pallas"), params,
                  engine="continuous", batch_size=2, max_len=64,
                  mesh=make_serve_mesh(2, 4))
got = eng.generate(prompts, 8)
for i, (r, g) in enumerate(zip(ref, got)):
    assert np.array_equal(r, g), (i, r, g)
# kernel path must not cost pool distribution: storage stays sharded
per = eng.kv.pool_bytes_by_device()
tot = eng.kv.pool_bytes()
assert len(per) == 8 and max(per.values()) == tot // 4, (per, tot)
assert sum(per.values()) == 2 * tot
print("OK", {arch!r})
"""


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-moe-16b"])
def test_mesh_engine_pallas_matches_host_xla(arch):
    """decode_kernel="pallas" on the (2, 4) serve mesh: greedy outputs
    equal the host XLA reference engine, with the paged pool still
    genuinely model-sharded (the kernel pins its OPERANDS replicated,
    never the pool storage)."""
    out = run_with_devices(MESH_PALLAS_SNIPPET.format(arch=arch))
    assert "OK" in out


# --------------------------------------------------------------------------
# fused-sampling dispatch discipline
# --------------------------------------------------------------------------

def test_prefill_fused_sampling_dispatch_discipline():
    """Per request: ceil(S/prefill_chunk) prefill dispatches and ONE
    prefill host sync — the first-token sample rides the last chunk's
    compiled call, no separate sampling launch.  Decode: one dispatch
    and one sync per fused chunk."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    C, K, new = 8, 8, 17
    sch = ContinuousScheduler(cfg, params, slots=4, max_len=64,
                              page_size=16, prefill_chunk=C,
                              decode_chunk=K)
    lengths = (7, 12, 5, 9)
    sch.generate(_prompts(cfg, lengths), new)
    st = sch.stats()
    want_prefill = sum(-(-L // C) for L in lengths)
    assert st["prefill_dispatches"] == want_prefill, st
    assert st["prefill_host_syncs"] == len(lengths), st
    assert st["decode_dispatches"] == st["decode_host_syncs"], st
    # fused loop: K tokens per decode sync; all slots run lockstep here
    assert st["decode_host_syncs"] == -(-(new - 1) // K), st
    assert st["dispatches"] == (st["prefill_dispatches"]
                                + st["decode_dispatches"]), st
    assert st["host_syncs"] == (st["prefill_host_syncs"]
                                + st["decode_host_syncs"]), st
    assert st["syncs_per_token"] < 0.25, st


# --------------------------------------------------------------------------
# perf-model calibration + microbench row schema
# --------------------------------------------------------------------------

ROWS = [
    {"arch": "a", "phase": "ar_step", "batch": 4, "tokens": 8,
     "time_s": 0.08, "flags": "baseline"},
    {"arch": "a", "phase": "ar_step", "batch": 4, "tokens": 8,
     "time_s": 0.064, "flags": "tuned"},
    {"arch": "a", "phase": "prefill", "batch": 4, "tokens": 1,
     "time_s": 0.002, "flags": "baseline"},
    {"arch": "b", "phase": "ar_step", "batch": 2, "tokens": 8,
     "time_s": 0.4, "flags": "baseline"},
]


def test_calibrate_kernel_time_selects_best_row():
    # fastest matching ar_step row, divided down to per-token
    assert perf_model.calibrate_kernel_time(ROWS, arch="a") \
        == pytest.approx(0.064 / 8)
    assert perf_model.calibrate_kernel_time(ROWS, arch="a",
                                            per_token=False) \
        == pytest.approx(0.064)
    assert perf_model.calibrate_kernel_time(ROWS, arch="b", batch=2) \
        == pytest.approx(0.05)
    with pytest.raises(ValueError):
        perf_model.calibrate_kernel_time(ROWS, arch="a", batch=16)


def test_decode_step_time_kernel_floor():
    base = perf_model.decode_step_time(1e9, 1e6, batch=8)
    assert perf_model.decode_step_time(1e9, 1e6, batch=8,
                                       kernel_time_s=0.0) == base
    # a measured floor above the roofline wins
    assert perf_model.decode_step_time(
        1e9, 1e6, batch=8, kernel_time_s=base * 10) == base * 10
    # and feeds through to throughput
    slow = perf_model.decode_tokens_per_s(1e9, 1e6, batch=8,
                                          kernel_time_s=base * 10)
    assert slow == pytest.approx(8 / (base * 10))


def test_microbench_rows_schema():
    """One in-process sweep cell produces rows with the schema the
    calibration helper and the CI artifact consumers read."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    import decode_microbench as mb
    rows = mb._bench_arch("qwen3-1.7b", "schema-test", repeats=1,
                          quick=True)
    phases = {r["phase"] for r in rows}
    assert phases == {"prefill", "insert", "ar_step"}
    kernels = {r["decode_kernel"] for r in rows}
    assert kernels == {"xla", "pallas"}
    for r in rows:
        for k in ("arch", "phase", "decode_kernel", "batch", "page_size",
                  "block_q", "block_kv", "flags", "tokens", "time_s"):
            assert k in r, (k, r)
        assert r["time_s"] > 0
        assert r["tokens"] == (mb.DECODE_CHUNK
                               if r["phase"] == "ar_step" else 1)
    # rows are calibration-ready
    assert perf_model.calibrate_kernel_time(rows, arch="qwen3-1.7b") > 0
