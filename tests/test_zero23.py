"""ZeRO-2/ZeRO-3 on the TrainState contract (ISSUE 3 tentpole).

Acceptance:

* sequential equivalence ≤1e-5 after 5 steps on 8 emulated devices for
  zero2 and zero3, with and without the overlap scheduler (and with
  microbatch accumulation);
* physical 1/p param+grad residency for zero3, asserted via per-device
  live-buffer inspection — between steps no device holds any buffer of
  full-model size;
* ``perf_model.dp_memory_report`` shows zero3 param+state memory ≈ 1/p
  of replicated;
* the zero3 overlap schedule asyncifies into all-gather AND
  reduce-scatter pairs; the serialized schedule admits no all-gather
  pairs (the param gathers are strictly chained);
* the layout contract is enforced loudly (state/config mismatch raises,
  pointing at the migration path).
"""
import os

import numpy as np
import pytest

from conftest import run_with_devices

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import MNIST_DNN
from repro.models import init_paper_net, apply_paper_net
from repro.core import (DPConfig, make_dp_train_step, make_sequential_step,
                        host_params, init_train_state)
from repro import optim

mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
net = MNIST_DNN
key = jax.random.PRNGKey(0)
params = init_paper_net(net, key)
x = jax.random.normal(key, (64, 784)); y = jax.random.randint(key, (64,), 0, 10)
batch = {'x': x, 'y': y}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])

def max_err(t1, t2):
    return max(np.abs(np.asarray(a) - np.asarray(b)).max()
               for a, b in zip(jax.tree_util.tree_leaves(t1),
                               jax.tree_util.tree_leaves(t2)))

def run5(strategy, overlap=False, microbatches=1, opt=None):
    opt = opt or optim.adam(1e-3)
    dp = DPConfig(sync='grads', strategy=strategy, overlap=overlap,
                  microbatches=microbatches, bucket_bytes=1 << 16)
    step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
    s = init_train_state(opt, params, mesh, dp)
    for i in range(5):
        s, m = step(s, batch)
    assert np.isfinite(float(m['loss'])) and float(m['grad_norm']) > 0
    assert int(s.step) == 5
    return s
"""


# --------------------------------------------------------------------------
# sequential equivalence (with and without overlap)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["zero2", "zero3"])
@pytest.mark.parametrize("overlap", [False, True])
def test_matches_sequential(strategy, overlap):
    """Acceptance: zero2/zero3 params ≡ sequential large-batch Adam to
    ≤1e-5 after 5 steps on 8 emulated devices."""
    run_with_devices(COMMON + f"""
opt = optim.adam(1e-3)
seq = make_sequential_step(loss_fn, opt)
s1 = init_train_state(opt, params)
for i in range(5):
    s1, _ = seq(s1, batch)
s2 = run5('{strategy}', overlap={overlap!r})
err = max_err(s1.params, host_params(s2))
print('ERR', err)
assert err < 1e-5, err
""")


@pytest.mark.parametrize("strategy", ["zero2", "zero3"])
def test_microbatches_match_sequential(strategy):
    """zero2's eager per-microbatch shard accumulation and zero3's
    per-microbatch gather/scatter both ≡ one big batch (sgd: exact up
    to reduction order)."""
    run_with_devices(COMMON + f"""
opt = optim.sgd(0.1)
seq = make_sequential_step(loss_fn, opt)
s1 = init_train_state(opt, params)
for i in range(5):
    s1, _ = seq(s1, batch)
for overlap in (False, True, 'serial'):
    s2 = run5('{strategy}', overlap=overlap, microbatches=4,
              opt=optim.sgd(0.1))
    err = max_err(s1.params, host_params(s2))
    print('overlap', overlap, 'ERR', err)
    assert err < 1e-5, (overlap, err)
""")


def test_bf16_wire_bounded():
    """compress='bf16' rides both zero3 wires (param gather + grad
    scatter) — lossy but bounded, fp32 master shard kept."""
    run_with_devices(COMMON + """
opt = optim.adam(1e-3)
seq = make_sequential_step(loss_fn, opt)
s1 = init_train_state(opt, params)
for i in range(5):
    s1, _ = seq(s1, batch)
dp = DPConfig(sync='grads', strategy='zero3', compress='bf16')
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
s2 = init_train_state(opt, params, mesh, dp)
for i in range(5):
    s2, m = step(s2, batch)
err = max_err(s1.params, host_params(s2))
print('ERR', err)
assert 0 < err < 5e-2, err
assert s2.params.dtype == jnp.float32          # fp32 master shard
assert s2.opt_state['m']['flat'].dtype == jnp.float32
""")


# --------------------------------------------------------------------------
# physical residency: params, grads and state live 1/p per device
# --------------------------------------------------------------------------

def test_zero3_physical_residency_one_pth():
    """Acceptance: between steps every zero3 state leaf is physically
    sharded 1/8, and per-device live-buffer inspection finds NO buffer
    of full-model size — the full params/grads never persist."""
    run_with_devices(COMMON + """
import gc
opt = optim.adam(1e-3)
dp = DPConfig(sync='grads', strategy='zero3')
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
state = init_train_state(opt, params, mesh, dp)
total = state.layout.total
padded = state.layout.padded_total
assert padded == total + (-total) % 8
# every persistent leaf: global flat (padded,), shards of padded/8
for name, leaf in [('params', state.params),
                   ('m', state.opt_state['m']['flat']),
                   ('v', state.opt_state['v']['flat'])]:
    assert leaf.shape == (padded,), (name, leaf.shape)
    sizes = {s.data.size for s in leaf.addressable_shards}
    assert sizes == {padded // 8}, (name, sizes)
for _ in range(2):
    state, m = step(state, batch)
jax.block_until_ready(state.params)
# live-buffer sweep: drop every host handle to full-size arrays, then
# no live device buffer may reach full-model size (the batch, shards,
# and metrics are all far smaller)
del params, m
gc.collect()
offenders = []
for arr in jax.live_arrays():
    for s in arr.addressable_shards:
        if s.data.size >= total:
            offenders.append((arr.shape, str(arr.dtype), s.data.size))
assert not offenders, offenders
# the state that survives is still the 1/8 shards
sizes = {s.data.size for s in state.params.addressable_shards}
assert sizes == {padded // 8}, sizes
print('RESIDENCY OK', total, padded // 8)
""")


def test_zero2_grad_shard_is_persistent_state():
    """zero2: the optimizer consumes grad shards directly — the moment
    vectors stay 1/8-sharded across steps and the full gradient
    accumulator never exists (scan carries a (padded/8,) buffer)."""
    run_with_devices(COMMON + """
opt = optim.adam(1e-3)
dp = DPConfig(sync='grads', strategy='zero2', microbatches=4)
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
state = init_train_state(opt, params, mesh, dp)
padded = state.layout.padded_total
for _ in range(2):
    state, m = step(state, batch)
for name in ('m', 'v'):
    leaf = state.opt_state[name]['flat']
    sizes = {s.data.size for s in leaf.addressable_shards}
    assert sizes == {padded // 8}, (name, sizes)
# the lowered module accumulates into the (padded/8,) grad shard:
# the shard-sized f32 buffer appears as a scan carry in the StableHLO
hlo = step.lower(state, batch).as_text()
assert f'tensor<{padded // 8}xf32>' in hlo
print('OK')
""")


def test_zero3_per_shard_init_live_buffers():
    """ROADMAP residency gap closed: init_train_state builds zero3 from
    shape structs / host slices — the full parameter pytree never lands
    on ANY device at construction.  Live-buffer assertion AT INIT TIME
    (before any step): once the caller's own param handles are dropped,
    no device buffer reaches full-model size."""
    run_with_devices(COMMON + """
import gc
opt = optim.adam(1e-3)
dp = DPConfig(sync='grads', strategy='zero3')
state = init_train_state(opt, params, mesh, dp)
total = state.layout.total
# same guarantee from shape structs alone (a restore template): the
# values never exist anywhere, not even on host
pshape = jax.tree_util.tree_map(
    lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params)
tpl = init_train_state(opt, pshape, mesh, dp)
assert tpl.layout == state.layout
assert tpl.params.shape == (state.layout.padded_total,)
del params, pshape
gc.collect()
offenders = []
for arr in jax.live_arrays():
    for s in arr.addressable_shards:
        if s.data.size >= total:
            offenders.append((arr.shape, str(arr.dtype), s.data.size))
assert not offenders, offenders
# both states carry the 1/8 shards and are steppable
for st in (state, tpl):
    sizes = {s.data.size for s in st.params.addressable_shards}
    assert sizes == {state.layout.padded_total // 8}, sizes
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
st, m = step(state, batch)
assert np.isfinite(float(m['loss']))
print('INIT RESIDENCY OK', total)
""")


# --------------------------------------------------------------------------
# memory model + HLO schedule
# --------------------------------------------------------------------------

def test_dp_memory_report_zero3_is_one_pth():
    """Acceptance: modeled zero3 param+grad+state memory ≈ 1/p of the
    replicated layout; the ladder is monotone."""
    from repro.core import perf_model
    n_params, f, p = 178_110, 2, 8
    rpt = perf_model.dp_memory_report(n_params, f, p)
    assert abs(rpt["ratio_zero3"] - 1.0 / p) < 1e-2
    assert abs((rpt["params_zero3"] + rpt["opt_state_zero3"])
               / (rpt["params_replicated"] + rpt["opt_state_replicated"])
               - 1.0 / p) < 1e-2
    assert rpt["total_zero3"] < rpt["total_zero2"] < rpt["total_zero1"] \
        < rpt["total_replicated"]
    assert rpt["grads_zero2"] == rpt["grads_zero3"] \
        < rpt["grads_zero1"]
    # wire model: zero2 pays per-microbatch reduce-scatters, zero3 pays
    # the double param gather; both equal zero1's two halves at the
    # degenerate points
    v = 4.0 * n_params
    t1 = perf_model.zero1_comm_time(v, p=p)
    assert perf_model.zero2_comm_time(v, p=p, microbatches=1) == t1
    assert perf_model.zero2_comm_time(v, p=p, microbatches=4) > t1
    assert perf_model.zero3_comm_time(v, p=p) == pytest.approx(1.5 * t1)
    for strat in ("zero2", "zero3"):
        assert perf_model.bucket_comm_time(v, p=p, strategy=strat) > 0
        assert perf_model.bucket_comm_time(v, p=1, strategy=strat) == 0.0


def test_zero3_hlo_async_pairs():
    """overlap=True asyncifies the per-bucket param all-gathers AND the
    cotangent reduce-scatters; 'serial' admits no all-gather pairs (the
    gathers are strictly chained — only the scalar loss-metric epilogue
    of the forward gather remains concurrent with the grad
    reduce-scatter, see docs)."""
    run_with_devices(COMMON + """
from repro.core import asyncify_hlo, lowered_hlo_text

def rep_of(overlap):
    dp = DPConfig(sync='grads', strategy='zero3', overlap=overlap,
                  bucket_bytes=1 << 16)
    step = make_dp_train_step(loss_fn, optim.adam(1e-3), mesh, dp,
                              donate=False)
    s = init_train_state(optim.adam(1e-3), params, mesh, dp)
    hlo = lowered_hlo_text(step.lower(s, batch))
    return asyncify_hlo(hlo)

txt, rep = rep_of(True)
print('zero3 overlap', rep['pairs'], rep['by_kind'])
assert rep['by_kind'].get('all-gather', 0) >= 2, rep
assert rep['by_kind'].get('reduce-scatter', 0) >= 2, rep
assert 'all-gather-start(' in txt and 'reduce-scatter-start(' in txt

stxt, srep = rep_of('serial')
print('zero3 serial', srep['pairs'], srep['by_kind'])
assert srep['by_kind'].get('all-gather', 0) == 0, srep
assert srep['pairs'] < rep['pairs'], (srep['pairs'], rep['pairs'])
assert 'all-gather-start(' not in stxt
""")


# --------------------------------------------------------------------------
# the layout contract is enforced
# --------------------------------------------------------------------------

def test_layout_mismatch_raises():
    """Feeding a state built for one strategy into another's step (or
    the old loose tuples) fails loudly with the migration hint."""
    run_with_devices(COMMON + """
opt = optim.adam(1e-3)
dp1 = DPConfig(sync='grads', strategy='zero1')
dp3 = DPConfig(sync='grads', strategy='zero3')
s1 = init_train_state(opt, params, mesh, dp1)
step3 = make_dp_train_step(loss_fn, opt, mesh, dp3, donate=False)
try:
    step3(s1, batch)
    raise SystemExit('expected ValueError')
except ValueError as e:
    assert 'zero3' in str(e) and 'zero1' in str(e), e

# bucket-layout drift is caught too
dpb = DPConfig(sync='grads', strategy='zero1', overlap=True,
               bucket_bytes=1 << 16)
stepb = make_dp_train_step(loss_fn, opt, mesh, dpb, donate=False)
try:
    stepb(s1, batch)
    raise SystemExit('expected ValueError')
except ValueError as e:
    assert 'bucket' in str(e).lower(), e

# the old (params, opt_state) tuple contract is gone — loud TypeError
try:
    step3(params, batch)
    raise SystemExit('expected TypeError')
except TypeError as e:
    assert 'TrainState' in str(e), e
print('OK')
""")


def test_sequential_and_replicated_share_contract():
    """make_sequential_step and the replicated DP step speak the same
    TrainState contract — state round-trips between them."""
    run_with_devices(COMMON + """
opt = optim.sgd(0.1)
dp = DPConfig(sync='grads', strategy='flat')
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
seq = make_sequential_step(loss_fn, opt)
s = init_train_state(opt, params, mesh, dp)
s, _ = step(s, batch)
s, _ = seq(s, batch)        # replicated layout: interchangeable
s, _ = step(s, batch)
assert int(s.step) == 3
print('OK')
""")
