"""MoE: routing invariants, capacity semantics, EP-vs-dense equality."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.configs import smoke_config
from repro.models import moe as moe_lib

KEY = jax.random.PRNGKey(3)


def test_router_topk_weights_normalised():
    cfg = smoke_config("deepseek-moe-16b")
    p = moe_lib.init_moe(cfg, KEY)
    xf = jax.random.normal(KEY, (32, cfg.d_model))
    w, idx, aux = moe_lib._routing(cfg, {"router": p["router"]}, xf)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    assert int(idx.max()) < cfg.moe.num_experts
    assert float(aux) > 0.0


def test_generous_capacity_means_no_drops():
    """With capacity >= N*k the MoE output equals the uncapped weighted
    sum of expert outputs."""
    cfg = smoke_config("jamba-v0.1-52b")
    p = moe_lib.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    y_hi, _ = moe_lib.apply_moe_dense(cfg, p, x, capacity_factor=64.0)

    # brute-force: every expert on every token, weighted by router
    m = cfg.moe
    xf = x.reshape(-1, cfg.d_model)
    w, idx, _ = moe_lib._routing(cfg, {"router": p["router"]}, xf)
    dense = jnp.stack([
        moe_lib._expert_ffn(cfg, jax.tree_util.tree_map(
            lambda t: t[e:e + 1], p["experts"]),
            xf[None])[0]
        for e in range(m.num_experts)])               # (E, N, d)
    want = jnp.zeros_like(xf)
    for j in range(m.top_k):
        want = want + w[:, j:j + 1] * dense[idx[:, j], jnp.arange(xf.shape[0])]
    want = want.reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y_hi), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_tight_capacity_drops_tokens():
    cfg = smoke_config("deepseek-moe-16b")
    p = moe_lib.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    y_tight, _ = moe_lib.apply_moe_dense(cfg, p, x, capacity_factor=0.25)
    y_loose, _ = moe_lib.apply_moe_dense(cfg, p, x, capacity_factor=64.0)
    assert np.abs(np.asarray(y_tight) - np.asarray(y_loose)).max() > 1e-4


EP_SNIPPET = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.compat import make_mesh, auto_axis_types, set_mesh
from repro.configs import smoke_config
from repro.models import moe as moe_lib
from repro.sharding.ctx import set_activation_mesh
key = jax.random.PRNGKey(0)
mesh = make_mesh({mesh_shape}, {mesh_axes},
                 axis_types=auto_axis_types({ndim}))
cfg = smoke_config('deepseek-moe-16b')
{cfg_override}
p = moe_lib.init_moe(cfg, key)
x = jax.random.normal(key, {x_shape}, jnp.float32)
set_activation_mesh(None)
y0, a0 = jax.jit(lambda p, x: moe_lib.apply_moe(cfg, p, x,
                 capacity_factor=8.0))(p, x)
set_activation_mesh(mesh)
with set_mesh(mesh):
    y1, a1 = jax.jit(lambda p, x: moe_lib.apply_moe(cfg, p, x,
                     capacity_factor=8.0))(p, x)
set_activation_mesh(None)
err = float(jnp.abs(y0 - y1).max())
print('ERR', err)
assert err < 5e-5, err
"""


def test_ep_all_to_all_path_matches_dense():
    run_with_devices(EP_SNIPPET.format(
        mesh_shape="(2, 2)", mesh_axes="('data', 'model')", ndim=2,
        cfg_override="", x_shape="(4, 8, cfg.d_model)"))


def test_ep_expert_fsdp_path_matches_dense():
    run_with_devices(EP_SNIPPET.format(
        mesh_shape="(2, 2)", mesh_axes="('data', 'model')", ndim=2,
        cfg_override=("cfg = cfg.with_overrides(moe=dataclasses.replace("
                      "cfg.moe, num_experts=6, top_k=2, d_expert=128))"),
        x_shape="(4, 8, cfg.d_model)"))


def test_ep_unsharded_batch_matches_dense():
    run_with_devices(EP_SNIPPET.format(
        mesh_shape="(2, 2)", mesh_axes="('data', 'model')", ndim=2,
        cfg_override="", x_shape="(1, 8, cfg.d_model)"))


def test_ep_multipod_matches_dense():
    run_with_devices(EP_SNIPPET.format(
        mesh_shape="(2, 2, 2)", mesh_axes="('pod', 'data', 'model')",
        ndim=3, cfg_override="", x_shape="(4, 8, cfg.d_model)"))


# --------------------------------------------------------------------------
# aux-free router-bias balancing (V3): dtype-stable update
# --------------------------------------------------------------------------

def test_update_router_bias_exact_gamma_opposite_directions():
    """Over/underloaded experts move by EXACTLY gamma in opposite
    directions — in fp32, regardless of the count dtype."""
    cfg = smoke_config("deepseek-v3-671b")   # sigmoid router: has bias
    p = moe_lib.init_moe(cfg, KEY)
    gamma = 1e-3
    E = cfg.moe.num_experts
    counts = np.full((E,), 8)
    counts[0], counts[1] = 20, 0          # over / under; rest at mean-ish
    for dt in (np.int32, np.float32, jnp.bfloat16):
        new = moe_lib.update_router_bias(cfg, p, jnp.asarray(counts, dt),
                                         gamma=gamma)
        d = np.asarray(new, np.float64) - np.asarray(p["router_bias"],
                                                     np.float64)
        assert d[0] == -np.float32(gamma), (dt, d[0])
        assert d[1] == +np.float32(gamma), (dt, d[1])


def test_update_router_bias_no_bf16_freeze():
    """The regression: a bf16-accumulated update at |bias|~8 rounds a
    1e-3 step to ZERO (ulp is 0.0625 there) and balancing silently
    freezes; the fp32 accumulate keeps stepping."""
    cfg = smoke_config("deepseek-v3-671b")
    p = moe_lib.init_moe(cfg, KEY)
    big = jnp.full_like(p["router_bias"], 8.0)
    p = dict(p, router_bias=big)
    counts = jnp.asarray(
        np.r_[20, np.full((cfg.moe.num_experts - 1,), 8)], jnp.bfloat16)
    new = moe_lib.update_router_bias(cfg, p, counts, gamma=1e-3)
    # the overloaded expert's bias must actually move (fp32 resolves it)
    assert float(new[0]) < 8.0
