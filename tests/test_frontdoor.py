"""Async front door: non-blocking submit, streaming handles, priority
ordering, per-tenant quotas — plus the serving-path regressions this
PR fixes (mid-pass slot reuse, TTFT stats windowing).
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_model
from repro.serve import ContinuousScheduler, FrontDoor

KEY = jax.random.PRNGKey(7)


def _cfg(**kw):
    return smoke_config("qwen3-1.7b").with_overrides(dtype="float32", **kw)


def _prompt(seed, n, vocab):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, vocab))


def _sched(params, **kw):
    base = dict(slots=2, max_len=64, page_size=8, prefill_chunk=8,
                decode_chunk=4, num_pages=32)
    base.update(kw)
    return ContinuousScheduler(_cfg(), params, **base)


@pytest.fixture(scope="module")
def params():
    return init_model(_cfg(), KEY)


# --------------------------------------------------------------------------
# streaming
# --------------------------------------------------------------------------

def test_submit_is_nonblocking_and_stream_matches_batch(params):
    cfg = _cfg()
    prompts = [_prompt(i, 10 + i, cfg.vocab_size) for i in range(3)]
    ref = _sched(params).generate(prompts, 8)

    fd = FrontDoor(_sched(params))
    handles = [fd.submit(p, 8) for p in prompts]
    assert fd.sched.dispatches == 0            # no device work yet
    assert all(h.available() == [] for h in handles)
    streamed = [[t for t in h] for h in handles]
    for got, want in zip(streamed, ref):
        np.testing.assert_array_equal(np.asarray(got, np.int32), want)
    assert all(h.done for h in handles)
    assert fd.in_flight == 0                   # results harvested


def test_tokens_arrive_in_decode_chunk_bursts(params):
    cfg = _cfg()
    fd = FrontDoor(_sched(params, slots=1))
    h = fd.submit(_prompt(0, 12, cfg.vocab_size), 8)
    sizes = []
    while not h.done:
        fd.pump()
        got = h.available()
        if got:
            sizes.append(len(got))
    # one tick = admission (prefill seeds 1 token) + one fused decode
    # chunk, so bursts are at most 1 + decode_chunk tokens
    assert len(sizes) >= 2                     # streaming, not one blob
    assert sum(sizes) == 8
    assert all(s <= 5 for s in sizes)
    assert h.ttft is not None and h.ttft >= 0


def test_interleaved_consumers_see_shared_progress(params):
    cfg = _cfg()
    fd = FrontDoor(_sched(params))
    h1 = fd.submit(_prompt(1, 9, cfg.vocab_size), 8)
    h2 = fd.submit(_prompt(2, 11, cfg.vocab_size), 8)
    next(h1)                                   # pumping h1 advances h2 too
    while not h1.done:
        fd.pump()
    assert len(h2.available()) > 0
    r2 = h2.result()
    assert len(r2) == 8


# --------------------------------------------------------------------------
# priority + tenant quotas
# --------------------------------------------------------------------------

def test_priority_order_admits_high_first(params):
    cfg = _cfg()
    fd = FrontDoor(_sched(params, slots=1))
    lo = fd.submit(_prompt(0, 10, cfg.vocab_size), 4, priority=0)
    hi = fd.submit(_prompt(1, 10, cfg.vocab_size), 4, priority=5)
    hi2 = fd.submit(_prompt(2, 10, cfg.vocab_size), 4, priority=5)
    fd.drain()
    # high priority admits first; equal priorities keep submit order
    assert hi._req.t_first < hi2._req.t_first < lo._req.t_first


def test_tenant_quota_skips_not_blocks(params):
    cfg = _cfg()
    fd = FrontDoor(_sched(params), quotas={"a": 1})
    a1 = fd.submit(_prompt(0, 10, cfg.vocab_size), 8, tenant="a")
    a2 = fd.submit(_prompt(1, 10, cfg.vocab_size), 8, tenant="a")
    b1 = fd.submit(_prompt(2, 10, cfg.vocab_size), 8, tenant="b")
    fd.pump()
    # a2 is quota-blocked but does NOT head-of-line block b1
    active = {r.uid for r in fd.sched._active.values()}
    assert a1.uid in active and b1.uid in active
    assert a2.uid not in active
    fd.drain()
    assert all(h.done for h in (a1, a2, b1))
    assert len(a2.result()) == 8
    # a2 could only start after a1 finished its slot
    assert a2._req.t_first > a1._req.t_done


def test_quota_validation():
    params = init_model(_cfg(), KEY)
    with pytest.raises(ValueError, match=">= 1"):
        _sched(params, tenant_quota=0)
    with pytest.raises(ValueError, match=">= 1"):
        FrontDoor(_sched(params), quotas={"a": 0})


# --------------------------------------------------------------------------
# serving-path regressions
# --------------------------------------------------------------------------

def test_slot_freed_mid_pass_admits_same_tick(params):
    """Regression: a request that retires AT PREFILL (EOS on its first
    sampled token) frees its slot mid-admission-pass; the queued
    request behind it must admit in the SAME tick, not strand until the
    next decode-chunk boundary."""
    cfg = _cfg()
    probe = _prompt(3, 10, cfg.vocab_size)
    first = int(_sched(params, slots=1).generate([probe], 1)[0][0])

    sch = _sched(params, slots=1, eos_id=first)
    u_eos = sch.submit(probe, 8)               # retires at its first token
    u_next = sch.submit(_prompt(4, 10, cfg.vocab_size), 4)
    sch.tick()
    done = sch.take_results()
    assert u_eos in done                       # EOS fired at prefill...
    assert list(done[u_eos].out) == [first]
    assert u_next in {r.uid for r in sch._active.values()} \
        or not sch._pending                    # ...and the queue moved on
    sch.run()


def test_budget_one_requests_drain_in_single_tick(params):
    """max_new_tokens=1 requests retire at prefill: one admission pass
    serves the whole queue through a single slot."""
    cfg = _cfg()
    sch = _sched(params, slots=1)
    uids = [sch.submit(_prompt(10 + i, 8, cfg.vocab_size), 1)
            for i in range(3)]
    assert sch.tick() is False                 # nothing left after one tick
    done = sch.take_results()
    assert sorted(done) == sorted(uids)


def test_ttft_stats_window_resets_per_run(params):
    """Regression: ``stats()["ttft_s"]`` is windowed to the current/last
    ``run()`` — it must not grow without bound (or re-report old
    requests) on a long-lived scheduler; the cumulative counters keep
    the lifetime view."""
    cfg = _cfg()
    sch = _sched(params)
    sch.generate([_prompt(0, 8, cfg.vocab_size)] * 3, 4)
    st1 = sch.stats()
    assert len(st1["ttft_s"]) == 3
    assert st1["ttft_count_cum"] == 3

    sch.generate([_prompt(1, 8, cfg.vocab_size)] * 2, 4)
    st2 = sch.stats()
    assert len(st2["ttft_s"]) == 2             # window: THIS run only
    assert st2["ttft_count_cum"] == 5          # lifetime keeps counting
    assert st2["ttft_sum_cum_s"] >= st1["ttft_sum_cum_s"]
