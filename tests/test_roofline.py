"""Roofline accounting: the StableHLO cost walker must be exact on
counted scans (including nested and differentiated), and the collective
walker must handle tuple-output ops and loop trip counts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlocost import stablehlo_cost
from repro.launch.dryrun import collective_bytes_from_hlo
from repro.roofline.analysis import model_flops, V5E


def test_walker_exact_on_scan():
    def f(x, w):
        def body(c, _):
            return c, x @ w
        _, ys = jax.lax.scan(body, 0., None, length=10)
        return ys
    x = jnp.zeros((64, 128))
    w = jnp.zeros((128, 32))
    c = stablehlo_cost(jax.jit(f).lower(x, w).as_text())
    assert c["flops"] == 10 * 2 * 64 * 32 * 128
    assert c["unresolved_loops"] == 0


def test_walker_exact_on_nested_scan():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c
    w = jnp.zeros((128, 128))
    x = jnp.zeros((64, 128))
    c = stablehlo_cost(jax.jit(g).lower(x, w).as_text())
    assert c["flops"] == 15 * 2 * 64 * 128 * 128


def test_walker_exact_through_grad():
    def h(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(c)
    w = jnp.zeros((128, 128))
    x = jnp.zeros((64, 128))
    c = stablehlo_cost(jax.jit(jax.grad(h)).lower(w, x).as_text())
    # fwd 7 dots; bwd 2 dots per step (dx and dw)
    assert c["flops"] == 21 * 2 * 64 * 128 * 128


def test_collective_walker_tuple_and_trips():
    hlo = """
HloModule m
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %t = (f32[4]{0}, f32[4]{0}) all-to-all(%a, %b), replica_groups={}
  %big = bf16[2,8,16]{2,1,0} all-gather(%y), dimensions={1}
  %r = f32[8]{0} all-reduce(%x), to_apply=%add
  ROOT %tup = (s32[], f32[8]) tuple(%i, %r)
}
%cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (x: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %gte = f32[8] get-tuple-element(%w), index=1
}
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-to-all"] == 5 * (4 + 4) * 4      # 5 trips, tuple of two f32[4]
    assert out["all-reduce"] == 5 * 8 * 4
    assert out["all-gather"] == 5 * 2 * 8 * 16 * 2   # layout braces with commas


def test_model_flops_consistency():
    # train = 3x prefill per token
    t = model_flops("qwen3-1.7b", "train_4k")
    p = model_flops("qwen3-1.7b", "prefill_32k")
    tokens_t = 256 * 4096
    tokens_p = 32 * 32768
    assert abs(t / tokens_t / (p / tokens_p) - 3.0) < 1e-6
