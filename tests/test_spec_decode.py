"""Speculative multi-token decode: MTP draft-verify fused into the
one-sync scan.

The lossless contract: with ``spec_decode=k`` every emitted token is
the VERIFY forward's argmax, so greedy outputs are bitwise-equal to the
non-speculative engine by construction — draft quality only moves the
acceptance rate (and therefore dispatches per token), never the text.
These tests pin that contract on the host path and the (2, 4) serve
mesh, for both paged-decode kernels, plus the host-side accept/rollback
machinery (``accept_speculative``), the page-slack guard, the trained-
MTP-checkpoint serve path, and the perf-model acceptance term.

Acceptance-rate-dependent tests (dispatch discipline, EOS mid-chunk)
train a tiny model first: random-init drafts accept ~nothing, which
exercises losslessness but not the speedup.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_with_devices
from repro.configs import smoke_config
from repro.models import init_model
from repro.serve import make_engine
from repro.serve.sampling import SamplingConfig, accept_speculative
from repro.serve.scheduler import ContinuousScheduler

TINY = dict(mtp_depth=1, d_model=64, d_ff=128, num_heads=2,
            num_kv_heads=1, head_dim=32)


def _cfg(arch="qwen3-1.7b", **over):
    return smoke_config(arch).with_overrides(dtype="float32", **over)


def _prompts(cfg, lens=(7, 12, 5, 9)):
    return [np.asarray(jax.random.randint(
        jax.random.PRNGKey(10 + i), (L,), 0, cfg.vocab_size))
        for i, L in enumerate(lens)]


# --------------------------------------------------------------------------
# accept_speculative: the pure accept/emit/rollback decision
# --------------------------------------------------------------------------

def _accept(targets, chunk, done=None, pad_id=0, eos_id=None):
    t = jnp.asarray(targets, jnp.int32)
    c = jnp.asarray(chunk, jnp.int32)
    d = (jnp.zeros((t.shape[0],), bool) if done is None
         else jnp.asarray(done))
    emit, n_emit, n_acc, done_new = accept_speculative(t, c, d, pad_id,
                                                       eos_id)
    return (np.asarray(emit), np.asarray(n_emit), np.asarray(n_acc),
            np.asarray(done_new))


def test_accept_full_partial_none():
    # chunk = [carried, draft0, draft1, draft2]; targets = verify argmax
    targets = [[10, 11, 12, 13]] * 3
    chunk = [[9, 10, 11, 12],    # all drafts match -> all 4 emit
             [9, 10, 99, 12],    # draft1 wrong -> prefix of 1 accepted
             [9, 99, 11, 12]]    # draft0 wrong -> nothing accepted
    emit, n_emit, n_acc, done = _accept(targets, chunk)
    assert n_acc.tolist() == [3, 1, 0]
    assert n_emit.tolist() == [4, 2, 1]
    assert emit.tolist() == [[10, 11, 12, 13],
                             [10, 11, 0, 0],
                             [10, 0, 0, 0]]
    assert not done.any()
    # the carried token's target ALWAYS emits: n_emit = n_acc + 1
    assert (n_emit == n_acc + 1).all()


def test_accept_done_lane_pinned():
    emit, n_emit, n_acc, done = _accept(
        [[10, 11]], [[9, 10]], done=[True], pad_id=7)
    assert n_emit.tolist() == [0]
    assert emit.tolist() == [[7, 7]]       # nothing leaks from a done lane
    assert done.tolist() == [True]         # and it stays done


def test_accept_eos_mid_window_truncates():
    # EOS lands at emit index 1 of a fully-accepted 4-chunk: the EOS
    # itself emits, everything after it is dropped, the lane retires
    emit, n_emit, n_acc, done = _accept(
        [[10, 5, 12, 13]], [[9, 10, 5, 12]], eos_id=5)
    assert n_emit.tolist() == [2]
    assert emit.tolist() == [[10, 5, 0, 0]]
    assert done.tolist() == [True]


def test_accept_eos_at_carried_target():
    emit, n_emit, n_acc, done = _accept(
        [[5, 11, 12]], [[9, 5, 11]], eos_id=5)
    assert n_emit.tolist() == [1]
    assert emit.tolist() == [[5, 0, 0]]
    assert done.tolist() == [True]


def test_accept_eos_beyond_accepted_prefix_ignored():
    # an EOS in the REJECTED region must not retire the lane
    emit, n_emit, n_acc, done = _accept(
        [[10, 11, 5]], [[9, 10, 99]], eos_id=5)
    assert n_emit.tolist() == [2]
    assert done.tolist() == [False]


# --------------------------------------------------------------------------
# lossless greedy: host path, both kernels, k in {2, 4}; MLA+MoE arch
# --------------------------------------------------------------------------

_PARAM_CACHE = {}


def _params_for(cfg, seed=3):
    key = (cfg.name, cfg.mtp_depth, cfg.decode_kernel, seed)
    if key not in _PARAM_CACHE:
        _PARAM_CACHE[key] = init_model(cfg, jax.random.PRNGKey(seed))
    return _PARAM_CACHE[key]


@pytest.mark.parametrize("kernel", ["xla", "pallas"])
@pytest.mark.parametrize("k", [2, 4])
def test_spec_bitwise_host(kernel, k):
    cfg = _cfg(mtp_depth=1, decode_kernel=kernel)
    params = _params_for(cfg)
    prompts = _prompts(cfg)
    kw = dict(slots=2, max_len=96, page_size=16, prefill_chunk=8,
              decode_chunk=4)
    ref = ContinuousScheduler(cfg, params, **kw).generate(prompts, 8)
    sch = ContinuousScheduler(cfg, params, spec_decode=k, **kw)
    got = sch.generate(prompts, 8)
    for i, (r, g) in enumerate(zip(ref, got)):
        assert np.array_equal(r, g), (i, r, g)
    sd = sch.stats()["spec_decode"]
    assert sd["k"] == k and sd["verify_steps"] > 0
    # per-slot telemetry covers every slot that decoded
    assert len(sd["slot_accepted_len"]) == 2
    assert sum(sd["slot_verify_steps"]) == sd["verify_steps"]


def test_spec_bitwise_mla_moe():
    """deepseek-v3-671b smoke: MLA attention + MoE FFN + the config's
    own MTP depth — the arch family the draft head was built for."""
    cfg = _cfg("deepseek-v3-671b")
    assert cfg.mtp_depth > 0          # native MTP, no override needed
    params = _params_for(cfg)
    prompts = _prompts(cfg, lens=(6, 9, 5))
    kw = dict(slots=3, max_len=96, page_size=16, prefill_chunk=8,
              decode_chunk=4)
    ref = ContinuousScheduler(cfg, params, **kw).generate(prompts, 6)
    got = ContinuousScheduler(cfg, params, spec_decode=3,
                              **kw).generate(prompts, 6)
    for i, (r, g) in enumerate(zip(ref, got)):
        assert np.array_equal(r, g), (i, r, g)


def test_spec_composes_with_prefix_cache():
    """Aliased prompt pages are safe under spec decode: rejected-draft
    garbage lands at positions >= S in the slot's PRIVATE slack pages,
    never in shared prefix pages."""
    cfg = _cfg(mtp_depth=1)
    params = _params_for(cfg)
    shared = np.asarray(jax.random.randint(
        jax.random.PRNGKey(9), (16,), 0, cfg.vocab_size))
    rng = np.random.default_rng(3)
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, 3 + i)
                               .astype(np.int32)]) for i in range(3)]
    kw = dict(slots=2, max_len=96, page_size=8, prefill_chunk=8,
              decode_chunk=4, num_pages=64)
    ref = ContinuousScheduler(cfg, params, **kw).generate(prompts, 6)
    sch = ContinuousScheduler(cfg, params, spec_decode=3,
                              prefix_cache=True, **kw)
    got = sch.generate(prompts, 6)
    for i, (r, g) in enumerate(zip(ref, got)):
        assert np.array_equal(r, g), (i, r, g)
    assert sch.stats()["prefix_hit_rate"] > 0


# --------------------------------------------------------------------------
# (2, 4) serve mesh: placement must stay a pure placement change
# --------------------------------------------------------------------------

SPEC_MESH_SNIPPET = """
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import init_model
from repro.launch.mesh import make_serve_mesh
from repro.serve import make_engine

for kernel in ("xla", "pallas"):
    cfg = smoke_config("qwen3-1.7b").with_overrides(
        dtype="float32", mtp_depth=1, decode_kernel=kernel)
    params = init_model(cfg, jax.random.PRNGKey(3))
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(10 + i), (L,), 0, cfg.vocab_size))
        for i, L in enumerate((7, 12, 5, 9))]
    ref = make_engine(cfg, params, engine="continuous", batch_size=2,
                      max_len=96).generate(prompts, 8)
    mesh = make_serve_mesh(2, 4)
    for k in (2, 4):
        eng = make_engine(cfg, params, engine="continuous",
                          batch_size=2, max_len=96, mesh=mesh,
                          spec_decode=k)
        got = eng.generate(prompts, 8)
        for i, (r, g) in enumerate(zip(ref, got)):
            assert np.array_equal(r, g), (kernel, k, i, r, g)
        per = eng.kv.pool_bytes_by_device()
        assert len(per) == 8 and \\
            max(per.values()) == eng.kv.pool_bytes() // 4
        print("OK", kernel, k)
"""


def test_spec_mesh_bitwise_both_kernels():
    out = run_with_devices(SPEC_MESH_SNIPPET)
    for kernel in ("xla", "pallas"):
        for k in (2, 4):
            assert f"OK {kernel} {k}" in out, out


# --------------------------------------------------------------------------
# construction guards + page-slack accounting
# --------------------------------------------------------------------------

def test_spec_requires_mtp_heads():
    cfg = _cfg()                      # qwen3 smoke: mtp_depth == 0
    assert cfg.mtp_depth == 0
    with pytest.raises(ValueError, match="mtp_depth"):
        ContinuousScheduler(cfg, _params_for(cfg), slots=2, max_len=64,
                            spec_decode=2)


def test_spec_is_greedy_only():
    cfg = _cfg(mtp_depth=1)
    with pytest.raises(ValueError, match="greedy"):
        ContinuousScheduler(cfg, _params_for(cfg), slots=2, max_len=64,
                            spec_decode=2,
                            sampling=SamplingConfig(temperature=0.7))


def test_spec_k1_rejected():
    cfg = _cfg(mtp_depth=1)
    with pytest.raises(ValueError, match="spec_decode"):
        ContinuousScheduler(cfg, _params_for(cfg), slots=2, max_len=64,
                            spec_decode=1)


def test_submit_guard_accounts_spec_slack():
    """Per-slot page allocation must cover the worst case: every fused
    step writes a full k-chunk plus k rejected-draft positions past the
    budget — slack = decode_chunk*k + k instead of decode_chunk."""
    cfg = _cfg(mtp_depth=1)
    params = _params_for(cfg)
    kw = dict(slots=1, max_len=64, page_size=16, decode_chunk=4)
    plain = ContinuousScheduler(cfg, params, **kw)
    spec = ContinuousScheduler(cfg, params, spec_decode=4, **kw)
    assert plain._chunk_slack == 4
    assert spec._chunk_slack == 4 * 4 + 4
    prompt = np.arange(1, 9, dtype=np.int32)        # S = 8
    plain.submit(prompt, 64 - 8 - 4)                # fits exactly
    with pytest.raises(ValueError, match="spec_decode"):
        spec.submit(prompt, 64 - 8 - 4)             # same budget: too big
    spec.submit(prompt, 64 - 8 - 20)                # spec-adjusted: fits


# --------------------------------------------------------------------------
# trained-MTP behaviour: EOS mid-chunk, checkpoint serve, dispatch drop
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trained_mtp():
    """Tiny qwen3-style model with an MTP head, trained to saturation
    on the alternating [3, 5] stream: drafts become near-perfect, so
    acceptance ~= 1 and EOS (=5) lands mid-verify-chunk."""
    from repro.api import Trainer
    cfg = _cfg(**TINY)
    tok = jnp.tile(jnp.asarray([3, 5], jnp.int32), (8, 16))
    tr = Trainer.create(model_cfg=cfg, optimizer="adam", lr=3e-3)
    for _ in range(60):
        tr.step({"tokens": tok})
    return cfg, tr


def test_eos_mid_chunk_and_no_post_eos_tokens(trained_mtp):
    cfg, tr = trained_mtp
    params = tr.params
    prompt = np.tile(np.asarray([3, 5], np.int32), 6)   # ends in 5
    kw = dict(slots=2, max_len=96, page_size=16, prefill_chunk=8,
              decode_chunk=4, eos_id=5)
    ref = ContinuousScheduler(cfg, params, **kw).generate([prompt], 12)
    sch = ContinuousScheduler(cfg, params, spec_decode=4, **kw)
    got = sch.generate([prompt], 12)
    assert np.array_equal(ref[0], got[0]), (ref[0], got[0])
    # the model continues ... 3, 5(EOS): retire mid-stream, nothing after
    assert got[0].tolist() == [3, 5]
    sd = sch.stats()["spec_decode"]
    assert sd["verify_steps"] >= 1


def test_trained_mtp_checkpoint_serves_with_spec(trained_mtp, tmp_path):
    """Satellite: a checkpoint trained with MTP heads restores into
    serving with ``params["mtp"]`` intact, and ``spec_decode`` drafts
    from it — outputs equal the non-spec restore of the same step."""
    from repro.serve import make_engine_from_checkpoint
    cfg, tr = trained_mtp
    tr.save(tmp_path)
    kw = dict(engine="continuous", batch_size=2, max_len=96,
              page_size=16)
    ref_eng = make_engine_from_checkpoint(tmp_path, cfg, **kw)
    assert "mtp" in ref_eng.params          # the head survived restore
    eng = make_engine_from_checkpoint(tmp_path, cfg, spec_decode=2,
                                      **kw)
    assert eng.restored_step == ref_eng.restored_step
    prompts = [np.tile(np.asarray([3, 5], np.int32), 4),
               np.tile(np.asarray([5, 3], np.int32), 3)]
    ref = ref_eng.generate(prompts, 6)
    got = eng.generate(prompts, 6)
    for r, g in zip(ref, got):
        assert np.array_equal(r, g), (r, g)


def test_dispatch_discipline_speedup(trained_mtp):
    """The acceptance criterion: at measured acceptance >= 0.6, decode
    dispatches (== host syncs) per emitted token drop >= 1.8x vs the
    non-speculative engine on the same workload."""
    cfg, tr = trained_mtp
    params = tr.params
    prompts = [np.tile(np.asarray([3, 5], np.int32), 4)
               for _ in range(4)]
    kw = dict(slots=4, max_len=128, page_size=16, prefill_chunk=8,
              decode_chunk=8)
    new = 32
    base = ContinuousScheduler(cfg, params, **kw)
    ref = base.generate(prompts, new)
    spec = ContinuousScheduler(cfg, params, spec_decode=4, **kw)
    got = spec.generate(prompts, new)
    for r, g in zip(ref, got):
        assert np.array_equal(r, g)
    sd = spec.stats()["spec_decode"]
    assert sd["acceptance"] >= 0.6, sd
    base_dpt = base.stats()["decode_dispatches"] / base.tokens_out
    spec_dpt = spec.stats()["decode_dispatches"] / spec.tokens_out
    drop = base_dpt / spec_dpt
    assert drop >= 1.8, (drop, sd)
    # same for the sync side of the discipline
    sync_drop = (base.stats()["decode_host_syncs"] / base.tokens_out) \
        / (spec.stats()["decode_host_syncs"] / spec.tokens_out)
    assert sync_drop >= 1.8, sync_drop


# --------------------------------------------------------------------------
# sampled decode stays deterministic under variable tokens-per-tick
# --------------------------------------------------------------------------

def test_sampled_decode_deterministic_across_chunk_width():
    """The per-step PRNG split lives INSIDE the fused scan carry, so
    regrouping steps into different decode_chunk widths must not move
    any sample."""
    cfg = _cfg()
    params = _params_for(cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (7, 12, 9)]
    sc = SamplingConfig(temperature=0.8, top_k=7)
    outs = []
    for chunk in (2, 8):
        eng = ContinuousScheduler(cfg, params, slots=3, max_len=64,
                                  page_size=16, prefill_chunk=8,
                                  decode_chunk=chunk, sampling=sc,
                                  seed=3)
        outs.append(eng.generate(prompts, 10))
    for a, b in zip(*outs):
        assert np.array_equal(a, b), (a, b)


# --------------------------------------------------------------------------
# perf model: the acceptance term
# --------------------------------------------------------------------------

def test_spec_expected_tokens_values():
    from repro.core.perf_model import spec_expected_tokens
    assert spec_expected_tokens(0.6, 4) == pytest.approx(
        1 + 0.6 + 0.36 + 0.216)            # 2.176
    assert spec_expected_tokens(1.0, 4) == pytest.approx(4.0)
    assert spec_expected_tokens(0.6, 2) == pytest.approx(1.6)
    assert spec_expected_tokens(0.0, 4) == pytest.approx(1.0)
    assert spec_expected_tokens(0.5, 1) == pytest.approx(1.0)
    assert spec_expected_tokens(2.0, 3) == pytest.approx(3.0)  # clamped


def test_decode_tokens_per_s_acceptance_term():
    """HBM-bound decode (tiny per-token FLOPs): the verify step streams
    the same weights a 1-token step does, so modeled throughput scales
    by exactly the expected-tokens factor."""
    from repro.core.perf_model import (decode_tokens_per_s,
                                       spec_expected_tokens)
    kw = dict(batch=8, flops_per_token=0.0)
    base = decode_tokens_per_s(1e9, 1e6, **kw)
    for a, k in ((0.6, 4), (1.0, 2), (0.3, 3)):
        spec = decode_tokens_per_s(1e9, 1e6, acceptance=a, spec_k=k,
                                   **kw)
        assert spec / base == pytest.approx(spec_expected_tokens(a, k))
    # compute term DOES scale with k: at high FLOPs the win shrinks
    kw2 = dict(batch=8, flops_per_token=1e12)
    base2 = decode_tokens_per_s(1e9, 1e6, **kw2)
    spec2 = decode_tokens_per_s(1e9, 1e6, acceptance=0.6, spec_k=4,
                                **kw2)
    assert spec2 / base2 < spec_expected_tokens(0.6, 4)
