"""Shared test helpers.

NOTE: no XLA_FLAGS here on purpose — unit/smoke tests run on the single
real CPU device.  Tests that need multiple devices spawn a subprocess
with --xla_force_host_platform_device_count (see `run_with_devices`).
"""
import os
import subprocess
import sys
import textwrap

import pytest


def run_with_devices(code: str, n_devices: int = 8) -> str:
    """Run a python snippet in a subprocess with N emulated devices.
    Raises on failure; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-3000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)
