"""Per-architecture smoke tests (deliverable f): reduced variant of each
family — 2 layers, d_model<=512, <=4 experts — one forward and one train
step on CPU, asserting output shapes and finiteness, plus
prefill+decode == full-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHITECTURES, smoke_config
from repro.models import init_model, apply_model, init_cache
from repro.train.loss import lm_loss

ARCHS = sorted(ARCHITECTURES)
KEY = jax.random.PRNGKey(7)
B, S = 2, 16


def make_batch(cfg, key, seq=S):
    toks = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        return {"src_embeds": jax.random.normal(key, (B, seq, cfg.d_model)),
                "tgt_tokens": toks}
    if cfg.frontend == "vision":
        nv = cfg.num_frontend_tokens
        n_text = max(seq - nv, 8)   # keep enough text for a real loss
        return {"tokens": toks[:, :n_text],
                "vision_embeds": jax.random.normal(key, (B, nv, 1024))}
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = init_model(cfg, KEY)
    batch = make_batch(cfg, KEY)
    out = apply_model(cfg, params, batch, mode="train")
    toks = batch.get("tgt_tokens", batch.get("tokens"))
    exp_len = toks.shape[1] + (cfg.num_frontend_tokens
                               if cfg.frontend == "vision" else 0)
    assert out["logits"].shape == (B, exp_len, cfg.vocab_size)
    assert np.isfinite(np.asarray(out["logits"])).all()
    assert np.isfinite(float(out["aux"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = smoke_config(arch).with_overrides(dtype="float32")
    params = init_model(cfg, KEY)
    batch = make_batch(cfg, KEY)
    opt = optim.adam(1e-3)
    state = opt.init(params)

    def loss_fn(p):
        out = apply_model(cfg, p, batch, mode="train")
        total, _ = lm_loss(cfg, out, batch)
        return total

    l0, grads = jax.value_and_grad(loss_fn)(params)
    new_params, state = opt.update(grads, state, params)
    l1 = loss_fn(new_params)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    # at least one parameter must actually change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert changed
    assert float(l1) < float(l0) + 1e-3  # step must not blow the loss up


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(arch).with_overrides(dtype="float32")
    params = init_model(cfg, KEY)
    batch_full = make_batch(cfg, KEY)
    toks = batch_full.get("tgt_tokens", batch_full.get("tokens"))
    pre_toks = toks[:, :-1]
    if cfg.is_encoder_decoder:
        batch_pre = dict(batch_full, tgt_tokens=pre_toks)
        pre_len = pre_toks.shape[1]
    elif cfg.frontend == "vision":
        batch_pre = dict(batch_full, tokens=pre_toks)
        pre_len = cfg.num_frontend_tokens + pre_toks.shape[1]
    else:
        batch_pre = {"tokens": pre_toks}
        pre_len = pre_toks.shape[1]

    full = apply_model(cfg, params, batch_full, mode="train")["logits"]
    cache = init_cache(cfg, B, pre_len + 4, jnp.float32,
                       cross_len=batch_full["src_embeds"].shape[1]
                       if cfg.is_encoder_decoder else 0)
    pre = apply_model(cfg, params, batch_pre, mode="prefill", cache=cache,
                      cache_pos=0)
    dec = apply_model(cfg, params, {"tokens": toks[:, -1:]}, mode="decode",
                      cache=pre["cache"], cache_pos=pre_len)
    np.testing.assert_allclose(
        np.asarray(dec["logits"][:, 0]), np.asarray(full[:, -1]),
        atol=2e-5, rtol=2e-5)


def test_swa_variant_restricts_context():
    """Sliding-window attention must change logits vs full attention."""
    cfg = smoke_config("qwen3-1.7b").with_overrides(dtype="float32")
    params = init_model(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    full = apply_model(cfg, params, {"tokens": toks}, mode="train")["logits"]
    cfg_swa = cfg.with_overrides(swa_window=4)
    swa = apply_model(cfg_swa, params, {"tokens": toks},
                      mode="train")["logits"]
    # early positions (< window) identical, late positions differ
    np.testing.assert_allclose(np.asarray(full[:, :4]),
                               np.asarray(swa[:, :4]), atol=1e-5)
    assert np.abs(np.asarray(full[:, -1]) - np.asarray(swa[:, -1])).max() > 1e-4


def test_mtp_head_present_and_shaped():
    cfg = smoke_config("deepseek-v3-671b").with_overrides(dtype="float32")
    params = init_model(cfg, KEY)
    batch = make_batch(cfg, KEY)
    out = apply_model(cfg, params, batch, mode="train")
    assert "mtp_logits" in out
    assert out["mtp_logits"].shape == (B, S - 1, cfg.vocab_size)


def test_head_padding_exact_and_grad_clean():
    """§Perf: pad_heads_to must be mathematically exact (padded heads are
    structural zeros) and padded slots must receive zero gradients."""
    cfg = smoke_config("deepseek-coder-33b").with_overrides(
        dtype="float32", num_heads=6, num_kv_heads=2)
    cfg_pad = cfg.with_overrides(pad_heads_to=8)
    params_pad = init_model(cfg_pad, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    out_pad = apply_model(cfg_pad, params_pad, {"tokens": toks},
                          mode="train")["logits"]

    # slice padded params (g 3->4, pad slot last in each kv group) back
    # to the unpadded layout; outputs must match exactly
    idx = np.concatenate([np.arange(i * 4, i * 4 + 3) for i in range(2)])

    def walk(t):
        if isinstance(t, dict):
            return {k: (walk(v) if isinstance(v, dict) else fix(k, v))
                    for k, v in t.items()}
        return t

    def fix(k, v):
        if k == "wq" and v.ndim >= 3 and v.shape[-2] == 8:
            return v[..., idx, :]
        if k == "wo" and v.ndim >= 3 and v.shape[-3] == 8:
            return v[..., idx, :, :]
        return v

    out_ref = apply_model(cfg, walk(params_pad), {"tokens": toks},
                          mode="train")["logits"]
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_ref),
                               atol=5e-5)

    def loss(p):
        o = apply_model(cfg_pad, p, {"tokens": toks}, mode="train")
        return lm_loss(cfg_pad, o, {"tokens": toks})[0]

    g = jax.grad(loss)(params_pad)
    wq_g = np.asarray(g["decoder"]["blocks"]["layer0"]["mixer"]["wq"])
    assert np.abs(wq_g[..., [3, 7], :]).max() == 0.0
