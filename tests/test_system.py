"""End-to-end behaviour: the paper's training pipeline on its own
networks (synthetic stand-in datasets), single-device mesh; plus an
LM end-to-end train-improves-loss check on a reduced architecture."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import smoke_config
from repro.configs.paper_nets import MNIST_DNN, HIGGS_DNN, MNIST_CNN
from repro.core import DPConfig, init_train_state, make_dp_train_step
from repro.data import make_dataset
from repro.data.pipeline import ShardedLoader
from repro.launch.mesh import make_host_mesh
from repro.models import (init_paper_net, apply_paper_net, init_model,
                          apply_model)
from repro.train.loss import lm_loss

KEY = jax.random.PRNGKey(0)


def _ce(net, p, batch):
    lg = apply_paper_net(net, p, batch["x"])
    n = lg.shape[0]
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(n), batch["y"]])


def test_mnist_dnn_end_to_end_training_learns():
    """Full pipeline: synthetic MNIST-shaped data -> rank0 scatter ->
    sync-DP step -> loss decreases and accuracy beats chance."""
    ds = make_dataset("mnist", n=2048)
    mesh = make_host_mesh()
    net = MNIST_DNN
    params = init_paper_net(net, KEY)
    opt = optim.momentum(0.2, 0.9)
    dp = DPConfig(sync="grads")
    step = make_dp_train_step(lambda p, b: _ce(net, p, b), opt, mesh,
                              dp, donate=False)
    loader = ShardedLoader({"x": ds.x, "y": ds.y}, batch_size=256,
                           mesh=mesh)
    state = init_train_state(opt, params, mesh, dp)
    losses = []
    for epoch in range(6):
        for batch in loader.epoch(epoch):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
    logits = apply_paper_net(net, state.params, jnp.asarray(ds.x[:512]))
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(ds.y[:512])))
    assert acc > 0.2, acc  # 10 classes -> chance is 0.1


def test_higgs_dnn_trains():
    ds = make_dataset("higgs", n=1024)
    net = HIGGS_DNN
    params = init_paper_net(net, KEY)
    opt = optim.adagrad(0.05)   # paper cites TensorFlow's AdaGrad
    state = opt.init(params)
    batch = {"x": jnp.asarray(ds.x[:256]), "y": jnp.asarray(ds.y[:256])}
    l0 = float(_ce(net, params, batch))
    for _ in range(30):
        g = jax.grad(lambda p: _ce(net, p, batch))(params)
        params, state = opt.update(g, state, params)
    l1 = float(_ce(net, params, batch))
    assert l1 < l0


def test_mnist_cnn_forward_shape():
    net = MNIST_CNN
    params = init_paper_net(net, KEY)
    x = jax.random.normal(KEY, (4, 28, 28, 1))
    logits = apply_paper_net(net, params, x)
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_lm_end_to_end_loss_decreases():
    """Reduced qwen3: 30 steps of Adam on a repeated batch must overfit."""
    cfg = smoke_config("qwen3-1.7b").with_overrides(
        dtype="float32", vocab_size=128)
    params = init_model(cfg, KEY)
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    opt = optim.adam(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            out = apply_model(cfg, p, batch, mode="train")
            total, _ = lm_loss(cfg, out, batch)
            return total
        l, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        return params, state, l

    first = None
    for i in range(30):
        params, state, l = step(params, state)
        if first is None:
            first = float(l)
    assert float(l) < first * 0.7, (first, float(l))
