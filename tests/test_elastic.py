"""Elastic fault-tolerance tier (ISSUE 9 tentpole).

* async checkpointer — the save blocks only for the device→host copy
  (``snapshot_train_state``); publish happens on a daemon thread with a
  bounded last-publish-wins queue, and the published bytes are
  bitwise-identical to a synchronous save;
* fault injection — a planned ``os._exit`` at a step boundary leaves
  exactly the torn ``tmp-`` state a preemption would, with a
  recognisable exit code;
* elastic resize — THE acceptance: a 2×16 ``zero1_hier`` run killed
  mid-flight (with the async writer lagging, so the last *published*
  step trails the kill step) resumes as 1×8 ``zero3`` from the last
  published step and its losses match an unkilled same-stream reference
  ≤ 1e-5;
* store hygiene — stale ``tmp-`` sweep on publish, ``keep_last``
  retention, corrupt-shard restores failing loudly (naming the bad
  file) and ``resume_elastic`` falling back to the previous published
  step;
* perf model — ``ckpt_overhead`` (sync vs async) and the zero3_hier
  DCN saving.
"""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from conftest import run_with_devices


def run_expect_exit(code: str, n_devices: int, expect: int) -> str:
    """Like ``run_with_devices`` but asserting a SPECIFIC exit code —
    the fault-injection runs are supposed to die."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != expect:
        raise AssertionError(
            f"expected exit {expect}, got {proc.returncode}:\n"
            f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}")
    return proc.stdout


def _tiny_state():
    """A 1-device replicated TrainState — enough for the host-side
    store/daemon semantics (no emulated mesh needed)."""
    import jax
    from repro.core import init_train_state
    from repro import optim
    params = {"w": jax.numpy.arange(12, dtype=jax.numpy.float32),
              "b": jax.numpy.ones((3,), jax.numpy.float32)}
    return init_train_state(optim.adam(1e-3), params)


# --------------------------------------------------------------------------
# async checkpointer daemon
# --------------------------------------------------------------------------

def test_async_save_matches_sync_bitwise(tmp_path):
    from repro.checkpoint import save_sharded_checkpoint
    from repro.elastic import AsyncCheckpointer
    st = _tiny_state()
    sync_dir, async_dir = tmp_path / "sync", tmp_path / "async"
    save_sharded_checkpoint(sync_dir, 5, st, extra={"k": 1})
    with AsyncCheckpointer(async_dir) as ck:
        rec = ck.save(st, 5, extra={"k": 1})
        assert rec["step"] == 5 and rec["bytes"] > 0
        ck.wait()
        stats = ck.stats()
    assert stats["published"] == 1 and stats["steps_behind"] == 0
    a = sync_dir / "step_0000000005.shards"
    b = async_dir / "step_0000000005.shards"
    assert sorted(p.name for p in a.iterdir()) == \
        sorted(p.name for p in b.iterdir())
    for p in a.iterdir():
        if p.suffix == ".npz":
            za = np.load(p); zb = np.load(b / p.name)
            assert sorted(za.files) == sorted(zb.files)
            for k in za.files:
                np.testing.assert_array_equal(za[k], zb[k])
        else:
            assert p.read_bytes() == (b / p.name).read_bytes()


def test_async_queue_last_publish_wins(tmp_path):
    """With a slow writer and max_in_flight=1, intermediate snapshots
    are dropped, the newest always publishes, and save() returns long
    before the write completes (the blocking half is the snapshot)."""
    from repro.checkpoint.store import write_state_snapshot
    from repro.elastic import AsyncCheckpointer

    def slow_writer(ckpt_dir, snap, *, keep_last=None):
        time.sleep(0.25)
        return write_state_snapshot(ckpt_dir, snap, keep_last=keep_last)

    st = _tiny_state()
    with AsyncCheckpointer(tmp_path, writer=slow_writer) as ck:
        t0 = time.monotonic()
        for s in (1, 2, 3, 4):
            rec = ck.save(st, s)
            assert rec["blocking_s"] < 0.2      # never waits on the writer
        assert time.monotonic() - t0 < 0.5      # 4 saves, no 4x0.25s
        mid = ck.stats()
        assert mid["last_requested_step"] == 4
        assert (mid["steps_behind"] or 0) > 0   # publish genuinely lags
        ck.wait()
        stats = ck.stats()
    assert stats["last_published_step"] == 4    # newest always wins
    assert stats["steps_behind"] == 0
    assert stats["dropped"] >= 1                # some middle step dropped
    assert stats["saves"] == 4
    assert stats["published"] + stats["dropped"] == 4
    from repro.checkpoint import published_steps
    steps = published_steps(tmp_path)
    assert steps[-1] == 4 and len(steps) == stats["published"]


def test_async_snapshot_is_consistent_not_live(tmp_path):
    """The snapshot is frozen at save() time: mutating the state before
    the (delayed) write publishes must not leak into the checkpoint."""
    import dataclasses
    import jax.numpy as jnp
    from repro.checkpoint import restore_train_state
    from repro.checkpoint.store import write_state_snapshot
    from repro.elastic import AsyncCheckpointer

    def slow_writer(ckpt_dir, snap, *, keep_last=None):
        time.sleep(0.2)
        return write_state_snapshot(ckpt_dir, snap, keep_last=keep_last)

    st = _tiny_state()
    with AsyncCheckpointer(tmp_path, writer=slow_writer) as ck:
        ck.save(st, 1)
        st = dataclasses.replace(
            st, params={k: v + 100.0 for k, v in st.params.items()})
        ck.wait()
    tpl = _tiny_state()
    got, at = restore_train_state(tmp_path, tpl, 1)
    np.testing.assert_array_equal(
        np.asarray(got.params["w"]),
        np.arange(12, dtype=np.float32))        # pre-mutation values


def test_async_writer_error_surfaces_on_step_path(tmp_path):
    from repro.elastic import AsyncCheckpointer

    def bad_writer(ckpt_dir, snap, *, keep_last=None):
        raise OSError("disk full")

    st = _tiny_state()
    ck = AsyncCheckpointer(tmp_path, writer=bad_writer)
    ck.save(st, 1)
    with pytest.raises(RuntimeError, match="LAST PUBLISHED"):
        ck.wait()
    with pytest.raises(RuntimeError, match="LAST PUBLISHED"):
        ck.save(st, 2)


# --------------------------------------------------------------------------
# store hygiene: stale-tmp sweep, retention, corruption
# --------------------------------------------------------------------------

def test_publish_sweeps_stale_tmp_and_keeps_last(tmp_path):
    from repro.checkpoint import published_steps, save_sharded_checkpoint
    st = _tiny_state()
    # torn residue of a killed writer
    (tmp_path / "tmp-step_0000000009.shards").mkdir(parents=True)
    (tmp_path / "tmp-step_0000000009.shards" / "worker_00000.npz"
     ).write_bytes(b"trunc")
    (tmp_path / "tmp-step_0000000010.npz").write_bytes(b"PK\x03junk")
    for s in (1, 2, 3, 4):
        save_sharded_checkpoint(tmp_path, s, st, keep_last=2)
    names = {p.name for p in tmp_path.iterdir()}
    assert not any(n.startswith("tmp-") for n in names), names
    assert published_steps(tmp_path) == [3, 4]
    from repro.checkpoint import latest_step
    assert latest_step(tmp_path) == 4


def test_keep_last_validation(tmp_path):
    from repro.checkpoint import save_sharded_checkpoint
    with pytest.raises(ValueError):
        save_sharded_checkpoint(tmp_path, 1, _tiny_state(), keep_last=0)


def test_corrupt_shard_fails_loudly_and_elastic_falls_back(tmp_path):
    from repro.checkpoint import (CorruptCheckpointError,
                                  restore_train_state,
                                  save_sharded_checkpoint)
    from repro.elastic import resume_elastic
    st = _tiny_state()
    save_sharded_checkpoint(tmp_path, 1, st)
    save_sharded_checkpoint(tmp_path, 2, st)
    for bad in (tmp_path / "step_0000000002.shards").glob("*.npz"):
        bad.write_bytes(b"PK\x03\x04 truncated mid write")
    tpl = _tiny_state()
    # direct restore of the bad step names the unreadable member
    with pytest.raises(CorruptCheckpointError, match=r"\.npz"):
        restore_train_state(tmp_path, tpl, 2)
    # the resize driver falls back to the previous published step
    got, at, skipped = resume_elastic(tmp_path, tpl)
    assert at == 1
    assert [s for s, _ in skipped] == [2]
    assert ".npz" in skipped[0][1]
    np.testing.assert_array_equal(np.asarray(got.params["w"]),
                                  np.asarray(st.params["w"]))
    # every step corrupt -> loud aggregate error, not a silent zero state
    for bad in (tmp_path / "step_0000000001.shards").glob("*.npz"):
        bad.write_bytes(b"also dead")
    with pytest.raises(CorruptCheckpointError, match="every candidate"):
        resume_elastic(tmp_path, tpl)


def test_data_cursor_rides_checkpoint_meta(tmp_path):
    from repro.checkpoint import checkpoint_meta, save_sharded_checkpoint
    st = _tiny_state()
    cur = {"data_cursor": {"data_seed": 7, "next_step": 42}}
    save_sharded_checkpoint(tmp_path, 42, st, extra=cur)
    meta = checkpoint_meta(tmp_path, 42)
    assert meta["extra"]["data_cursor"] == cur["data_cursor"]


# --------------------------------------------------------------------------
# fault injection
# --------------------------------------------------------------------------

def test_fault_injector_raise_mode_fires_once():
    from repro.elastic import FaultInjector, FaultPlan, SimulatedFault
    inj = FaultInjector(FaultPlan(3, mode="raise"))
    inj.after_step(1)
    inj.after_step(2)
    with pytest.raises(SimulatedFault):
        inj.after_step(3)
    inj.after_step(4)                       # fires at most once
    assert inj.fired


def test_fault_injector_env_and_validation():
    from repro.elastic import FaultInjector, FaultPlan
    assert FaultInjector.from_env(env={}) is None
    assert FaultInjector.from_env(env={"REPRO_FAULT_STEP": "-1"}) is None
    inj = FaultInjector.from_env(env={"REPRO_FAULT_STEP": "5",
                                      "REPRO_FAULT_MODE": "raise"})
    assert inj.plan.kill_at_step == 5 and inj.plan.mode == "raise"
    with pytest.raises(ValueError):
        FaultPlan(1, mode="segfault")


def test_fault_injector_hard_kill_exit_code():
    out = run_expect_exit("""
    from repro.elastic import FAULT_EXIT_CODE, FaultInjector, FaultPlan
    inj = FaultInjector(FaultPlan(2))
    for i in range(10):
        print('step', i, flush=True)
        inj.after_step(i)
    print('UNREACHABLE')
    """, n_devices=1, expect=113)
    assert "FAULT: killing at step 2" in out
    assert "UNREACHABLE" not in out


# --------------------------------------------------------------------------
# THE acceptance: kill a 2x16 zero1_hier run, resume as 1x8 zero3
# --------------------------------------------------------------------------

_ELASTIC_COMMON = """
import json, os, time
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import MNIST_DNN
from repro.models import init_paper_net, apply_paper_net
from repro.core import DPConfig, make_dp_train_step, init_train_state
from repro import optim

net = MNIST_DNN
key = jax.random.PRNGKey(0)
params = init_paper_net(net, key)
opt = optim.adam(1e-3)

def batch_of(i):
    k = jax.random.fold_in(jax.random.PRNGKey(7), i)
    x = jax.random.normal(k, (64, 784))
    y = jax.random.randint(k, (64,), 0, 10)
    return {'x': x, 'y': y}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])
"""

_KILL_RUN = _ELASTIC_COMMON + """
# -- phase 1: 2x16 zero1_hier with a LAGGING async writer, killed at 6
from repro.checkpoint.store import write_state_snapshot
from repro.elastic import AsyncCheckpointer, FaultInjector, FaultPlan

ckpt = os.environ['ELASTIC_CKPT']
mesh = make_mesh((2, 16), ('pod', 'data'), axis_types=auto_axis_types(2))
dp = DPConfig(sync='grads', strategy='zero1_hier', overlap=True,
              bucket_bytes=1 << 16)
state = init_train_state(opt, params, mesh, dp)
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)

def lagging_writer(d, snap, *, keep_last=None):
    time.sleep(0.3)                   # writer lags ~2 steps behind
    return write_state_snapshot(d, snap, keep_last=keep_last)

inj = FaultInjector(FaultPlan(6))
ck = AsyncCheckpointer(ckpt, writer=lagging_writer)
for i in range(10):
    state, m = step(state, batch_of(i))
    time.sleep(0.15)                  # emulated per-step compute: the
                                      # smoke net steps in microseconds,
                                      # which would let no write finish
    print('KLOSS', i, repr(float(m['loss'])), flush=True)
    ck.save(state, i + 1)
    inj.after_step(i + 1)             # hard-kills at step 6
print('UNREACHABLE')
"""

_RESUME_RUN = _ELASTIC_COMMON + """
# -- phase 2 (different world size): resume as 1x8 zero3 from whatever
# was PUBLISHED, train to step 10; reference = unkilled zero3 run over
# the same stream from scratch.  Sequential equivalence makes the two
# prefixes interchangeable, so losses after the resume point must match.
from repro.checkpoint import published_steps
from repro.elastic import resume_elastic

ckpt = os.environ['ELASTIC_CKPT']
mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
dp = DPConfig(sync='grads', strategy='zero3')
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)

pub = published_steps(ckpt)
print('PUBLISHED', pub, flush=True)
assert pub and pub[-1] < 6, pub       # the writer genuinely lagged the kill

tpl = init_train_state(opt, params, mesh, dp)
state, at, skipped = resume_elastic(ckpt, tpl)
assert not skipped, skipped
assert at == pub[-1]
print('RESUMED', at, flush=True)

resumed = {}
for i in range(at, 10):
    state, m = step(state, batch_of(i))
    resumed[i] = float(m['loss'])

ref = init_train_state(opt, params, mesh, dp)
reference = {}
for i in range(10):
    ref, m = step(ref, batch_of(i))
    reference[i] = float(m['loss'])

for i in sorted(resumed):
    err = abs(resumed[i] - reference[i])
    print('CMP', i, repr(resumed[i]), repr(reference[i]), err, flush=True)
    assert err < 1e-5, (i, resumed[i], reference[i])
print('MATCH OK')
"""


def test_kill_and_elastic_resize_acceptance(tmp_path, monkeypatch):
    """2×16 zero1_hier killed at step 6 with the async publish lagging;
    resumed as 1×8 zero3 from the last published step; losses match the
    unkilled reference ≤ 1e-5."""
    ckpt = str(tmp_path / "elastic")
    monkeypatch.setenv("ELASTIC_CKPT", ckpt)
    out = run_expect_exit(_KILL_RUN, n_devices=32, expect=113)
    assert "FAULT: killing at step 6" in out
    assert "UNREACHABLE" not in out
    # the kill abandoned the daemon mid-write: torn tmp- residue is
    # expected on disk, published steps must all be older than the kill
    from repro.checkpoint import published_steps
    pub = published_steps(ckpt)
    assert pub and pub[-1] < 6, pub
    out2 = run_with_devices(_RESUME_RUN, n_devices=8)
    assert "MATCH OK" in out2


def test_zero3_hier_checkpoint_cross_layout():
    """zero3_hier trains on a pod×data mesh, checkpoints gather-free,
    and its shards reshard into plain zero1 on a flat mesh (and back)."""
    run_with_devices(_ELASTIC_COMMON + """
import tempfile
from repro.checkpoint import restore_sharded_checkpoint, \
    save_sharded_checkpoint
mesh = make_mesh((2, 4), ('pod', 'data'), axis_types=auto_axis_types(2))
dph = DPConfig(sync='grads', strategy='zero3_hier', overlap=True,
               bucket_bytes=1 << 16)
st = init_train_state(opt, params, mesh, dph)
step = make_dp_train_step(loss_fn, opt, mesh, dph, donate=False)
for i in range(3):
    st, m = step(st, batch_of(i))
d = tempfile.mkdtemp()
save_sharded_checkpoint(d, 3, st)

dp1 = DPConfig(sync='grads', strategy='zero1')
ref = init_train_state(opt, params, mesh, dp1)
step1 = make_dp_train_step(loss_fn, opt, mesh, dp1, donate=False)
for i in range(3):
    ref, m = step1(ref, batch_of(i))
got, at = restore_sharded_checkpoint(d, init_train_state(opt, params,
                                                         mesh, dp1))
assert at == 3
from repro.core import host_params
err = max(np.abs(np.asarray(a) - np.asarray(b)).max()
          for a, b in zip(jax.tree_util.tree_leaves(host_params(got)),
                          jax.tree_util.tree_leaves(host_params(ref))))
print('ERR', err)
assert err < 1e-5, err
print('OK')
""")


# --------------------------------------------------------------------------
# perf model
# --------------------------------------------------------------------------

def test_ckpt_overhead_model():
    from repro.core.perf_model import ckpt_overhead
    r = ckpt_overhead(1e9, step_time_s=1.0, every=10)
    # async blocks only for the device->host copy; sync adds the write
    assert r["async_s"] < r["sync_s"]
    assert abs(r["sync_s"] - (r["async_s"] + r["publish_lag_s"])) < 1e-12
    assert r["async_overhead"] < r["sync_overhead"]
    assert r["publish_lag_steps"] == pytest.approx(r["publish_lag_s"])
    # amortisation: checkpointing every step costs 10x more
    r1 = ckpt_overhead(1e9, step_time_s=1.0, every=1)
    assert r1["sync_overhead"] == pytest.approx(10 * r["sync_overhead"])


def test_zero3_hier_comm_model_dcn_saving():
    from repro.core.perf_model import (TPU_DCN, TPU_V5E_ICI,
                                       zero3_comm_time,
                                       zero3_hier_comm_time)
    v = 4 * 33_000_000_000
    flat = zero3_comm_time(v, p=64, fabric=TPU_DCN)
    hier = zero3_hier_comm_time(v, n_intra=16, n_pods=4)
    # staging keeps the bulk on ICI; DCN only ever moves 1/n_intra
    assert hier < flat
    assert zero3_hier_comm_time(v, n_intra=1, n_pods=1) == 0.0
    # the DCN term alone is ~1/n_intra of the flat DCN volume cost
    dcn_only = zero3_hier_comm_time(v, n_intra=16, n_pods=4,
                                    intra=TPU_DCN)
    assert dcn_only > hier


def test_strategy_registry_has_hier_pair():
    from repro.core.strategy import available_strategies, get_strategy
    names = available_strategies()
    assert "zero1_hier" in names and "zero3_hier" in names
    z3h = get_strategy("zero3_hier")
    assert z3h.sharded and z3h.kind == "zero3_hier"
    # overlap=True is a first-class configuration for both hier kinds
    from repro.core import DPConfig
    from repro.compat import make_mesh, auto_axis_types  # noqa: F401
    dp = DPConfig(sync="grads", strategy="zero1_hier", overlap=True,
                  bucket_bytes=1 << 16)
    # validate() must not raise on a host mesh (1 device, single axis)
    import jax
    mesh = make_mesh((1,), ("data",), axis_types=auto_axis_types(1))
    get_strategy("zero1_hier").validate(dp, mesh)
