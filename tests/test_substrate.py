"""Substrate: optimizers, checkpointing, data, serving, sharding rules."""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.configs import (ARCHITECTURES, INPUT_SHAPES, smoke_config,
                           config_for_shape, LONG_500K_SKIPS)
from repro.data import make_dataset, synthetic_tokens, PAPER_DATASET_SHAPES
from repro.data.specs import input_specs
from repro.models import init_model, init_cache
from repro.serve.engine import ServeEngine
from repro.sharding.rules import param_specs, cache_spec, ShardingConfig, _path_str

KEY = jax.random.PRNGKey(11)


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------

def _quad_step(opt, steps=60):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"w": 2.0 * params["w"]}        # d/dw of |w|^2
        params, state = opt.update(grads, state, params)
    return float(jnp.abs(params["w"]).max())


@pytest.mark.parametrize("name", ["sgd", "momentum", "adagrad", "adam"])
def test_optimizers_minimise_quadratic(name):
    lr = {"adagrad": 1.0}.get(name, 0.1)   # adagrad's step decays as 1/√Σg²
    opt = optim.get_optimizer(name, lr)
    assert _quad_step(opt) < 0.5


def test_adam_bias_correction_first_step():
    """First Adam step must be ~lr * sign(grad) (bias-corrected)."""
    opt = optim.adam(1e-3)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    grads = {"w": jnp.array([1.0, -2.0, 0.5])}
    new, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(
        np.asarray(new["w"]), -1e-3 * np.sign(grads["w"]), rtol=1e-3)


def test_cosine_schedule_shape():
    lr = optim.cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(jnp.array(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.array(10))), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(lr(jnp.array(100))), 0.1, atol=1e-5)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.array(7)}}
    save_checkpoint(tmp_path, 7, state)
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), state)
    restored, step = restore_checkpoint(tmp_path, like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_latest_and_shape_validation(tmp_path):
    state = {"w": jnp.ones((2,))}
    save_checkpoint(tmp_path, 1, state)
    save_checkpoint(tmp_path, 5, state)
    assert latest_step(tmp_path) == 5
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, {"w": jnp.ones((3,))})


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PAPER_DATASET_SHAPES))
def test_paper_dataset_shapes(name):
    ds = make_dataset(name, n=256)
    spec = PAPER_DATASET_SHAPES[name]
    assert ds.x.shape == (256, spec["features"])
    assert ds.y.shape == (256,)
    assert ds.num_classes == spec["classes"]
    assert set(np.unique(ds.y)) <= set(range(spec["classes"]))
    # deterministic
    ds2 = make_dataset(name, n=256)
    np.testing.assert_array_equal(ds.x, ds2.x)


def test_synthetic_tokens_in_range():
    t = synthetic_tokens(KEY, 4, 64, 1000)
    assert t.shape == (4, 64)
    assert int(t.min()) >= 0 and int(t.max()) < 1000


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------

def test_serve_engine_greedy_matches_full_forward():
    from repro.models import apply_model
    cfg = smoke_config("qwen3-1.7b").with_overrides(dtype="float32")
    params = init_model(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32,
                      dtype=jnp.float32)
    gen = eng.generate(prompts, max_new_tokens=4)
    assert gen.shape == (2, 4)
    # check first generated token against a plain forward pass
    out = apply_model(cfg, params, {"tokens": prompts}, mode="train")
    want0 = jnp.argmax(out["logits"][:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(gen[:, 0]), np.asarray(want0))
    # and the second token: append and re-run full forward
    ext = jnp.concatenate([prompts, gen[:, :1]], axis=1)
    out2 = apply_model(cfg, params, {"tokens": ext}, mode="train")
    want1 = jnp.argmax(out2["logits"][:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(gen[:, 1]), np.asarray(want1))


# --------------------------------------------------------------------------
# sharding rules: rank agreement for every arch, both modes
# --------------------------------------------------------------------------

class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_rank_match(arch, mode):
    cfg = ARCHITECTURES[arch]
    pshape = jax.eval_shape(functools.partial(init_model, cfg), KEY)
    specs = param_specs(cfg, _FakeMesh(), pshape,
                        ShardingConfig.for_mode(mode))
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_leaves_with_path(pshape),
            jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))):
        assert len(spec) <= len(leaf.shape), (_path_str(path), spec,
                                              leaf.shape)
        # sharded dims must divide evenly (jit input requirement)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            n = np.prod([_FakeMesh.shape[a] for a in
                         (ax if isinstance(ax, tuple) else (ax,))])
            assert dim % n == 0, (_path_str(path), spec, leaf.shape)


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_cache_specs_rank_match(arch):
    for shape_name in INPUT_SHAPES:
        if INPUT_SHAPES[shape_name].mode != "decode":
            continue
        if shape_name == "long_500k" and arch in LONG_500K_SKIPS:
            continue
        cfg = config_for_shape(arch, shape_name)
        shp = INPUT_SHAPES[shape_name]
        cache = jax.eval_shape(lambda: init_cache(
            cfg, shp.global_batch, min(shp.seq_len, 4096), jnp.bfloat16,
            cross_len=min(shp.seq_len, 4096)))
        sh = ShardingConfig.for_mode("serve")
        for path, leaf in jax.tree_util.tree_leaves_with_path(cache):
            spec = cache_spec(cfg, _FakeMesh(), _path_str(path), leaf,
                              shp.global_batch, sh)
            assert len(spec) == len(leaf.shape), (_path_str(path), spec,
                                                  leaf.shape)


# --------------------------------------------------------------------------
# input specs cover every (arch, shape)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_input_specs_complete(arch):
    for name, shp in INPUT_SHAPES.items():
        if name == "long_500k" and arch in LONG_500K_SKIPS:
            continue
        cfg = config_for_shape(arch, name)
        specs = input_specs(cfg, shp)
        assert isinstance(specs, dict) and specs
        for leaf in jax.tree_util.tree_leaves(specs):
            assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
