"""Per-kernel validation: Pallas (interpret=True) and chunked-jnp fast
paths against the pure-jnp oracles in kernels/ref.py, swept over
shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas
from repro.kernels.mamba_scan import mamba_pallas

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

FLASH_CASES = [
    # B, S, T, h, hk, hd, causal, window
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 96, 160, 4, 4, 64, True, 0),       # right-aligned decode-style
    (2, 128, 128, 8, 2, 128, True, 48),    # sliding window
    (1, 64, 64, 2, 1, 64, False, 0),       # bidirectional, MQA
    (1, 33, 70, 2, 2, 64, True, 0),        # ragged (padding paths)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas(case, dtype):
    B, S, T, h, hk, hd, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, h, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, hk, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, hk, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=32, block_kv=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("impl", ["chunked", "pallas"])
def test_flash_attention_ops_dispatch(impl):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 64))
    k = jax.random.normal(ks[1], (2, 64, 4, 64))
    v = jax.random.normal(ks[2], (2, 64, 4, 64))
    out = ops.flash_attention(q, k, v, impl=impl, block_q=32, block_kv=32)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


# --------------------------------------------------------------------------
# WKV6
# --------------------------------------------------------------------------

WKV_CASES = [
    # B, T, H, K, chunk
    (2, 64, 2, 64, 16),
    (1, 80, 3, 32, 32),   # T not a multiple of chunk
    (2, 37, 1, 64, 8),
]


@pytest.mark.parametrize("case", WKV_CASES)
@pytest.mark.parametrize("impl", ["chunked", "pallas"])
def test_wkv6(case, impl):
    B, T, H, K, chunk = case
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, K)) for i in range(3))
    wl = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)))
    u = jax.random.normal(ks[4], (H, K))
    s0 = jax.random.normal(ks[5], (B, H, K, K))
    y_ref, s_ref = ref.wkv6_ref(r, k, v, wl, u, s0)
    if impl == "pallas":
        y, s = wkv6_pallas(r, k, v, wl, u, s0, chunk=chunk)
    else:
        y, s = ops.wkv6_chunked(r, k, v, wl, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=5e-4)


def test_wkv6_step_matches_scan():
    ks = jax.random.split(KEY, 6)
    B, H, K = 2, 2, 32
    r, k, v = (jax.random.normal(ks[i], (B, 1, H, K)) for i in range(3))
    wl = -jnp.exp(jax.random.normal(ks[3], (B, 1, H, K)))
    u = jax.random.normal(ks[4], (H, K))
    s0 = jax.random.normal(ks[5], (B, H, K, K))
    y_ref, s_ref = ref.wkv6_ref(r, k, v, wl, u, s0)
    y, s = ops.wkv6_step(r[:, 0], k[:, 0], v[:, 0], wl[:, 0], u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref[:, 0]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5)


# --------------------------------------------------------------------------
# Mamba selective scan
# --------------------------------------------------------------------------

MAMBA_CASES = [
    # Bb, T, dI, dS, chunk, block_di
    (2, 64, 256, 8, 16, 128),
    (1, 72, 128, 16, 32, 128),   # ragged T
    (2, 40, 512, 4, 8, 256),
]


@pytest.mark.parametrize("case", MAMBA_CASES)
@pytest.mark.parametrize("impl", ["chunked", "pallas"])
def test_mamba_scan(case, impl):
    Bb, T, dI, dS, chunk, bdi = case
    ks = jax.random.split(KEY, 7)
    x = jax.random.normal(ks[0], (Bb, T, dI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, T, dI)))
    A = -jnp.exp(jax.random.normal(ks[2], (dI, dS)))
    B = jax.random.normal(ks[3], (Bb, T, dS))
    C = jax.random.normal(ks[4], (Bb, T, dS))
    D = jax.random.normal(ks[5], (dI,))
    h0 = jax.random.normal(ks[6], (Bb, dI, dS))
    y_ref, h_ref = ref.mamba_ref(x, dt, A, B, C, D, h0)
    if impl == "pallas":
        y, h = mamba_pallas(x, dt, A, B, C, D, h0, chunk=chunk, block_di=bdi)
    else:
        y, h = ops.mamba_chunked(x, dt, A, B, C, D, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=5e-4)


def test_mamba_step_matches_scan():
    ks = jax.random.split(KEY, 7)
    Bb, dI, dS = 2, 64, 8
    x = jax.random.normal(ks[0], (Bb, 1, dI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, 1, dI)))
    A = -jnp.exp(jax.random.normal(ks[2], (dI, dS)))
    B = jax.random.normal(ks[3], (Bb, 1, dS))
    C = jax.random.normal(ks[4], (Bb, 1, dS))
    D = jax.random.normal(ks[5], (dI,))
    h0 = jax.random.normal(ks[6], (Bb, dI, dS))
    y_ref, h_ref = ref.mamba_ref(x, dt, A, B, C, D, h0)
    y, h = ops.mamba_step(x[:, 0], dt[:, 0], A, B[:, 0], C[:, 0], D, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref[:, 0]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-5)


def test_wkv6_grad_flows():
    """Chunked path is differentiable (per-chunk checkpointing intact)."""
    ks = jax.random.split(KEY, 6)
    B, T, H, K = 1, 32, 1, 16
    r, k, v = (jax.random.normal(ks[i], (B, T, H, K)) for i in range(3))
    wl = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)))
    u = jax.random.normal(ks[4], (H, K))
    s0 = jnp.zeros((B, H, K, K))

    def loss(r):
        y, _ = ops.wkv6_chunked(r, k, v, wl, u, s0, chunk=8)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(r)
    assert np.isfinite(np.asarray(g)).all()


# --------------------------------------------------------------------------
# flash block-size tuning surface
# --------------------------------------------------------------------------

def test_set_flash_blocks_roundtrip_and_restore():
    """The shared tuning surface the decode microbenchmark sweeps:
    set returns the previous pair (so sweeps can restore), partial
    updates leave the other knob untouched."""
    orig = ops.get_flash_blocks()
    try:
        prev = ops.set_flash_blocks(128, 256)
        assert prev == orig
        assert ops.get_flash_blocks() == (128, 256)
        assert ops.set_flash_blocks(block_kv=64) == (128, 256)
        assert ops.get_flash_blocks() == (128, 64)     # block_q untouched
        with pytest.raises(AssertionError):
            ops.set_flash_blocks(0)
    finally:
        ops.set_flash_blocks(*orig)
    assert ops.get_flash_blocks() == orig


@pytest.mark.parametrize("impl", ["chunked", "pallas"])
def test_flash_attention_uses_block_surface(impl):
    """flash_attention with no explicit blocks resolves them from the
    surface — numerics identical across block choices."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    want = ref.attention_ref(q, k, v)
    orig = ops.get_flash_blocks()
    try:
        for bq, bkv in ((16, 32), (32, 16)):
            ops.set_flash_blocks(bq, bkv)
            out = ops.flash_attention(q, k, v, impl=impl)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       atol=2e-4)
    finally:
        ops.set_flash_blocks(*orig)
