"""Mesh-sharded serving: the continuous engine on the production
topology (data x model serve mesh) must be a pure placement change —
greedy outputs bitwise-equal to the host-mesh engine, with the paged
pool genuinely distributed (no device holds the full pool).

Every mesh test runs under ``run_with_devices`` (a subprocess with
``--xla_force_host_platform_device_count=8``): a (2, 4) serve mesh —
2 DP replica groups, 4-way model-sharded decode — the host-scale
instance of the production 16x16 layout.
"""
import numpy as np
import pytest

from conftest import run_with_devices

# the two acceptance archs: GQA (qwen3: kv_heads=2 < model=4 exercises
# the head_dim-sharding fallback) and MoE (deepseek: expert-parallel
# decode dispatch + head-sharded pool)
MESH_BITWISE_SNIPPET = """
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import init_model
from repro.launch.mesh import make_serve_mesh
from repro.serve import make_engine

cfg = smoke_config({arch!r}).with_overrides(dtype="float32")
params = init_model(cfg, jax.random.PRNGKey(3))
prompts = [np.asarray(jax.random.randint(
    jax.random.PRNGKey(10 + i), (L,), 0, cfg.vocab_size))
    for i, L in enumerate((7, 12, 5, 9))]

solo = make_engine(cfg, params, engine="continuous", batch_size=2,
                   max_len=64)
ref = solo.generate(prompts, 8)

mesh = make_serve_mesh(2, 4)
eng = make_engine(cfg, params, engine="continuous", batch_size=2,
                  max_len=64, mesh=mesh)
got = eng.generate(prompts, 8)
for i, (r, g) in enumerate(zip(ref, got)):
    assert np.array_equal(r, g), (i, r, g)

# ---- live-buffer sweep: the pool is genuinely distributed ----
per = eng.kv.pool_bytes_by_device()
tot = eng.kv.pool_bytes()
assert len(per) == 8, per                       # every device holds a shard
assert all(b < tot for b in per.values()), \\
    "a device holds the full pool"
# feature axes shard 4-way over "model": per-device == pool/model_size
assert max(per.values()) == tot // 4, (per, tot)
assert sum(per.values()) == 2 * tot             # 2 data-replicas of the pool
assert eng.kv.pool_bytes_per_device() == tot // 4
print("OK", {arch!r})
"""


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-moe-16b"])
def test_mesh_continuous_bitwise_and_pool_distributed(arch):
    out = run_with_devices(MESH_BITWISE_SNIPPET.format(arch=arch))
    assert "OK" in out


PREFIX_MESH_SNIPPET = """
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import init_model
from repro.launch.mesh import make_serve_mesh
from repro.serve import make_engine

cfg = smoke_config({arch!r}).with_overrides(dtype="float32")
params = init_model(cfg, jax.random.PRNGKey(3))
shared = np.asarray(jax.random.randint(
    jax.random.PRNGKey(9), (16,), 0, cfg.vocab_size))
rng = np.random.default_rng(3)
prompts = [np.concatenate([shared,
                           rng.integers(0, cfg.vocab_size, 3 + i)
                           .astype(np.int32)]) for i in range(3)]
prompts.append(prompts[0].copy())     # exact repeat: the COW-fork path

kw = dict(engine="continuous", batch_size=2, max_len=64, page_size=8,
          prefill_chunk=8, decode_chunk=4, num_pages=40)
ref = make_engine(cfg, params, **kw).generate(prompts, 6)

mesh = make_serve_mesh(2, 4)
eng = make_engine(cfg, params, prefix_cache=True, mesh=mesh, **kw)
got = eng.generate(prompts, 6)
for i, (r, g) in enumerate(zip(ref, got)):
    assert np.array_equal(r, g), (i, r, g)
st = eng.stats()
assert st["prefix_hit_rate"] > 0, st
# aliasing is host-table-only: the pool stays genuinely distributed
per = eng.kv.pool_bytes_by_device()
assert len(per) == 8 and max(per.values()) == eng.kv.pool_bytes() // 4
print("OK", {arch!r})
"""


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-moe-16b"])
def test_mesh_prefix_cache_bitwise(arch):
    """Radix prefix cache on the (2, 4) serve mesh: aliasing edits only
    the replicated HOST page table while pool feature axes stay
    model-sharded — cache on vs off (and vs host) must be bitwise."""
    out = run_with_devices(PREFIX_MESH_SNIPPET.format(arch=arch))
    assert "OK" in out


def test_mesh_legacy_engine_matches_solo():
    """The slab reference engine takes the same mesh= and must also be
    placement-invariant."""
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.models import init_model
    from repro.launch.mesh import make_serve_mesh
    from repro.serve import make_engine

    cfg = smoke_config("qwen3-1.7b").with_overrides(dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    pr = jnp.asarray(np.tile(np.arange(4, 12, dtype=np.int32), (2, 1)))
    ref = np.asarray(make_engine(cfg, params, engine="legacy",
                                 batch_size=2, max_len=64,
                                 dtype=jnp.float32).generate(pr, 8))
    eng = make_engine(cfg, params, engine="legacy", batch_size=2,
                      max_len=64, dtype=jnp.float32,
                      mesh=make_serve_mesh(2, 4))
    got = np.asarray(eng.generate(pr, 8))
    assert np.array_equal(ref, got), (ref, got)
    """)


def test_pool_specs_follow_divisibility():
    """pool_spec unit semantics on a real (2, 4) mesh: kv heads shard
    over "model" when divisible, fall back to head_dim, replicate
    per-slot leaves; MLA latent shards its last axis."""
    run_with_devices("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import smoke_config
    from repro.launch.mesh import make_serve_mesh
    from repro.serve import PagedKVCache
    from repro.sharding import pool_spec

    mesh = make_serve_mesh(2, 4)

    class Leaf:
        def __init__(self, shape): self.shape = shape

    cfg = smoke_config("deepseek-moe-16b")   # kv_heads=4 : head-sharded
    assert pool_spec(cfg, mesh, "/blocks/k", Leaf((1, 256, 4, 64)),
                     -1) == P(None, None, "model", None)
    cfg = smoke_config("qwen3-1.7b")         # kv_heads=2 : head_dim
    assert pool_spec(cfg, mesh, "/layers/0/k", Leaf((256, 2, 64)),
                     -1) == P(None, None, "model")
    # MLA latent (N, r): last axis over "model"
    v3 = smoke_config("deepseek-v3-671b")
    assert pool_spec(v3, mesh, "/layers/0/ckv", Leaf((256, 32)),
                     -1) == P(None, "model")
    # per-slot (SSM) leaves: replicated whatever their shape
    assert pool_spec(cfg, mesh, "/layers/1/ssm", Leaf((4, 8, 16)),
                     0) == P(None, None, None)
    print("OK")
    """)


def test_kvcache_accounting_host_path():
    """Host path (mesh=None): the per-device sweep degenerates to the
    full pool on the single default device."""
    import jax.numpy as jnp

    from repro.configs import smoke_config
    from repro.serve import PagedKVCache

    cfg = smoke_config("qwen3-1.7b").with_overrides(dtype="float32")
    kv = PagedKVCache(cfg, slots=2, max_len=64, page_size=16,
                      dtype=jnp.float32)
    assert kv.shardings is None
    assert kv.pool_bytes_per_device() == kv.pool_bytes()


def test_launcher_mesh_end_to_end_no_systemexit():
    """The acceptance path: the launcher runs the CONTINUOUS engine on
    a serve mesh (no --reduced refusal, no SystemExit) and its outputs
    equal the host-path run bit-for-bit."""
    run_with_devices("""
    from repro.launch.serve import main

    base = ["--arch", "deepseek-moe-16b", "--reduced", "--batch", "2",
            "--prompt-len", "8", "--new-tokens", "6",
            "--engine", "continuous"]
    host = main(base)
    mesh = main(base + ["--mesh-shape", "2x4"])
    assert host == mesh, (host, mesh)
    print("OK")
    """)


def test_launcher_requests_normalisation_and_legacy_refusal():
    """S1: --requests 0 / omitted both resolve to --batch in one place;
    the legacy-engine refusal reports the RESOLVED values."""
    from repro.launch.serve import main

    base = ["--arch", "qwen3-1.7b", "--reduced", "--batch", "2",
            "--prompt-len", "6", "--new-tokens", "4"]
    outs_default = main(base)
    assert len(outs_default) == 2                 # resolved to --batch
    outs_zero = main(base + ["--requests", "0"])  # legacy sentinel
    assert outs_zero == outs_default

    with pytest.raises(SystemExit) as ei:
        main(base + ["--engine", "legacy", "--requests", "5"])
    msg = str(ei.value)
    assert "--requests 5" in msg and "--batch 2" in msg

    # the sentinel must NOT trip the refusal (0 means "--batch", not 0)
    outs = main(base + ["--engine", "legacy", "--requests", "0"])
    assert len(np.asarray(outs)) == 2
