"""Strategy registry + Trainer facade + multi-pod zero1_hier (ISSUE 4
tentpole).

Acceptance:

* the registry is the single dispatch point: duplicate registration
  raises, unknown ``strategy=`` names list the registered names, legacy
  pre-registry spellings resolve through the deprecation shim with a
  warning;
* a toy custom strategy registered in-test round-trips through
  ``Trainer.create`` → ``.step`` → ``.save``/``.restore`` (and the
  checkpoint meta records the registry strategy name, which restore
  resolves — failing loudly with the name list when unknown);
* ``zero1_hier`` — registered purely through the public API — matches
  sequential ≤1e-5 on the emulated (2,4) pod×data mesh with optimizer
  state sharded over the *global* 8 workers, and its perf-model entry
  shows the DCN saving;
* zero3's per-shard init builds from shape structs: a template
  constructed without ever materialising the params keeps every live
  buffer under full-model size.
"""
import importlib.util
import os
import warnings

import numpy as np
import pytest

from conftest import run_with_devices

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.api import Trainer
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import MNIST_DNN
from repro.core import DPConfig
from repro import optim

net = MNIST_DNN
key = jax.random.PRNGKey(0)
from repro.models import init_paper_net, apply_paper_net
params = init_paper_net(net, key)
x = jax.random.normal(key, (64, 784)); y = jax.random.randint(key, (64,), 0, 10)
batch = {'x': x, 'y': y}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])

def max_err(t1, t2):
    return max(np.abs(np.asarray(a) - np.asarray(b)).max()
               for a, b in zip(jax.tree_util.tree_leaves(t1),
                               jax.tree_util.tree_leaves(t2)))
"""


# --------------------------------------------------------------------------
# the registry (host-side, no devices needed)
# --------------------------------------------------------------------------

def test_registry_lists_builtins_and_rejects_duplicates():
    from repro.core.strategy import (FlatStrategy, ShardedStrategy,
                                     available_strategies,
                                     register_strategy)
    names = available_strategies()
    for expected in ("flat", "bucketed", "hierarchical", "zero1", "zero2",
                     "zero3", "zero1_hier"):
        assert expected in names, names
    with pytest.raises(ValueError, match="already registered"):
        register_strategy(FlatStrategy())      # duplicate name
    # overwrite=True is the sanctioned replacement path
    register_strategy(FlatStrategy(), overwrite=True)
    with pytest.raises(TypeError):
        register_strategy("flat")              # not a Strategy instance

    # a sharded strategy that forgets to declare its own layout kind
    # (inheriting "replicated") must fail AT REGISTRATION, not poison
    # the shared kind table for every replicated layout in the process
    class Forgot(ShardedStrategy):
        name = "forgot_kind"

    with pytest.raises(ValueError, match="declare its own kind"):
        register_strategy(Forgot())
    from repro.core.train_state import Layout
    assert not Layout("replicated", (), 1, 4, 4).sharded


def test_unknown_strategy_lists_registered_names():
    from repro.core.strategy import get_strategy
    with pytest.raises(ValueError) as ei:
        get_strategy("definitely_not_registered")
    msg = str(ei.value)
    assert "flat" in msg and "zero1_hier" in msg and "register" in msg


def test_legacy_alias_resolves_with_deprecation_warning():
    from repro.core.strategy import get_strategy
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        strat = get_strategy("zero-1")
    assert strat.name == "zero1"
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert any("registered" in str(x.message) for x in w)


def test_perf_model_is_registry_driven():
    """dp_memory_report and bucket_comm_time are thin drivers over the
    registry; zero1_hier contributes its own rows and its comm model
    shows the DCN saving over single-level zero1 across pods."""
    from repro.core import perf_model
    rpt = perf_model.dp_memory_report(33_300_000_000, 2, 32)
    # zero1_hier: per-device memory identical to zero1 (opt state over
    # the GLOBAL pod*data axes)
    for part in ("params", "grads", "opt_state", "total", "ratio"):
        assert rpt[f"{part}_zero1_hier"] == rpt[f"{part}_zero1"], part
    v = 4 * 33.3e9
    t_hier = perf_model.zero1_hier_comm_time(v, n_intra=16, n_pods=2)
    t_flat = perf_model.zero1_flat_multipod_comm_time(v, n_intra=16,
                                                      n_pods=2)
    assert 0 < t_hier < t_flat      # DCN carries 1/n_intra of the volume
    # degenerate single-pod case: no DCN term, matches plain zero1 shape
    t1 = perf_model.zero1_hier_comm_time(v, n_intra=16, n_pods=1)
    assert t1 == pytest.approx(perf_model.zero1_comm_time(v, p=16))
    # bucket_comm_time resolves through the registry (unknown -> names)
    with pytest.raises(ValueError, match="registered"):
        perf_model.bucket_comm_time(v, p=8, strategy="nope")


# --------------------------------------------------------------------------
# zero1_hier through the public API (the extensibility proof rides the
# same path a plugin would)
# --------------------------------------------------------------------------

def test_zero1_hier_matches_sequential_on_pod_data_mesh():
    """Acceptance: zero1_hier ≤1e-5 vs sequential after 5 adam steps on
    the (2,4) pod×data mesh, with moments sharded over the GLOBAL 8
    workers and the layout recording the intra-major axis order."""
    run_with_devices(COMMON + """
mesh = make_mesh((2, 4), ('pod', 'data'), axis_types=auto_axis_types(2))
opt = lambda: optim.adam(1e-3)
seq = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt(),
                     mesh=None)
dp = DPConfig(sync='grads', strategy='zero1_hier')
t = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt(),
                   dp=dp, mesh=mesh)
for i in range(5):
    seq.step(batch)
    m = t.step(batch)
assert np.isfinite(float(m['loss'])) and float(m['grad_norm']) > 0
err = max_err(seq.params, t.params)
print('ERR', err)
assert err < 1e-5, err
st = t.state
assert st.layout.kind == 'zero1_hier'
assert st.layout.axes == ('data', 'pod')      # intra-major linearisation
assert st.layout.num_shards == 8
assert st.layout.strategy == 'zero1_hier'
padded = st.layout.padded_total
for name in ('m', 'v'):
    leaf = st.opt_state[name]['flat']
    sizes = {s.data.size for s in leaf.addressable_shards}
    assert sizes == {padded // 8}, (name, sizes)
# describe() surfaces the strategy's own perf-model entries
d = t.describe()
assert d['strategy'] == 'zero1_hier' and d['world_size'] == 8
assert d['memory_per_device_bytes']['opt_state'] == 4.0 * 2 * (padded // 8)
assert d['comm_time_s'] > 0
print('OK')
""")


def test_zero1_hier_staged_collectives_in_hlo():
    """The lowered HLO stages the reduction: separate reduce-scatter
    pairs over the data axis (ICI, full volume) and the pod axis (DCN,
    1/n_intra volume), and the updated-param gathers mirror them —
    i.e. the DCN collectives really do move only the shard."""
    run_with_devices(COMMON + """
import re
mesh = make_mesh((2, 4), ('pod', 'data'), axis_types=auto_axis_types(2))
dp = DPConfig(sync='grads', strategy='zero1_hier')
t = Trainer.create(loss_fn=loss_fn, params=params, optimizer=optim.sgd(0.1),
                   dp=dp, mesh=mesh)
hlo = t.lower(batch).as_text()
# four staged collectives on the flat master vector: rs(data), rs(pod),
# ag(pod), ag(data) — with the pod-stage tensors 1/4 the data-stage ones
padded = t.state.layout.padded_total
assert padded % 8 == 0
big, small = padded, padded // 4
assert f'tensor<{big}xf32>' in hlo
assert f'tensor<{small}xf32>' in hlo
n_rs = len(re.findall(r'reduce_scatter', hlo))
n_ag = len(re.findall(r'all_gather', hlo))
assert n_rs >= 2 and n_ag >= 2, (n_rs, n_ag)
print('OK', n_rs, n_ag)
""")


def test_zero1_hier_checkpoint_cross_layout():
    """zero1_hier state checkpoints gather-free and reshards into plain
    zero1 (and back) through the canonical flat representation —
    training continues identically after the reshard."""
    run_with_devices(COMMON + """
import os, tempfile
mesh = make_mesh((2, 4), ('pod', 'data'), axis_types=auto_axis_types(2))
tmp = tempfile.mkdtemp()
opt = lambda: optim.adam(1e-3)
dph = DPConfig(sync='grads', strategy='zero1_hier')
dp1 = DPConfig(sync='grads', strategy='zero1')
th = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt(),
                    dp=dph, mesh=mesh)
t1 = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt(),
                    dp=dp1, mesh=mesh)
for i in range(3):
    th.step(batch); t1.step(batch)
d = os.path.join(tmp, 'hier')
th.save(d)
import json, pathlib
meta = json.loads((pathlib.Path(d) / 'step_0000000003.shards'
                   / 'meta.json').read_text())
assert meta['layout']['strategy'] == 'zero1_hier', meta['layout']
# same-layout restore is bitwise
fresh = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt(),
                       dp=dph, mesh=mesh)
assert fresh.restore(d) == 3
assert max_err(fresh.state.params, th.state.params) == 0.0
# cross-layout: hier checkpoint into zero1 (different kind AND axis
# order) — moments agree with the independently trained zero1 run
tz = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt(),
                    dp=dp1, mesh=mesh)
assert tz.restore(d) == 3
assert max_err(tz.state.params, t1.state.params) < 1e-5
errm = np.abs(np.asarray(tz.state.opt_state['m']['flat'])
              - np.asarray(t1.state.opt_state['m']['flat'])).max()
assert errm < 1e-5, errm
m = tz.step(batch)
assert np.isfinite(float(m['loss']))
print('OK')
""")


# --------------------------------------------------------------------------
# custom strategies through the public registry
# --------------------------------------------------------------------------

def test_custom_strategy_roundtrips_through_trainer():
    """A toy strategy registered in-test is a first-class citizen:
    Trainer.create resolves it, training matches its base algorithm,
    the checkpoint meta carries its name, restore resolves it — and a
    process that does NOT register it fails with the name list."""
    run_with_devices(COMMON + """
import os, tempfile
from repro.core.strategy import FlatStrategy, register_strategy

mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))

class ToyStrategy(FlatStrategy):
    name = 'toy_flat'

register_strategy(ToyStrategy())

dp = DPConfig(sync='grads', strategy='toy_flat')
t = Trainer.create(loss_fn=loss_fn, params=params, optimizer=optim.sgd(0.1),
                   dp=dp, mesh=mesh)
ref = Trainer.create(loss_fn=loss_fn, params=params,
                     optimizer=optim.sgd(0.1),
                     dp=DPConfig(sync='grads', strategy='flat'), mesh=mesh)
for i in range(3):
    t.step(batch); ref.step(batch)
assert max_err(t.params, ref.params) < 1e-7      # same algorithm
tmp = tempfile.mkdtemp()
d = os.path.join(tmp, 'toy')
t.save(d)
import json, pathlib
meta = json.loads((pathlib.Path(d) / 'step_0000000003.shards'
                   / 'meta.json').read_text())
assert meta['layout']['strategy'] == 'toy_flat', meta['layout']
fresh = Trainer.create(loss_fn=loss_fn, params=params,
                       optimizer=optim.sgd(0.1), dp=dp, mesh=mesh)
assert fresh.restore(d) == 3
assert max_err(fresh.state.params, t.state.params) == 0.0
m = fresh.step(batch)
assert np.isfinite(float(m['loss']))

# a Strategy INSTANCE passed straight into DPConfig (never registered)
# trains AND saves — only a restore elsewhere demands registration
class Unregistered(FlatStrategy):
    name = 'never_registered'

dpu = DPConfig(sync='grads', strategy=Unregistered())
tu = Trainer.create(loss_fn=loss_fn, params=params,
                    optimizer=optim.sgd(0.1), dp=dpu, mesh=mesh)
tu.step(batch)
du = os.path.join(tmp, 'unreg')
tu.save(du)
meta = json.loads((pathlib.Path(du) / 'step_0000000001.shards'
                   / 'meta.json').read_text())
assert meta['layout']['strategy'] == 'never_registered'
print('OK')
""")


def test_restore_of_unregistered_strategy_lists_names():
    """A checkpoint whose meta names a strategy this process never
    registered fails loudly with the registered-name list — not a
    shard-shape mismatch three layers down."""
    run_with_devices(COMMON + """
import json, os, pathlib, tempfile
mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
dp = DPConfig(sync='grads', strategy='zero1')
t = Trainer.create(loss_fn=loss_fn, params=params, optimizer=optim.adam(1e-3),
                   dp=dp, mesh=mesh)
t.step(batch)
tmp = tempfile.mkdtemp()
d = os.path.join(tmp, 'ck')
t.save(d)
meta_path = pathlib.Path(d) / 'step_0000000001.shards' / 'meta.json'
meta = json.loads(meta_path.read_text())
meta['layout']['strategy'] = 'vanished_plugin'
meta_path.write_text(json.dumps(meta))
try:
    t.restore(d)
    raise SystemExit('expected ValueError')
except ValueError as e:
    msg = str(e)
    assert 'vanished_plugin' in msg and 'zero1_hier' in msg \\
        and 'register' in msg, msg
print('OK')
""")


# --------------------------------------------------------------------------
# zero3 per-shard init from shape structs (ROADMAP residency gap)
# --------------------------------------------------------------------------

def test_zero3_template_from_shape_structs_never_materialises():
    """init_train_state on a ShapeDtypeStruct pytree builds a valid
    zero3 template without the full parameter pytree EVER existing —
    no live device buffer reaches full-model size — and a checkpoint
    restores into it bitwise."""
    run_with_devices(COMMON + """
import gc, os, tempfile
mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
opt = optim.adam(1e-3)
dp = DPConfig(sync='grads', strategy='zero3')
t = Trainer.create(loss_fn=loss_fn, params=params, optimizer=opt,
                   dp=dp, mesh=mesh)
for i in range(2):
    t.step(batch)
tmp = tempfile.mkdtemp()
d = os.path.join(tmp, 'z3')
t.save(d)

pshape = jax.tree_util.tree_map(
    lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params)
del t
tpl = Trainer.create(loss_fn=loss_fn, params=pshape, optimizer=opt,
                     dp=dp, mesh=mesh)
total = tpl.state.layout.total
assert tpl.state.params.shape == (tpl.state.layout.padded_total,)
# live-buffer assertion AT INIT TIME: the template was built from
# shapes alone, so (beyond the caller's own `params` handle, dropped
# here) no buffer of full-model size may exist anywhere
del params
gc.collect()
offenders = [(arr.shape, s.data.size) for arr in jax.live_arrays()
             for s in arr.addressable_shards if s.data.size >= total]
assert not offenders, offenders
assert tpl.restore(d) == 2
m = tpl.step(batch)
assert np.isfinite(float(m['loss']))
print('RESIDENCY OK', total)
""")


# --------------------------------------------------------------------------
# benchmark scenario
# --------------------------------------------------------------------------

def test_benchmark_zero1_hier_scenario_runs():
    spec = importlib.util.spec_from_file_location(
        "bench_run", os.path.join(ROOT, "benchmarks", "run.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rows = mod.bench_zero1_hier(quick=True)
    assert rows and rows[0][0] == "zero1_hier_dp"
    assert rows[0][1] > 0
    assert "DCN" in rows[0][2]
