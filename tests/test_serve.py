"""Serving subsystem: paged KV cache, continuous batching, fused decode
loop, EOS discipline, checkpoint-backed serving (train-and-serve loop).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices

from repro.api import Trainer
from repro.checkpoint import restore_serve_params, save_checkpoint
from repro.configs import smoke_config
from repro.models import apply_model, init_cache, init_model
from repro.serve import (ContinuousScheduler, PagedKVCache, SamplingConfig,
                         ServeEngine, make_engine, make_engine_from_checkpoint,
                         masked_sample)

KEY = jax.random.PRNGKey(7)


def _cfg(arch="qwen3-1.7b", **kw):
    return smoke_config(arch).with_overrides(dtype="float32", **kw)


def _prompts(cfg, lengths, seed=0):
    return [np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + i), (L,), 0, cfg.vocab_size))
        for i, L in enumerate(lengths)]


def _solo_reference(cfg, params, prompt, n_new):
    """Ground-truth greedy generation: plain slab prefill + per-token
    decode, batch 1 — what every engine must reproduce per request."""
    cache = init_cache(cfg, 1, 64, jnp.float32)
    out = apply_model(cfg, params, {"tokens": jnp.asarray(prompt)[None]},
                      mode="prefill", cache=cache, cache_pos=0,
                      last_only=True)
    cache, pos = out["cache"], len(prompt)
    tok = jnp.argmax(out["logits"][:, -1], -1)[:, None]
    gen = [int(tok[0, 0])]
    for _ in range(n_new - 1):
        out = apply_model(cfg, params, {"tokens": tok}, mode="decode",
                          cache=cache, cache_pos=pos)
        cache, pos = out["cache"], pos + 1
        tok = jnp.argmax(out["logits"][:, -1], -1)[:, None]
        gen.append(int(tok[0, 0]))
    return gen


# --------------------------------------------------------------------------
# paged KV cache bookkeeping
# --------------------------------------------------------------------------

def test_kvcache_alloc_free_reuse():
    cfg = _cfg()
    kv = PagedKVCache(cfg, slots=2, max_len=64, page_size=16, num_pages=5)
    assert kv.free_pages == 4                  # page 0 is the trash page
    kv.alloc(0, 33)                            # 3 pages
    assert kv.pages_in_use == 3 and kv.free_pages == 1
    assert set(np.asarray(kv.table())[0, :3].tolist()).isdisjoint({0})
    assert not kv.can_alloc(17)                # would need 2, only 1 free
    with pytest.raises(MemoryError):
        kv.alloc(1, 32)
    kv.free(0)
    assert kv.free_pages == 4
    assert (np.asarray(kv.table())[0] == 0).all()   # row -> trash
    kv.alloc(1, 64)                            # whole pool again
    assert kv.free_pages == 0
    # incremental: topping up an existing allocation only adds pages
    kv.free(1)
    kv.alloc(0, 10)
    kv.alloc(0, 20)                            # +1 page, not 2 fresh
    assert kv.pages_in_use == 2


def test_kvcache_rejects_misaligned_and_overflow():
    cfg = _cfg()
    with pytest.raises(ValueError):
        PagedKVCache(cfg, slots=1, max_len=60, page_size=16)
    kv = PagedKVCache(cfg, slots=1, max_len=32, page_size=16, num_pages=9)
    with pytest.raises(ValueError):
        kv.alloc(0, 33)                        # > max_len


# --------------------------------------------------------------------------
# scheduler == legacy engine (greedy, bitwise)
# --------------------------------------------------------------------------

def test_scheduler_lockstep_bitwise_matches_legacy():
    cfg = _cfg()
    params = init_model(cfg, KEY)
    prompts = jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size)
    eng = ServeEngine(cfg, params, batch_size=3, max_len=64,
                      dtype=jnp.float32)
    ref = np.asarray(eng.generate(prompts, max_new_tokens=10))
    sched = ContinuousScheduler(cfg, params, slots=3, max_len=64,
                                page_size=8, prefill_chunk=8,
                                decode_chunk=4)
    outs = sched.generate(list(np.asarray(prompts)), 10)
    for o, r in zip(outs, ref):
        np.testing.assert_array_equal(o, r)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "jamba-v0.1-52b"])
def test_scheduler_staggered_matches_solo(arch):
    """3 mixed-length requests through 2 slots: the third admits only
    after a retirement, prompts are not chunk-aligned (exercises the
    ragged prefill tail and, for jamba, per-slot SSM state reset on a
    reused slot)."""
    cfg = _cfg(arch)
    params = init_model(cfg, KEY)
    plist = _prompts(cfg, [5, 19, 12])
    sched = ContinuousScheduler(cfg, params, slots=2, max_len=64,
                                page_size=8, prefill_chunk=8,
                                decode_chunk=4)
    outs = sched.generate(plist, 6)
    for p, o in zip(plist, outs):
        assert list(o) == _solo_reference(cfg, params, p, 6)


def test_paged_mla_decode_causal_vs_train():
    """Paged chunked prefill must be per-query causal for MLA too (the
    absorbed-path read goes through the page table)."""
    cfg = _cfg("deepseek-v3-671b", mtp_depth=0)
    params = init_model(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    want = apply_model(cfg, params, {"tokens": prompts},
                       mode="train")["logits"][:, -1]
    from repro.models.attention import PagedView
    pcache = init_cache(cfg, 2, 32, jnp.float32, pool=(10, 8))
    view = PagedView(jnp.array([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32), 8)
    got = apply_model(cfg, params, {"tokens": prompts}, mode="decode",
                      cache=pcache, cache_pos=jnp.zeros((2,), jnp.int32),
                      paged=view)["logits"][:, -1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


# --------------------------------------------------------------------------
# EOS discipline
# --------------------------------------------------------------------------

def test_legacy_engine_post_eos_masking_regression():
    """Retired slots must stop leaking live samples: once a row emits
    EOS every later token is pinned to eos_id, while other rows keep
    generating their solo sequence."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    free = np.asarray(ServeEngine(
        cfg, params, batch_size=2, max_len=64,
        dtype=jnp.float32).generate(prompts, 8))
    # make row0's 3rd token the EOS; row1 must be unaffected
    eos = int(free[0, 2])
    assert eos not in free[1], "degenerate draw; pick another seed"
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      dtype=jnp.float32, eos_id=eos)
    out = np.asarray(eng.generate(prompts, 8))
    np.testing.assert_array_equal(out[0, :3], free[0, :3])
    assert (out[0, 3:] == eos).all(), "post-EOS slot leaked live tokens"
    np.testing.assert_array_equal(out[1], free[1])
    assert eng.host_syncs > 0               # the per-token round-trip


def test_scheduler_eos_retires_and_admits():
    """On-device EOS ends a request mid-stream, frees its pages, and
    the next queued request admits into the slot."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    plist = _prompts(cfg, [8, 8, 8])
    ref = [_solo_reference(cfg, params, p, 10) for p in plist]
    eos = ref[0][3]                          # req0 stops at token 4
    sched = ContinuousScheduler(cfg, params, slots=1, max_len=64,
                                page_size=8, prefill_chunk=8,
                                decode_chunk=4, eos_id=eos)
    outs = sched.generate(plist, 10)
    assert sched.kv.pages_in_use == 0        # everything retired
    for o, r in zip(outs, ref):
        want = r[:r.index(eos) + 1] if eos in r else r
        assert list(o) == want
    assert len(outs[0]) == 4


def test_masked_sample_pins_done_lanes():
    logits = jnp.zeros((3, 16)).at[:, 5].set(9.0)
    done = jnp.array([False, True, False])
    got = masked_sample(logits, KEY, done, 7, SamplingConfig())
    np.testing.assert_array_equal(np.asarray(got), [5, 7, 5])


# --------------------------------------------------------------------------
# fused decode loop: host-sync discipline + throughput
# --------------------------------------------------------------------------

def test_fused_loop_host_sync_discipline_and_speedup():
    """The fused loop's point: >=1 blocking sync per token (legacy)
    becomes ~1 per decode_chunk; on the dispatch-bound tiny config that
    is a measured wall-clock win (the serve_throughput benchmark pins
    the >=2x headline; here we assert a conservative floor)."""
    cfg = _cfg(d_model=64, d_ff=128, num_heads=2, num_kv_heads=1,
               head_dim=32)
    params = init_model(cfg, KEY)
    batch, new = 4, 48
    prompts = jax.random.randint(KEY, (batch, 16), 0, cfg.vocab_size)
    eos = cfg.vocab_size - 1                 # never sampled in practice
    leg = ServeEngine(cfg, params, batch_size=batch, max_len=96,
                      dtype=jnp.float32, eos_id=eos)
    sch = ContinuousScheduler(cfg, params, slots=batch, max_len=96,
                              page_size=16, eos_id=eos, prefill_chunk=16,
                              decode_chunk=8)
    lo = np.asarray(leg.generate(prompts, new))            # warm + check
    so = sch.generate(list(np.asarray(prompts)), new)
    for o, r in zip(so, lo):
        np.testing.assert_array_equal(o, r)
    leg.host_syncs = sch.host_syncs = 0
    sch.tokens_out = 0
    t_leg = t_sch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        leg.generate(prompts, new)
        t_leg = min(t_leg, time.perf_counter() - t0)
        t0 = time.perf_counter()
        sch.generate(list(np.asarray(prompts)), new)
        t_sch = min(t_sch, time.perf_counter() - t0)
    # sync discipline (exact, no timing): legacy ~1/token, fused ~1/chunk
    assert leg.host_syncs >= 3 * (new - 1)
    assert sch.stats()["syncs_per_token"] < 0.25
    # wall clock: generous floor (the benchmark records the real ratio)
    assert t_leg / t_sch > 1.2, (t_leg, t_sch)


# --------------------------------------------------------------------------
# train-and-serve loop
# --------------------------------------------------------------------------

def test_trainer_serve_and_checkpoint_roundtrip(tmp_path):
    cfg = _cfg()
    tr = Trainer.create(model_cfg=cfg, optimizer="adam", lr=1e-3)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    for _ in range(2):
        tr.step(batch)
    tr.save(tmp_path)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    want = np.asarray(tr.serve(engine="legacy", batch_size=2, max_len=32,
                               dtype=jnp.float32).generate(prompts, 6))
    # trained params actually differ from a fresh init: the served
    # outputs must not be those of untrained weights
    fresh = np.asarray(ServeEngine(cfg, init_model(cfg, KEY), batch_size=2,
                                   max_len=32, dtype=jnp.float32)
                       .generate(prompts, 6))
    assert not np.array_equal(want, fresh), \
        "served outputs identical to untrained init (degenerate seed?)"
    eng = make_engine_from_checkpoint(tmp_path, cfg, engine="continuous",
                                      batch_size=2, max_len=32,
                                      page_size=8, dtype=jnp.float32)
    assert eng.restored_step == 2
    outs = eng.generate(list(np.asarray(prompts)), 6)
    for o, w in zip(outs, want):
        np.testing.assert_array_equal(o, w)


def test_trainer_serve_requires_model_cfg():
    loss = lambda p, b: jnp.sum(p["w"] ** 2)  # noqa: E731
    tr = Trainer.create(loss_fn=loss, params={"w": jnp.ones(3)},
                        optimizer="sgd")
    with pytest.raises(ValueError, match="model_cfg"):
        tr.serve()


def test_restore_serve_params_legacy_npz(tmp_path):
    """The GSPMD launcher's legacy npz ((params, opt_state) tuple) also
    serves — read-only, params only."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    save_checkpoint(tmp_path, 3, (params, {"m": jnp.zeros(4)}))
    template = jax.eval_shape(lambda: params)
    got, at = restore_serve_params(tmp_path, template)
    assert at == 3
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero1_checkpoint_serves(tmp_path):
    """Acceptance: launch/train.py-style zero1 sharded checkpoint ->
    launch/serve.py --restore generates from the restored params.  The
    8-device zero1 state is written in a subprocess; the single-device
    parent restores it read-only (layout-independence of the store)."""
    run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import Trainer
        from repro.configs import smoke_config
        from repro.core import DPConfig
        from repro.launch.mesh import make_host_mesh
        cfg = smoke_config("qwen3-1.7b").with_overrides(dtype="float32")
        tr = Trainer.create(model_cfg=cfg, optimizer="adam", lr=1e-3,
                            dp=DPConfig(strategy="zero1"),
                            mesh=make_host_mesh(8))
        batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                               (8, 16), 0, cfg.vocab_size)}}
        for _ in range(2):
            tr.step(batch)
        tr.save(r"{tmp_path}")
        np.save(r"{tmp_path}/expect.npy", np.concatenate(
            [np.asarray(l).ravel()[:3] for l in
             jax.tree_util.tree_leaves(tr.params)][:4]))
        print("saved")
    """, 8)
    from repro.launch import serve as serve_launch
    from repro.sharding.ctx import get_activation_mesh, set_activation_mesh
    set_activation_mesh("sentinel")          # must be scoped away AND back
    outs = serve_launch.main([
        "--arch", "qwen3-1.7b", "--reduced", "--restore", str(tmp_path),
        "--batch", "2", "--prompt-len", "8", "--new-tokens", "4"])
    assert get_activation_mesh() == "sentinel"
    set_activation_mesh(None)
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
    # and the restored params really are the subprocess's trained ones
    cfg = _cfg()
    template = jax.eval_shape(lambda: init_model(cfg, KEY))
    params, at = restore_serve_params(tmp_path, template)
    assert at == 2
    expect = np.load(f"{tmp_path}/expect.npy")
    got = np.concatenate([np.asarray(l).ravel()[:3] for l in
                          jax.tree_util.tree_leaves(params)][:4])
    np.testing.assert_allclose(got, expect, rtol=1e-6)


def test_pool_exhaustion_raises_only_when_unservable():
    """A request that can never fit an EMPTY pool raises; one that
    merely has to wait for a retirement is served."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    plist = _prompts(cfg, [8, 8])
    # pool of 3 real pages (24 tokens): each request needs 8+4+4=16 ->
    # 2 pages; both cannot be live at once, sequentially they fit
    sched = ContinuousScheduler(cfg, params, slots=2, max_len=32,
                                page_size=8, num_pages=4,
                                prefill_chunk=8, decode_chunk=4)
    outs = sched.generate(plist, 4)
    assert all(len(o) == 4 for o in outs)
    big = ContinuousScheduler(cfg, params, slots=1, max_len=32,
                              page_size=8, num_pages=2,
                              prefill_chunk=8, decode_chunk=4)
    with pytest.raises(MemoryError):
        big.generate([plist[0]], 4)


def test_submit_rejects_empty_prompt():
    """Rejected at submit, not mid-admission: a failure after alloc
    would leak the slot's pages."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    sched = ContinuousScheduler(cfg, params, slots=1, max_len=32,
                                page_size=8)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(np.zeros((0,), np.int32), 4)
    assert sched.kv.pages_in_use == 0


def test_make_engine_dispatch():
    cfg = _cfg()
    params = init_model(cfg, KEY)
    assert isinstance(make_engine(cfg, params, engine="legacy",
                                  batch_size=1, max_len=32), ServeEngine)
    assert isinstance(make_engine(cfg, params, engine="continuous",
                                  batch_size=1, max_len=32, page_size=8),
                      ContinuousScheduler)
    with pytest.raises(ValueError):
        make_engine(cfg, params, engine="nope")
