"""Production-feature tests: grad clipping, schedules, sampling,
sigmoid router, sequence packing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.data.packing import pack_documents, packing_labels
from repro.models import moe as moe_lib
from repro.serve.sampling import SamplingConfig, sample
from repro.train.step import clip_by_global_norm, TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# grad clipping
# --------------------------------------------------------------------------

def test_clip_by_global_norm():
    grads = {"a": jnp.array([3.0, 4.0]), "b": jnp.zeros(2)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 5.0)
    out_norm = jnp.sqrt(sum(jnp.sum(g ** 2)
                            for g in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(out_norm), 1.0, rtol=1e-6)
    # below the threshold: untouched
    same, _ = clip_by_global_norm(grads, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]),
                               np.asarray(grads["a"]))


def test_train_step_with_clip_and_cosine():
    cfg = smoke_config("qwen3-1.7b").with_overrides(dtype="float32")
    from repro.models import init_model
    from repro import optim
    params = init_model(cfg, KEY)
    tc = TrainConfig(optimizer="adam", lr=1e-3, grad_clip=0.5,
                     schedule="cosine", warmup_steps=2, total_steps=10)
    step, opt = make_train_step(cfg, None, tc)
    from repro.core import init_train_state
    state = init_train_state(opt, params)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)}
    state, m = jax.jit(step)(state, batch)
    assert int(state.step) == 1
    assert float(m["grad_norm"]) > 0
    assert np.isfinite(float(m["loss"]))


# --------------------------------------------------------------------------
# sampling
# --------------------------------------------------------------------------

def test_greedy_is_argmax():
    logits = jax.random.normal(KEY, (4, 50))
    out = sample(logits, KEY, SamplingConfig(temperature=0.0))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_topk_restricts_support():
    logits = jnp.asarray(np.linspace(0, 10, 50)[None].repeat(8, 0))
    sc = SamplingConfig(temperature=1.0, top_k=3)
    ks = jax.random.split(KEY, 64)
    outs = np.stack([np.asarray(sample(logits, k, sc)) for k in ks])
    assert set(np.unique(outs)) <= {47, 48, 49}


def test_top_p_keeps_at_least_one():
    logits = jnp.zeros((2, 10)).at[:, 3].set(100.0)
    sc = SamplingConfig(temperature=1.0, top_p=0.01)
    out = sample(logits, KEY, sc)
    np.testing.assert_array_equal(np.asarray(out), [3, 3])


@given(st.floats(0.2, 3.0), st.integers(0, 20))
@settings(deadline=None, max_examples=10)
def test_sampling_in_vocab_range(temp, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (3, 17))
    sc = SamplingConfig(temperature=temp, top_k=5, top_p=0.9)
    out = sample(logits, jax.random.PRNGKey(seed + 1), sc)
    assert int(out.min()) >= 0 and int(out.max()) < 17


# --------------------------------------------------------------------------
# sigmoid router (DeepSeek-V3)
# --------------------------------------------------------------------------

def _sig_cfg():
    cfg = smoke_config("deepseek-v3-671b")
    assert cfg.moe.router_type == "sigmoid"
    return cfg


def test_sigmoid_router_weights_normalised():
    cfg = _sig_cfg()
    p = moe_lib.init_moe(cfg, KEY)
    xf = jax.random.normal(KEY, (32, cfg.d_model))
    w, idx, aux = moe_lib._routing(cfg, p, xf)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert "router_bias" in p


def test_router_bias_steers_selection_without_changing_weights_much():
    cfg = _sig_cfg()
    p = moe_lib.init_moe(cfg, KEY)
    xf = jax.random.normal(KEY, (64, cfg.d_model))
    _, idx0, _ = moe_lib._routing(cfg, p, xf)
    # strongly bias expert 0: it must appear in (almost) every selection
    p2 = dict(p, router_bias=p["router_bias"].at[0].set(100.0))
    _, idx1, _ = moe_lib._routing(cfg, p2, xf)
    assert (np.asarray(idx1) == 0).any(axis=1).all()
    assert not (np.asarray(idx0) == 0).any(axis=1).all()


def test_router_bias_gets_no_gradient():
    cfg = _sig_cfg()
    p = moe_lib.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_lib.apply_moe(cfg, p, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router_bias"]).max()) == 0.0
    assert float(jnp.abs(g["router"]).max()) > 0.0


def test_update_router_bias_direction():
    cfg = _sig_cfg()
    p = moe_lib.init_moe(cfg, KEY)
    counts = jnp.array([10.0, 0.0, 5.0, 5.0])   # expert0 overloaded
    new = moe_lib.update_router_bias(cfg, p, counts, gamma=0.1)
    assert float(new[0]) < 0 < float(new[1])


# --------------------------------------------------------------------------
# packing
# --------------------------------------------------------------------------

def test_pack_documents_roundtrip():
    docs = [np.arange(1, 6), np.arange(10, 13), np.arange(20, 40)]
    toks, segs = pack_documents(docs, seq_len=16, eos_id=99)
    # every document's tokens appear, in order, within one segment chain
    flat = toks[segs > 0]
    for d in docs:
        s = " ".join(map(str, d))
        assert s in " ".join(map(str, toks.flatten()))
    # EOS terminates fully-contained documents
    assert (toks == 99).sum() >= 2


def test_packing_labels_never_cross_documents():
    docs = [np.arange(1, 6), np.arange(10, 14)]
    toks, segs = pack_documents(docs, seq_len=12, eos_id=99)
    labels = packing_labels(toks, segs)
    # at segment boundaries the label must be IGNORE
    for r in range(toks.shape[0]):
        for i in range(toks.shape[1] - 1):
            if segs[r, i] != segs[r, i + 1]:
                assert labels[r, i] == -1


@given(st.lists(st.integers(1, 30), min_size=1, max_size=12),
       st.integers(8, 64))
@settings(deadline=None, max_examples=20)
def test_packing_conserves_tokens(lengths, seq_len):
    rng = np.random.default_rng(0)
    docs = [rng.integers(1, 50, size=n) for n in lengths]
    toks, segs = pack_documents(docs, seq_len=seq_len, eos_id=99)
    n_content = int((segs > 0).sum())
    n_expect_min = sum(len(d) for d in docs)        # content tokens
    assert n_content >= n_expect_min                # (+ EOS markers)
    assert toks.shape[1] == seq_len
