"""Checkpoint tier: gather-free sharded round-trips + crash safety.

* zero1/zero2/zero3 save → restore → bitwise-equal shards (contiguous
  AND bucket-major layouts);
* cross-layout restore via host resharding: replicated ↔ zero1, and
  zero1 → zero3 (training continues identically after the reshard);
* atomicity: writers stage under ``tmp-`` and publish with one rename,
  and ``latest_step`` can never pick up a truncated leftover.
"""
import os
import pathlib

import numpy as np
import pytest

from conftest import run_with_devices

COMMON = """
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import MNIST_DNN
from repro.models import init_paper_net, apply_paper_net
from repro.core import (DPConfig, make_dp_train_step, make_sequential_step,
                        host_params, init_train_state)
from repro.checkpoint import (latest_step, restore_sharded_checkpoint,
                              save_sharded_checkpoint)
from repro import optim

mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
net = MNIST_DNN
key = jax.random.PRNGKey(0)
params = init_paper_net(net, key)
x = jax.random.normal(key, (64, 784)); y = jax.random.randint(key, (64,), 0, 10)
batch = {'x': x, 'y': y}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])

opt = optim.adam(1e-3)

def trained(dp, steps=3):
    st = init_train_state(opt, params, mesh, dp)
    step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
    for _ in range(steps):
        st, _ = step(st, batch)
    return st

def shards_of(leaf):
    return [np.asarray(s.data) for s in leaf.addressable_shards]

def bitwise_equal_states(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        if hasattr(la, 'addressable_shards'):
            for sa, sb in zip(shards_of(la), shards_of(lb)):
                if not np.array_equal(sa, sb):
                    return False
        elif not np.array_equal(np.asarray(la), np.asarray(lb)):
            return False
    return True

tmp = tempfile.mkdtemp()
"""


@pytest.mark.parametrize("dp_expr", [
    "DPConfig(strategy='zero1')",
    "DPConfig(strategy='zero2', microbatches=2)",
    "DPConfig(strategy='zero3')",
    "DPConfig(strategy='zero1', overlap=True, bucket_bytes=1 << 16)",
    "DPConfig(strategy='zero3', overlap=True, bucket_bytes=1 << 16)",
])
def test_sharded_roundtrip_bitwise(dp_expr):
    """Acceptance: save → restore under the SAME layout reproduces
    every worker's shard bit for bit — per-shard files, no gather."""
    run_with_devices(COMMON + f"""
dp = {dp_expr}
st = trained(dp)
d = os.path.join(tmp, 'rt')
path = save_sharded_checkpoint(d, int(st.step), st)
assert path.endswith('.shards') and os.path.isdir(path)
assert latest_step(d) == int(st.step)
tpl = init_train_state(opt, params, mesh, dp)
rst, at = restore_sharded_checkpoint(d, tpl)
assert at == int(st.step)
assert rst.layout == st.layout
assert bitwise_equal_states(st, rst)
# training continues identically from the restored state
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
a, _ = step(st, batch)
b, _ = step(rst, batch)
assert bitwise_equal_states(a, b)
print('OK')
""")


def test_cross_layout_replicated_zero1_roundtrip():
    """Acceptance: replicated → zero1 and zero1 → replicated restores
    reshard on host exactly (training math identical both ways)."""
    run_with_devices(COMMON + """
dpr = DPConfig(strategy='flat')
dpz = DPConfig(strategy='zero1')
str_ = trained(dpr)
stz = trained(dpz)

d = os.path.join(tmp, 'rep')
save_sharded_checkpoint(d, int(str_.step), str_)
tplz = init_train_state(opt, params, mesh, dpz)
got, _ = restore_sharded_checkpoint(d, tplz)
# resharded replicated state == independently trained zero1 state
# (flat and zero1 are both sequential-equivalent, adam state matches)
err = max(np.abs(np.asarray(a) - np.asarray(b)).max()
          for a, b in zip(jax.tree_util.tree_leaves(got.params),
                          jax.tree_util.tree_leaves(stz.params)))
assert err < 1e-5, err
errm = np.abs(np.asarray(got.opt_state['m']['flat'])
              - np.asarray(stz.opt_state['m']['flat'])).max()
assert errm < 1e-5, errm
assert int(np.asarray(got.opt_state['step'])) == 3

d2 = os.path.join(tmp, 'z1')
save_sharded_checkpoint(d2, int(stz.step), stz)
tplr = init_train_state(opt, params, mesh, dpr)
back, _ = restore_sharded_checkpoint(d2, tplr)
err = max(np.abs(np.asarray(a) - np.asarray(b)).max()
          for a, b in zip(jax.tree_util.tree_leaves(back.params),
                          jax.tree_util.tree_leaves(str_.params)))
assert err < 1e-5, err
# and the resharded state trains on under its new layout
step = make_dp_train_step(loss_fn, opt, mesh, dpr, donate=False)
back, m = step(back, batch)
assert np.isfinite(float(m['loss']))
print('OK')
""")


def test_cross_layout_zero1_to_zero3_and_bucket_major():
    """Resharding reaches across the whole ladder: a zero1 checkpoint
    restores into zero3 (params scattered to flat shards) under both
    contiguous and bucket-major target layouts."""
    run_with_devices(COMMON + """
dpz = DPConfig(strategy='zero1')
stz = trained(dpz)
d = os.path.join(tmp, 'z1')
save_sharded_checkpoint(d, int(stz.step), stz)
for dpt in (DPConfig(strategy='zero3'),
            DPConfig(strategy='zero3', overlap=True, bucket_bytes=1 << 16)):
    tpl = init_train_state(opt, params, mesh, dpt)
    got, _ = restore_sharded_checkpoint(d, tpl)
    ref = trained(dpt)
    err = np.abs(np.asarray(got.params) - np.asarray(ref.params)).max()
    assert err < 1e-5, (dpt.overlap, err)
    sizes = {s.data.size for s in got.params.addressable_shards}
    assert sizes == {got.layout.shard_len}, sizes
print('OK')
""")


def test_restore_rejects_param_count_mismatch():
    run_with_devices(COMMON + """
dp = DPConfig(strategy='zero1')
st = trained(dp, steps=1)
d = os.path.join(tmp, 'ck')
save_sharded_checkpoint(d, 1, st)
from repro.configs.paper_nets import HIGGS_DNN
other = init_paper_net(HIGGS_DNN, key)
tpl = init_train_state(opt, other, mesh, dp)
try:
    restore_sharded_checkpoint(d, tpl)
    raise SystemExit('expected ValueError')
except ValueError as e:
    assert 'params' in str(e)
print('OK')
""")


# --------------------------------------------------------------------------
# crash safety (host-side, no devices needed)
# --------------------------------------------------------------------------

def test_truncated_tmp_files_are_invisible(tmp_path):
    """A killed worker leaves only tmp- files/dirs; latest_step must
    never pick them up, and the last published step stays restorable."""
    from repro.checkpoint import (latest_step, restore_checkpoint,
                                  save_checkpoint)
    state = {"w": np.arange(6, dtype=np.float32)}
    save_checkpoint(tmp_path, 3, state)
    # crash scenarios: truncated legacy tmp, truncated sharded tmp dir
    (tmp_path / "tmp-step_0000000007.npz").write_bytes(b"PK\x03garbage")
    partial = tmp_path / "tmp-step_0000000008.shards"
    partial.mkdir()
    (partial / "worker_00000.npz").write_bytes(b"trunc")
    # the marker is what a restart reads first; the fallback glob must
    # agree with it even when the marker is torn or gone
    assert latest_step(tmp_path) == 3
    (tmp_path / "latest").write_text("")     # kill mid-write: torn marker
    assert latest_step(tmp_path) == 3
    (tmp_path / "latest").unlink()
    assert latest_step(tmp_path) == 3
    # no tmp- marker residue after a publish
    save_checkpoint(tmp_path, 4, state)
    assert not (tmp_path / "tmp-latest").exists()
    assert latest_step(tmp_path) == 4
    save_checkpoint(tmp_path, 3, state)      # roll back for the restore
    restored, step = restore_checkpoint(tmp_path, {"w": np.zeros(6)})
    assert step == 3
    np.testing.assert_array_equal(restored["w"], state["w"])


def test_legacy_save_is_atomic_and_clean(tmp_path):
    """save_checkpoint stages under tmp- and leaves no leftovers."""
    from repro.checkpoint import save_checkpoint
    save_checkpoint(tmp_path, 1, {"w": np.ones(3, np.float32)})
    names = {p.name for p in tmp_path.iterdir()}
    assert names == {"step_0000000001.npz", "latest"}, names


def test_latest_step_fullmatch_only(tmp_path):
    """Names that merely CONTAIN a step pattern (the old truncation
    hazard: 'step_5.npz.tmp.npz') are ignored by the fallback glob."""
    from repro.checkpoint import latest_step
    (tmp_path / "step_0000000005.npz.tmp.npz").write_bytes(b"junk")
    (tmp_path / "xstep_0000000009.npz").write_bytes(b"junk")
    assert latest_step(tmp_path) is None
    (tmp_path / "step_0000000002.npz").write_bytes(b"ok")
    assert latest_step(tmp_path) == 2


def test_sharded_save_is_atomic(tmp_path):
    """save_sharded_checkpoint publishes the step directory with one
    rename: after a save there is no tmp- residue, and overwriting an
    existing step is safe."""
    run_with_devices(COMMON + """
dp = DPConfig(strategy='zero2')
st = trained(dp, steps=1)
d = os.path.join(tmp, 'atomic')
save_sharded_checkpoint(d, 1, st)
save_sharded_checkpoint(d, 1, st)        # overwrite in place
names = sorted(os.listdir(d))
assert names == ['latest', 'step_0000000001.shards'], names
inner = sorted(os.listdir(os.path.join(d, 'step_0000000001.shards')))
assert 'meta.json' in inner and 'replicated.npz' in inner
assert sum(n.startswith('worker_') for n in inner) == 8, inner
print('OK')
""")
