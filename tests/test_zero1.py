"""ZeRO-1 sharded-optimizer data parallelism (beyond-paper §3.3.3
successor) + the collective-layer bugfix guards.

* every gradient-sync strategy (flat / bucketed / hierarchical / zero1)
  produces the same averaged gradients;
* strategy="zero1" training matches ``make_sequential_step`` params to
  ≤1e-5 after 5 steps on 8 emulated devices, with the optimizer state
  physically sharded 1/8 per device;
* ``perf_model`` reports ~1/n per-device optimizer-state memory for
  zero1 vs the replicated path;
* the ``benchmarks/run.py`` zero1 scenario is runnable;
* empty-pytree guards in ``allreduce_bucketed`` / ``allreduce_mean`` /
  ``_global_norm``.
"""
import importlib.util
import os

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import MNIST_DNN
from repro.models import init_paper_net, apply_paper_net
from repro.core import (DPConfig, make_dp_train_step, make_sequential_step,
                        init_train_state)
from repro import optim

mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
net = MNIST_DNN
key = jax.random.PRNGKey(0)
params = init_paper_net(net, key)
x = jax.random.normal(key, (64, 784)); y = jax.random.randint(key, (64,), 0, 10)
batch = {'x': x, 'y': y}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])

def max_err(t1, t2):
    return max(np.abs(np.asarray(a) - np.asarray(b)).max()
               for a, b in zip(jax.tree_util.tree_leaves(t1),
                               jax.tree_util.tree_leaves(t2)))
"""


@pytest.mark.parametrize("optname,tol", [("sgd", 1e-6), ("adam", 1e-5)])
def test_zero1_matches_sequential(optname, tol):
    """Acceptance (a): zero1 params ≡ sequential large-batch step."""
    run_with_devices(COMMON + f"""
opt = optim.sgd(0.1) if '{optname}' == 'sgd' else optim.adam(1e-3)
seq = make_sequential_step(loss_fn, opt)
dp = DPConfig(sync='grads', strategy='zero1')
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
s1 = init_train_state(opt, params)
s2 = init_train_state(opt, params, mesh, dp)
for i in range(5):
    s1, _ = seq(s1, batch)
    s2, m = step(s2, batch)
err = max_err(s1.params, s2.params)
print('ERR', err)
assert err < {tol}, err
assert np.isfinite(float(m['loss']))
""")


def test_zero1_opt_state_physically_sharded():
    """The moment vectors live 1/8 per device and stay sharded across
    steps (the train step's out_specs keep the shard placement)."""
    run_with_devices(COMMON + """
opt = optim.adam(1e-3)
dp = DPConfig(sync='grads', strategy='zero1')
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
state = init_train_state(opt, params, mesh, dp)
total = sum(l.size for l in jax.tree_util.tree_leaves(params))
padded = total + (-total) % 8
assert state.layout.kind == 'zero1' and state.layout.padded_total == padded
for _ in range(2):
    state, _ = step(state, batch)
for name in ('m', 'v'):
    leaf = state.opt_state[name]['flat']
    assert leaf.shape == (padded,), leaf.shape
    shard_sizes = {s.data.size for s in leaf.addressable_shards}
    assert shard_sizes == {padded // 8}, shard_sizes
print('OK')
""")


def test_all_strategies_identical_averaged_grads():
    """flat / bucketed / hierarchical / zero1 all produce the same mean
    gradient (zero1 via its reduce-scatter + all-gather round trip)."""
    run_with_devices(COMMON + """
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map, shard_map_kwargs
from repro.core import allreduce_mean

def avg_grads(strategy):
    def worker(p, b):
        g = jax.grad(loss_fn)(p, b)
        return allreduce_mean(g, ('data',), strategy=strategy)
    w = shard_map(worker, mesh=mesh, in_specs=(P(), P('data')),
                  out_specs=P(), **shard_map_kwargs(check_vma=False))
    return jax.jit(w)(params, batch)

ref = avg_grads('flat')
for s in ('bucketed', 'hierarchical', 'zero1'):
    err = max_err(ref, avg_grads(s))
    print(s, 'ERR', err)
    assert err < 1e-6, (s, err)
""")


def test_zero1_microbatch_accumulation_matches_sequential():
    """Per-microbatch reduce-scatter accumulation ≡ one big batch."""
    run_with_devices(COMMON + """
opt = optim.sgd(0.1)
seq = make_sequential_step(loss_fn, opt)
dp = DPConfig(sync='grads', strategy='zero1', microbatches=2)
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
s1 = init_train_state(opt, params)
s2 = init_train_state(opt, params, mesh, dp)
for i in range(5):
    s1, _ = seq(s1, batch)
    s2, m = step(s2, batch)
err = max_err(s1.params, s2.params)
print('ERR', err)
assert err < 1e-6, err
""")


def test_zero1_bf16_compressed_reduce_scatter():
    """ROADMAP bf16 gap: compress="bf16" now rides the reduce-scatter —
    grads cross the wire in bfloat16, the optimizer keeps the fp32
    master shard.  Mirrors the replicated bf16 loss-bound case in
    tests/test_data_parallel.py (lossy wire => 5e-2 tolerance), and
    additionally checks the moment/master state stays fp32."""
    run_with_devices(COMMON + """
opt = optim.adam(1e-3)
seq = make_sequential_step(loss_fn, opt)
for mb in (1, 2):
    dp = DPConfig(sync='grads', strategy='zero1', compress='bf16',
                  microbatches=mb)
    step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
    sa = init_train_state(opt, params)
    s2 = init_train_state(opt, params, mesh, dp)
    for i in range(5):
        sa, _ = seq(sa, batch)
        s2, m = step(s2, batch)
    err = max_err(sa.params, s2.params)
    print('mb', mb, 'ERR', err)
    assert err < 5e-2, (mb, err)                 # lossy wire, bounded
    assert err > 0.0                             # really went through bf16
    assert np.isfinite(float(m['loss']))
    for name in ('m', 'v'):                      # fp32 master state
        assert s2.opt_state[name]['flat'].dtype == jnp.float32
print('OK')
""")


def test_zero1_bf16_shard_is_fp32_master():
    """Unit-level: reduce_scatter_mean(compress='bf16') reduces in bf16
    (result differs from the fp32 path) but returns an fp32 shard."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, auto_axis_types, shard_map, \
    shard_map_kwargs
from repro.core import all_gather_tree, reduce_scatter_mean

mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
tree = {'w': jax.random.normal(jax.random.PRNGKey(0), (8, 1000))}

def worker(t, compress):
    sh, spec = reduce_scatter_mean(t, ('data',), compress=compress)
    assert sh.dtype == jnp.float32, sh.dtype
    return all_gather_tree(sh, ('data',), spec)

f32 = jax.jit(shard_map(lambda t: worker(t, 'none'), mesh=mesh,
                        in_specs=(P('data'),), out_specs=P(),
                        **shard_map_kwargs(check_vma=False)))(tree)
bf16 = jax.jit(shard_map(lambda t: worker(t, 'bf16'), mesh=mesh,
                         in_specs=(P('data'),), out_specs=P(),
                         **shard_map_kwargs(check_vma=False)))(tree)
err = np.abs(np.asarray(f32['w']) - np.asarray(bf16['w'])).max()
print('wire err', err)
assert 0 < err < 5e-2, err
assert bf16['w'].dtype == jnp.float32
""")


def test_perf_model_zero1_memory_is_one_nth():
    """Acceptance (b): perf_model per-device optimizer-state bytes for
    zero1 ≈ 1/n of the replicated path."""
    from repro.core import perf_model
    n_params, n = 178_110, 8
    rep = perf_model.opt_state_bytes_per_device(
        n_params, 2, n_workers=n, strategy="replicated")
    z1 = perf_model.opt_state_bytes_per_device(
        n_params, 2, n_workers=n, strategy="zero1")
    assert abs(z1 / rep - 1.0 / n) < 1e-3
    rpt = perf_model.dp_memory_report(n_params, 2, n)
    assert abs(rpt["opt_state_ratio"] - 1.0 / n) < 1e-3
    assert rpt["total_zero1"] < rpt["total_replicated"]
    # wire volume: zero1 matches a ring allreduce, not worse
    t_z1 = perf_model.zero1_comm_time(4 * n_params, p=n)
    assert t_z1 > 0.0


def test_empty_tree_guards():
    """allreduce_bucketed / allreduce_mean pass empty pytrees through;
    _global_norm returns a float32 zero, not a Python int."""
    from repro.core.collectives import allreduce_bucketed, allreduce_mean
    from repro.core.data_parallel import _global_norm
    assert allreduce_bucketed({}, ("data",)) == {}
    assert allreduce_mean({}, ("data",), strategy="bucketed") == {}
    assert allreduce_mean([], ("data",), strategy="zero1") == []
    norm = _global_norm({})
    assert isinstance(norm, jnp.ndarray) and norm.dtype == jnp.float32
    assert float(norm) == 0.0


def test_benchmark_zero1_scenario_runs():
    """Acceptance (c): the benchmarks/run.py zero1 scenario executes."""
    spec = importlib.util.spec_from_file_location(
        "bench_run", os.path.join(ROOT, "benchmarks", "run.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rows = mod.bench_zero1(quick=True)
    assert rows and rows[0][0] == "zero1_dp"
    assert rows[0][1] > 0                      # measured us/step
    assert "opt_floats/dev" in rows[0][2]
