"""The paper's core claims, as tests.

* §3.3.3: synchronous gradient averaging over p workers is equivalent to
  sequential large-batch SGD — asserted to float tolerance for every
  registered collective strategy (incl. the registry-defined multi-pod
  ``zero1_hier``), on single- and multi-pod meshes (8 emulated devices
  in a subprocess), driven end to end through the ``repro.api.Trainer``
  facade — the sequential reference is the same facade with
  ``mesh=None``.
* §3.3.2: periodic weight averaging (the paper's per-epoch sync) keeps
  workers consistent after each sync point.
"""
import numpy as np
import pytest

from conftest import run_with_devices

EQUIV_SNIPPET = """
import jax, jax.numpy as jnp, numpy as np
from repro.api import Trainer
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import MNIST_DNN
from repro.models import init_paper_net, apply_paper_net
from repro.core import DPConfig
from repro import optim

mesh = make_mesh({mesh_shape}, {mesh_axes},
                 axis_types=auto_axis_types({ndim}))
net = MNIST_DNN
key = jax.random.PRNGKey(0)
params = init_paper_net(net, key)
x = jax.random.normal(key, (64, 784)); y = jax.random.randint(key, (64,), 0, 10)
batch = {{'x': x, 'y': y}}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])

seq = Trainer.create(loss_fn=loss_fn, params=params, optimizer=optim.sgd(0.1),
                     mesh=None)
for i in range(5):
    seq.step(batch)

strategy = '{strategy}'
dp = DPConfig(sync='grads', strategy=strategy, compress='{compress}')
t = Trainer.create(loss_fn=loss_fn, params=params, optimizer=optim.sgd(0.1),
                   dp=dp, mesh=mesh)
assert t.describe()['strategy'] == strategy
for i in range(5):
    t.step(batch)
assert int(t.state.step) == 5
err = max(np.abs(np.asarray(a) - np.asarray(b)).max()
          for a, b in zip(jax.tree_util.tree_leaves(seq.params),
                          jax.tree_util.tree_leaves(t.params)))
print('ERR', err)
assert err < {tol}, err
"""

STRATEGIES = ["flat", "bucketed", "hierarchical", "zero1", "zero2", "zero3",
              "zero1_hier"]


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_grad_sync_equals_sequential_single_pod(strategy):
    run_with_devices(EQUIV_SNIPPET.format(
        mesh_shape="(8,)", mesh_axes="('data',)", ndim=1,
        strategy=strategy, compress="none", tol=1e-6))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_grad_sync_equals_sequential_multi_pod(strategy):
    run_with_devices(EQUIV_SNIPPET.format(
        mesh_shape="(2, 4)", mesh_axes="('pod', 'data')", ndim=2,
        strategy=strategy, compress="none", tol=1e-6))


def test_bf16_compression_approximates_sequential():
    """Compressed allreduce is lossy but must stay close (beyond-paper)."""
    run_with_devices(EQUIV_SNIPPET.format(
        mesh_shape="(8,)", mesh_axes="('data',)", ndim=1,
        strategy="flat", compress="bf16", tol=5e-2))


def test_weight_averaging_consistency():
    """Paper §3.3.2 local-SGD mode: after a sync step every worker holds
    the same parameters; between syncs they may diverge."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import HIGGS_DNN
from repro.models import init_paper_net, apply_paper_net
from repro.core import DPConfig, make_dp_train_step
from repro import optim

mesh = make_mesh((8,), ('data',), axis_types=auto_axis_types(1))
net = HIGGS_DNN
key = jax.random.PRNGKey(1)
params = init_paper_net(net, key)
x = jax.random.normal(key, (64, 28)); y = jax.random.randint(key, (64,), 0, 2)
batch = {'x': x, 'y': y}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])

opt = optim.sgd(0.05)
dp = DPConfig(sync='weights', sync_period=2)
step = make_dp_train_step(loss_fn, opt, mesh, dp, donate=False)
from repro.core import init_train_state
s = init_train_state(opt, params, mesh, dp)
for i in range(4):   # sync fires when state.step+1 hits 2 and 4
    s, m = step(s, batch)
# after a sync step, the replicated output must be self-consistent and finite
for leaf in jax.tree_util.tree_leaves(s.params):
    assert np.isfinite(np.asarray(leaf)).all()
print('OK')
""")


def test_ps_baseline_converges_slower_or_equal():
    """The paper rejected async parameter-server updates; on a convex-ish
    toy problem sync DP's loss after N ticks must not be worse than
    async-PS by a large margin (and both must decrease)."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.param_server import make_ps_trainer
from repro import optim

key = jax.random.PRNGKey(0)
w_true = jax.random.normal(key, (16,))
X = jax.random.normal(jax.random.PRNGKey(1), (256, 16))
yv = X @ w_true

def loss_fn(p, b):
    xb, yb = b
    return jnp.mean((xb @ p['w'] - yb) ** 2)

params = {'w': jnp.zeros((16,))}
opt = optim.sgd(0.05)
ticks = 64
batches = (X.reshape(ticks, 4, 16), yv.reshape(ticks, 4))

ps = make_ps_trainer(loss_fn, opt, num_workers=8)
p_ps, _, losses = ps(params, opt.init(params), batches)

# sequential sync baseline over the same stream
p_sq, s_sq = params, opt.init(params)
for i in range(ticks):
    g = jax.grad(loss_fn)(p_sq, (batches[0][i], batches[1][i]))
    p_sq, s_sq = opt.update(g, s_sq, p_sq)

l_ps = loss_fn(p_ps, (X, yv)); l_sq = loss_fn(p_sq, (X, yv))
print('ps', float(l_ps), 'sync', float(l_sq))
assert float(l_ps) < float(losses[0])          # async does learn
assert float(l_sq) <= float(l_ps) * 1.5 + 1e-3  # sync at least as good
""", n_devices=1)
