"""Comm/compute overlap test tier (ISSUE 2 tentpole).

Proves the bucket-level overlap scheduler (``repro.core.overlap``) on
three axes:

* numerics — overlapped vs non-overlapped train steps agree ≤1e-5
  after 5 steps on 8 emulated devices, for all four strategies (and
  the zero1 software-pipelined microbatch path);
* structure — the *lowered* HLO of an ``overlap=True`` step contains
  collectives with concurrent work to hide behind, which
  ``asyncify_hlo`` splits into ``all-reduce-start``/``all-reduce-done``
  (``reduce-scatter-start``/…) pairs; the barrier-chained
  ``overlap="serial"`` baseline yields none;
* model — ``perf_model.overlapped_step_time`` degenerates to serial at
  one bucket and is never slower than serial at any bucketing.
"""
import importlib.util
import os

import numpy as np
import pytest

from conftest import run_with_devices

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, auto_axis_types
from repro.configs.paper_nets import MNIST_DNN
from repro.models import init_paper_net, apply_paper_net
from repro.core import (DPConfig, make_dp_train_step, init_train_state,
                        host_params, asyncify_hlo, lowered_hlo_text)
from repro import optim

mesh = make_mesh({mesh_shape}, {mesh_axes}, axis_types=auto_axis_types({ndim}))
net = MNIST_DNN
key = jax.random.PRNGKey(0)
params = init_paper_net(net, key)
x = jax.random.normal(key, (64, 784)); y = jax.random.randint(key, (64,), 0, 10)
batch = {{'x': x, 'y': y}}

def loss_fn(p, b):
    lg = apply_paper_net(net, p, b['x'])
    return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(lg.shape[0]), b['y']])

def max_err(t1, t2):
    return max(np.abs(np.asarray(a) - np.asarray(b)).max()
               for a, b in zip(jax.tree_util.tree_leaves(t1),
                               jax.tree_util.tree_leaves(t2)))

def make(strategy, overlap, microbatches=1):
    dp = DPConfig(sync='grads', strategy=strategy, overlap=overlap,
                  microbatches=microbatches, bucket_bytes=1 << 16)
    step = make_dp_train_step(loss_fn, optim.adam(1e-3), mesh, dp,
                              donate=False)
    state = init_train_state(optim.adam(1e-3), params, mesh, dp)
    return step, state

def run5(strategy, overlap, microbatches=1):
    step, s = make(strategy, overlap, microbatches)
    for i in range(5):
        s, m = step(s, batch)
    assert np.isfinite(float(m['loss']))
    return host_params(s)
"""

SINGLE = dict(mesh_shape="(8,)", mesh_axes="('data',)", ndim=1)
MULTI = dict(mesh_shape="(2, 4)", mesh_axes="('pod', 'data')", ndim=2)


# --------------------------------------------------------------------------
# numerical equivalence: overlapped vs non-overlapped (all 4 strategies)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["flat", "bucketed", "zero1"])
def test_overlap_equivalence_single_pod(strategy):
    run_with_devices(COMMON.format(**SINGLE) + f"""
err = max_err(run5('{strategy}', False), run5('{strategy}', True))
print('ERR', err)
assert err < 1e-5, err
""")


def test_overlap_equivalence_hierarchical_multipod():
    """hierarchical only has two stages on a pod×data mesh."""
    run_with_devices(COMMON.format(**MULTI) + """
err = max_err(run5('hierarchical', False), run5('hierarchical', True))
print('ERR', err)
assert err < 1e-5, err
""")


def test_overlap_equivalence_hier_zero_multipod():
    """Acceptance (ISSUE 9): zero1_hier/zero3_hier with overlap=True
    run the two-level staged collectives through the bucket scheduler
    and still match the non-overlapped step — overlap is a first-class
    configuration for the hier strategies, not a rejected one."""
    run_with_devices(COMMON.format(**MULTI) + """
ref = run5('zero1_hier', False)
err = max_err(ref, run5('zero1_hier', True))
print('ERR zero1_hier', err)
assert err < 1e-5, err
err = max_err(ref, run5('zero1_hier', 'serial'))
print('ERR zero1_hier serial', err)
assert err < 1e-5, err
err = max_err(run5('zero3_hier', False), run5('zero3_hier', True))
print('ERR zero3_hier', err)
assert err < 1e-5, err
""")


def test_hlo_async_pairs_hier_multipod():
    """The hier bucket pipelines asyncify like the flat ones: the
    lowered overlap=True HLO admits >= 2 reduce-scatter and >= 2
    all-gather -start/-done pairs on the pod×data mesh; zero1_hier's
    barrier-chained serial schedule admits none."""
    run_with_devices(COMMON.format(**MULTI) + """
def rep_of(strategy, overlap):
    step, s = make(strategy, overlap)
    hlo = lowered_hlo_text(step.lower(s, batch))
    return asyncify_hlo(hlo)

for strat in ('zero1_hier', 'zero3_hier'):
    txt, rep = rep_of(strat, True)
    print(strat, 'overlap', rep['pairs'], rep['by_kind'])
    assert rep['by_kind'].get('reduce-scatter', 0) >= 2, (strat, rep)
    assert rep['by_kind'].get('all-gather', 0) >= 2, (strat, rep)
    assert txt.count('reduce-scatter-start(') == \
        txt.count('reduce-scatter-done(')
    assert txt.count('all-gather-start(') == txt.count('all-gather-done(')

stxt, srep = rep_of('zero1_hier', 'serial')
print('zero1_hier serial', srep['pairs'])
assert srep['pairs'] == 0, srep
""")


def test_overlap_serialized_matches_overlapped():
    """'serial' runs the same buckets barrier-chained — same numbers."""
    run_with_devices(COMMON.format(**SINGLE) + """
err = max_err(run5('bucketed', 'serial'), run5('bucketed', True))
print('ERR', err)
assert err < 1e-6, err
""")


def test_zero2_pipelined_microbatches_equivalence():
    """The software-pipelined scan (reduce-scatter of microbatch k
    behind microbatch k+1's backward — the zero2 eager-shard path)
    matches plain accumulation; zero1's accumulate-then-one-RS tail
    must agree too."""
    run_with_devices(COMMON.format(**SINGLE) + """
err = max_err(run5('zero2', False, microbatches=4),
              run5('zero2', True, microbatches=4))
print('ERR', err)
assert err < 1e-5, err
err = max_err(run5('zero1', False, microbatches=4),
              run5('zero1', True, microbatches=4))
print('ERR zero1', err)
assert err < 1e-5, err
""")


# --------------------------------------------------------------------------
# HLO inspection: async -start/-done pairs in the dry-run lowering
# --------------------------------------------------------------------------

def test_hlo_async_pairs_when_overlap_on():
    """Acceptance: the lowered HLO of an overlap=True step asyncifies
    into >= 2 all-reduce-start/-done pairs; the barrier-chained serial
    schedule of the SAME buckets admits none."""
    run_with_devices(COMMON.format(**SINGLE) + """
def pairs(strategy, overlap):
    step, s = make(strategy, overlap)
    hlo = lowered_hlo_text(step.lower(s, batch))
    txt, rep = asyncify_hlo(hlo)
    return txt, rep

txt, rep = pairs('bucketed', True)
print('overlap pairs', rep['pairs'], rep['by_kind'])
assert rep['pairs'] >= 2, rep
assert rep['by_kind'].get('all-reduce', 0) >= 2, rep
assert txt.count('all-reduce-start(') == txt.count('all-reduce-done(')
assert txt.count('all-reduce-start(') >= 2

stxt, srep = pairs('bucketed', 'serial')
print('serial pairs', srep['pairs'])
assert srep['pairs'] == 0, srep
assert 'all-reduce-start(' not in stxt
""")


def test_hlo_async_pairs_zero1_reduce_scatter():
    """zero1 overlap splits into reduce-scatter and all-gather pairs;
    the pipelined microbatch scan overlaps the reduce-scatter with the
    next microbatch's backward matmuls inside the scan body."""
    run_with_devices(COMMON.format(**SINGLE) + """
def rep_of(overlap, microbatches=1, strategy='zero1'):
    step, s = make(strategy, overlap, microbatches)
    hlo = lowered_hlo_text(step.lower(s, batch))
    return asyncify_hlo(hlo)

txt, rep = rep_of(True)
print('zero1 overlap', rep['pairs'], rep['by_kind'])
assert rep['by_kind'].get('reduce-scatter', 0) >= 2, rep
assert rep['by_kind'].get('all-gather', 0) >= 2, rep
assert 'reduce-scatter-start(' in txt and 'reduce-scatter-done(' in txt

stxt, srep = rep_of('serial')
print('zero1 serial', srep['pairs'])
assert srep['pairs'] == 0, srep

mtxt, mrep = rep_of(True, microbatches=4)
print('zero1 mb4', mrep['pairs'], mrep['by_kind'])
assert mrep['by_kind'].get('reduce-scatter', 0) >= 1, mrep

# zero2's pipelined scan rides each microbatch's reduce-scatter behind
# the next backward
ztxt, zrep = rep_of(True, microbatches=4, strategy='zero2')
print('zero2 mb4', zrep['pairs'], zrep['by_kind'])
assert zrep['by_kind'].get('reduce-scatter', 0) >= 1, zrep
""")


# --------------------------------------------------------------------------
# bucket partition properties
# --------------------------------------------------------------------------

@pytest.mark.parametrize("total,align,bucket_bytes", [
    (178_110, 8, 1 << 16), (64, 8, 1 << 30), (7, 4, 16),
    (1 << 20, 1, 1 << 18), (8, 8, 4), (513, 8, 512),
])
def test_plan_buckets_roundtrip(total, align, bucket_bytes):
    from repro.core import plan_buckets
    plan = plan_buckets(total, bucket_bytes=bucket_bytes, align=align)
    assert plan.total == total
    assert plan.padded_total == total + (-total) % align
    assert plan.starts[0] == 0
    # buckets tile [0, padded_total) exactly, aligned
    off = 0
    for s, ln in zip(plan.starts, plan.lengths):
        assert s == off and ln > 0 and ln % align == 0
        off += ln
    assert off == plan.padded_total
    # slices of a padded vector reassemble bit-for-bit
    v = np.arange(plan.padded_total, dtype=np.float32)
    parts = [v[s:s + ln] for s, ln in zip(plan.starts, plan.lengths)]
    np.testing.assert_array_equal(np.concatenate(parts), v)
    # bucket-major shard layout covers padded_total // align
    offs, shard_len = plan.shard_offsets(align)
    assert shard_len == plan.padded_total // align
    assert offs[0] == 0 and len(offs) == plan.n_buckets


def test_plan_buckets_per_leaf():
    from repro.core import plan_buckets
    sizes = [200, 784 * 200, 100, 200 * 100, 10, 100 * 10]
    plan = plan_buckets(sum(sizes), bucket_bytes=1, leaf_sizes=sizes)
    assert plan.n_buckets == len(sizes)
    assert plan.lengths == tuple(sizes)
    assert plan.padded_total == sum(sizes)
    with pytest.raises(ValueError):
        plan_buckets(10, bucket_bytes=1, align=4, leaf_sizes=[10])


def test_plan_buckets_empty_rejected():
    from repro.core import plan_buckets
    with pytest.raises(ValueError):
        plan_buckets(0, bucket_bytes=1024)


# --------------------------------------------------------------------------
# asyncify_hlo unit behaviour on a handcrafted module
# --------------------------------------------------------------------------

_TOY_HLO = """HloModule toy

ENTRY main {
  p0 = f32[4096] parameter(0)
  p1 = f32[4096] parameter(1)
  ar.1 = f32[4096] all-reduce(p0), to_apply=add
  dot.1 = f32[4096] dot(p1, p1)
  add.1 = f32[4096] add(ar.1, dot.1)
  ROOT t = (f32[4096]) tuple(add.1)
}
"""

_TOY_SERIAL = """HloModule toy_serial

ENTRY main {
  p0 = f32[4096] parameter(0)
  ar.1 = f32[4096] all-reduce(p0), to_apply=add
  add.1 = f32[4096] add(ar.1, ar.1)
  ar.2 = f32[4096] all-reduce(add.1), to_apply=add
  ROOT add.2 = f32[4096] add(ar.2, ar.2)
}
"""


def test_asyncify_hlo_splits_overlappable_collective():
    from repro.core import asyncify_hlo
    txt, rep = asyncify_hlo(_TOY_HLO, min_bytes=1024)
    assert rep["pairs"] == 1 and rep["collectives"] == 1
    lines = txt.splitlines()
    i_start = next(i for i, l in enumerate(lines) if "all-reduce-start(" in l)
    i_dot = next(i for i, l in enumerate(lines) if " dot(" in l)
    i_done = next(i for i, l in enumerate(lines) if "all-reduce-done(" in l)
    # the done lands after the hidden compute, right before its user
    assert i_start < i_dot < i_done
    assert "ar.1 = f32[4096] all-reduce-done(all-reduce-start.ar.1)" in txt


def test_asyncify_hlo_serial_chain_untouched():
    from repro.core import asyncify_hlo
    txt, rep = asyncify_hlo(_TOY_SERIAL, min_bytes=1024)
    assert rep["pairs"] == 0 and rep["collectives"] == 2
    assert txt == _TOY_SERIAL


def test_asyncify_hlo_min_bytes_filter():
    from repro.core import asyncify_hlo
    small = _TOY_HLO.replace("f32[4096]", "f32[8]")
    txt, rep = asyncify_hlo(small, min_bytes=1024)
    assert rep["pairs"] == 0 and rep["collectives"] == 0
    assert txt == small


# --------------------------------------------------------------------------
# perf model: overlapped_step_time
# --------------------------------------------------------------------------

def test_overlapped_step_time_one_bucket_equals_serial():
    from repro.core import perf_model
    kw = dict(p=16, n_buckets=1, fabric=perf_model.TPU_V5E_ICI)
    for strat in ("flat", "bucketed", "zero1"):
        t_s = perf_model.serial_step_time(0.1, 4e9, strategy=strat, **kw)
        t_o = perf_model.overlapped_step_time(0.1, 4e9, strategy=strat, **kw)
        assert abs(t_s - t_o) < 1e-12, (strat, t_s, t_o)


def test_overlapped_never_slower_than_serial():
    from repro.core import perf_model
    for p in (2, 8, 64):
        for n_buckets in (1, 2, 8, 32, 128):
            for t_comp in (0.0, 1e-3, 0.1, 10.0):
                for v in (4e6, 4e9, 4e11):
                    for strat in ("flat", "zero1"):
                        kw = dict(p=p, n_buckets=n_buckets,
                                  fabric=perf_model.INFINIBAND_FDR,
                                  strategy=strat)
                        t_s = perf_model.serial_step_time(t_comp, v, **kw)
                        t_o = perf_model.overlapped_step_time(
                            t_comp, v, **kw)
                        assert t_o <= t_s + 1e-12, (p, n_buckets, t_comp,
                                                    v, strat, t_o, t_s)
                        assert perf_model.overlap_speedup(
                            t_comp, v, **kw) >= 1.0 - 1e-12


def test_bucket_comm_time_zero1_consistency():
    """strategy='zero1' per-bucket wire time IS zero1_comm_time, and at
    t_compute=0, n_buckets=1 the overlapped step degenerates to it."""
    from repro.core import perf_model
    v, p = 4 * 33.3e9, 16
    fab = perf_model.TPU_V5E_ICI
    assert perf_model.bucket_comm_time(v, p=p, fabric=fab,
                                       strategy="zero1") \
        == perf_model.zero1_comm_time(v, p=p, fabric=fab)
    t = perf_model.overlapped_step_time(0.0, v, p=p, n_buckets=1,
                                        fabric=fab, strategy="zero1")
    assert abs(t - perf_model.zero1_comm_time(v, p=p, fabric=fab)) < 1e-12
    # single worker: no wire at all
    assert perf_model.bucket_comm_time(v, p=1, fabric=fab) == 0.0


# --------------------------------------------------------------------------
# benchmark scenario
# --------------------------------------------------------------------------

def test_benchmark_overlap_scenario_runs():
    spec = importlib.util.spec_from_file_location(
        "bench_run", os.path.join(ROOT, "benchmarks", "run.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rows = mod.bench_overlap(quick=True)
    assert rows[0][0] == "overlap_sched" and rows[0][1] > 0
    assert "overlapped=" in rows[0][2]
    assert rows[1][0] == "overlap_serial_ref"
