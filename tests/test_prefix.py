"""Prefix/radix cache: refcounted page sharing over the paged pool.

Bookkeeping first (refcounts, COW, double-free, the page-0 invariant),
then the serving guarantee: greedy outputs with the cache ON are
bitwise-equal to the cache-OFF scheduler — aliasing changes WHEN pages
are written, never WHAT a request reads.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import init_model
from repro.serve import ContinuousScheduler, PagedKVCache, PrefixCache

KEY = jax.random.PRNGKey(7)


def _cfg(arch="qwen3-1.7b", **kw):
    return smoke_config(arch).with_overrides(dtype="float32", **kw)


def _rand_prompt(seed, n, vocab):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, vocab))


def _pool(slots=2, page_size=4, num_pages=12, max_len=32):
    return PagedKVCache(_cfg(), slots=slots, max_len=max_len,
                        page_size=page_size, num_pages=num_pages)


# --------------------------------------------------------------------------
# refcount bookkeeping (host-side, no model passes)
# --------------------------------------------------------------------------

def test_refcounts_alias_and_tree_survival():
    kv = _pool()
    px = PrefixCache(kv)
    prompt = np.arange(8, dtype=np.int32)        # 2 full pages of 4
    kv.alloc(0, 8)
    owned = list(kv._owned[0])
    px.insert(prompt, owned)                     # tree takes +1 each
    assert all(kv._refs[p] == 2 for p in owned)
    kv.free(0)                                   # slot drops its refs...
    assert all(kv._refs[p] == 1 for p in owned)  # ...pages survive (tree)
    assert sorted(px.pages()) == sorted(owned)

    n_tok, pages = px.match(prompt)
    assert (n_tok, pages) == (8, owned)
    kv.alias(1, pages)                           # admission: +1 per page
    assert all(kv._refs[p] == 2 for p in owned)
    assert kv._owned[1] == owned
    assert list(kv._table[1][:2]) == owned
    kv.free(1)                                   # decrements, not releases
    assert all(kv._refs[p] == 1 for p in owned)
    assert sorted(px.pages()) == sorted(owned)


def test_release_to_zero_returns_page_and_double_free_raises():
    kv = _pool()
    kv.alloc(0, 4)
    page = kv._owned[0][0]
    free0 = kv.free_pages
    kv.free(0)
    assert kv.free_pages == free0 + 1            # back on the free list
    with pytest.raises(ValueError, match="double free"):
        kv.release(page)


def test_page0_never_enters_tree_or_refcounts():
    kv = _pool()
    px = PrefixCache(kv)
    with pytest.raises(ValueError, match="page 0"):
        kv.retain(0)
    with pytest.raises(ValueError, match="page 0"):
        px.insert(np.arange(4, dtype=np.int32), [0])
    assert px.nodes == 0 and px.pages() == []


def test_cow_fork_copies_bytes_and_leaves_shared_page_untouched():
    kv = _pool()
    ps = kv.page_size
    kv.alloc(0, 8)
    shared = kv._owned[0][1]

    def tok_axis(x):
        return 0 if x.shape[0] == kv.num_pages * ps else 1

    # stamp recognisable bytes into the shared page on every pooled leaf
    import jax.numpy as jnp

    def stamp(x, ax):
        if ax >= 0:
            return x
        t = tok_axis(x)
        rows = jnp.ones((ps,) + x.shape[t + 1:], x.dtype) * 7.5
        if t == 1:
            rows = jnp.broadcast_to(rows[None], (x.shape[0],) + rows.shape)
        return jax.lax.dynamic_update_slice_in_dim(x, rows, shared * ps,
                                                   axis=t)
    kv.cache = jax.tree_util.tree_map(stamp, kv.cache, kv.slot_axis)

    kv.retain(shared)                    # simulate a second holder
    new = kv.cow_fork(0, 1)
    assert new != shared
    assert kv._owned[0][1] == new and kv._table[0, 1] == new
    assert kv._refs[shared] == 1         # slot's ref moved off the original
    assert kv._refs[new] == 1
    for leaf, ax in zip(jax.tree_util.tree_leaves(kv.cache),
                        jax.tree_util.tree_leaves(kv.slot_axis)):
        if ax >= 0:
            continue
        t = tok_axis(leaf)
        sl = [slice(None)] * leaf.ndim
        sl[t] = slice(new * ps, (new + 1) * ps)
        got = np.asarray(leaf[tuple(sl)])
        np.testing.assert_array_equal(got, np.full_like(got, 7.5))
        sl[t] = slice(shared * ps, (shared + 1) * ps)
        orig = np.asarray(leaf[tuple(sl)])
        np.testing.assert_array_equal(orig, np.full_like(orig, 7.5))


def test_match_requires_full_pages_from_root():
    kv = _pool()
    px = PrefixCache(kv)
    prompt = np.arange(8, dtype=np.int32)
    kv.alloc(0, 8)
    px.insert(prompt, kv._owned[0])
    assert px.match(prompt[:3])[0] == 0          # no full page -> no match
    shifted = prompt + 1
    assert px.match(shifted)[0] == 0             # mid-prompt never shared
    assert px.match(np.concatenate([prompt, prompt]))[0] == 8


def test_eviction_lru_leaf_only_and_alias_protection():
    kv = _pool(num_pages=12)
    px = PrefixCache(kv)
    a = np.arange(8, dtype=np.int32)
    b = np.arange(100, 108, dtype=np.int32)
    kv.alloc(0, 8)
    px.insert(a, kv._owned[0])
    kv.free(0)
    kv.alloc(0, 8)
    px.insert(b, kv._owned[0])
    kv.free(0)
    px.match(a)                                  # touch a: b is now LRU
    free0 = kv.free_pages
    assert px.evict_one()
    assert kv.free_pages == free0 + 1
    assert px.match(b)[0] == 4                   # only b's LEAF went
    # aliased pages survive eviction: only the tree's ref is dropped
    n, pages = px.match(a)
    kv.alias(1, pages)
    while px.evict_one():
        pass
    assert px.nodes == 0
    assert all(kv._refs[p] == 1 for p in pages)  # slot 1 still holds them
    kv.free(1)


def test_prefix_cache_refuses_ssm_hybrid():
    cfg = _cfg("jamba-v0.1-52b")
    params = init_model(cfg, KEY)
    with pytest.raises(ValueError, match="attention/MLA-only"):
        ContinuousScheduler(cfg, params, slots=1, max_len=32,
                            page_size=8, prefix_cache=True)


# --------------------------------------------------------------------------
# serving equivalence: cache on == cache off, bitwise (greedy)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-moe-16b"])
def test_scheduler_prefix_bitwise_vs_uncached(arch):
    """Staggered shared-prefix traffic: requests share a 2-page template
    with distinct suffixes (partial match), plus an exact repeat (full
    match — the COW-fork path).  Greedy outputs must be bitwise-equal
    to the cache-less scheduler."""
    cfg = _cfg(arch)
    params = init_model(cfg, KEY)
    shared = _rand_prompt(9, 16, cfg.vocab_size)
    rng = np.random.default_rng(3)
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, 3 + i)
                               .astype(np.int32)])
               for i in range(3)]
    prompts.append(prompts[0].copy())            # exact repeat: full match

    kw = dict(slots=2, max_len=64, page_size=8, prefill_chunk=8,
              decode_chunk=4, num_pages=40)
    on = ContinuousScheduler(cfg, params, prefix_cache=True, **kw)
    off = ContinuousScheduler(cfg, params, **kw)
    got = on.generate(prompts, 6)
    ref = off.generate(prompts, 6)
    for i, (g, r) in enumerate(zip(got, ref)):
        np.testing.assert_array_equal(g, r, err_msg=f"request {i}")
    st = on.stats()
    assert st["prefix_hit_rate"] > 0
    assert st["prefix_cache"]["nodes"] > 0


def test_full_match_cow_repeat_is_bitwise_stable():
    """Regression: an identical page-aligned prompt served twice from
    the same cached scheduler.  The second pass aliases every prompt
    page and COW-forks the last one to re-write its final token — the
    fork must copy the page on the pool's TOKEN axis (scanned
    super-block leaves carry a leading n_rep axis), or the forked page
    serves garbage keys."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    pa = _rand_prompt(5, 16, cfg.vocab_size)     # 2 full pages of 8
    s = ContinuousScheduler(cfg, params, slots=1, max_len=64, page_size=8,
                            prefill_chunk=8, decode_chunk=4,
                            prefix_cache=True, num_pages=32)
    o1 = s.generate([pa], 5)
    o2 = s.generate([pa], 5)
    np.testing.assert_array_equal(o1[0], o2[0])
    # the repeat matched both pages and prefilled only the final token
    assert s.stats()["prefix_hit_tokens"] == 15
    # pool bookkeeping is clean: only the tree holds the prompt pages
    assert all(r == 1 for r in s.kv._refs.values())


def test_prefix_eviction_under_pool_pressure_stays_correct():
    """A pool too small to cache every distinct prompt: admission evicts
    LRU chains to make room, and outputs still match the uncached
    scheduler bitwise."""
    cfg = _cfg()
    params = init_model(cfg, KEY)
    prompts = [_rand_prompt(20 + i, 16, cfg.vocab_size) for i in range(5)]
    kw = dict(slots=1, max_len=32, page_size=8, prefill_chunk=8,
              decode_chunk=4)
    on = ContinuousScheduler(cfg, params, prefix_cache=True,
                             num_pages=11, **kw)
    off = ContinuousScheduler(cfg, params, num_pages=11, **kw)
    got = on.generate(prompts, 4)
    ref = off.generate(prompts, 4)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)
    assert on.prefix.evictions > 0
