"""Batched serving example: prefill a batch of prompts, decode with a
KV cache, greedy sampling — the decode_32k shape at toy scale.

    PYTHONPATH=src python examples/serve_batched.py --arch jamba-v0.1-52b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, smoke_config
from repro.data import synthetic_tokens
from repro.models import init_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=[a for a in sorted(ARCHITECTURES)
                             if ARCHITECTURES[a].frontend == "none"
                             and not ARCHITECTURES[a].is_encoder_decoder])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).with_overrides(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    prompts = synthetic_tokens(key, args.batch, args.prompt_len,
                               cfg.vocab_size)

    eng = ServeEngine(cfg, params, batch_size=args.batch,
                      max_len=args.prompt_len + args.new_tokens,
                      dtype=jnp.float32)
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"arch={args.arch} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"generated in {dt:.2f}s ({tps:.1f} tok/s incl. compile)")
    for i, row in enumerate(out.tolist()):
        print(f"  seq{i}: {row}")


if __name__ == "__main__":
    main()
