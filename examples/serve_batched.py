"""Continuous-batching serving example: a queue of mixed-length
requests streams through a fixed number of slots over a paged KV
cache — admission on retirement, chunked prefill, fused decode — and
the per-request outputs match solo generation exactly (greedy).

    PYTHONPATH=src python examples/serve_batched.py --arch jamba-v0.1-52b
    PYTHONPATH=src python examples/serve_batched.py --legacy   # lockstep ref
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, smoke_config
from repro.data import synthetic_tokens
from repro.models import init_model
from repro.serve import ContinuousScheduler, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=[a for a in sorted(ARCHITECTURES)
                             if ARCHITECTURES[a].frontend == "none"
                             and not ARCHITECTURES[a].is_encoder_decoder])
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--legacy", action="store_true",
                    help="run the lockstep ServeEngine reference instead")
    args = ap.parse_args()

    cfg = smoke_config(args.arch).with_overrides(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)

    if args.legacy:
        prompts = synthetic_tokens(key, args.slots, 16, cfg.vocab_size)
        eng = ServeEngine(cfg, params, batch_size=args.slots, max_len=64,
                          dtype=jnp.float32)
        t0 = time.time()
        out = eng.generate(prompts, max_new_tokens=args.new_tokens)
        dt = time.time() - t0
        print(f"legacy lockstep: {args.slots} seqs x {args.new_tokens} "
              f"tokens in {dt:.2f}s")
        for i, row in enumerate(np.asarray(out).tolist()):
            print(f"  seq{i}: {row}")
        return

    # mixed-length queue: more requests than slots, so later requests
    # are admitted the moment an earlier one retires
    lengths = [5 + 7 * (i % 3) for i in range(args.requests)]
    prompts = [np.asarray(synthetic_tokens(
        jax.random.PRNGKey(i), 1, L, cfg.vocab_size))[0]
        for i, L in enumerate(lengths)]
    # max_len gives every slot 256 tokens of long-context HEADROOM, but
    # the pool only holds pages for what is actually live: this is the
    # paged-cache HBM story (a slab would reserve slots x 256 up front)
    bs = args.page_size
    live = max(lengths) + args.new_tokens + 4
    num_pages = args.slots * (-(-live // bs)) + 1
    sched = ContinuousScheduler(
        cfg, params, slots=args.slots, max_len=256, page_size=bs,
        num_pages=num_pages, prefill_chunk=16, decode_chunk=4)
    t0 = time.time()
    outs = sched.generate(prompts, args.new_tokens)
    dt = time.time() - t0
    st = sched.stats()
    n_tok = sum(len(o) for o in outs)
    print(f"arch={args.arch} (reduced) slots={args.slots} "
          f"requests={args.requests} prompts={lengths}")
    print(f"generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile; "
          f"{st['syncs_per_token']:.3f} host syncs/token; "
          f"ttft {min(st['ttft_s'])*1e3:.0f}-{max(st['ttft_s'])*1e3:.0f}ms)")
    for i, row in enumerate(outs):
        print(f"  req{i} (prompt {lengths[i]:2d}): {row.tolist()}")
    print(f"paged pool: {st['pool_bytes']/1e6:.2f} MB resident vs "
          f"{st['slab_bytes_equiv']/1e6:.2f} MB static-slab equivalent")


if __name__ == "__main__":
    main()
