"""Quickstart: build an architecture, train a few steps, generate.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]

Uses the reduced (smoke) variant of the chosen architecture so it runs
in seconds on CPU; the same code drives the full config on a TPU mesh.

Training goes through the ``repro.api.Trainer`` facade — the single
entry point that hides strategy resolution, TrainState construction
and sharded checkpointing.  Swapping ``DPConfig(strategy=...)`` for
any registered strategy ("flat", "zero1", ..., "zero1_hier", or your
own ``register_strategy``'d one) is the only change distribution needs
— the paper's user-transparency claim as an API.
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro import optim
from repro.api import Trainer
from repro.configs import ARCHITECTURES, smoke_config
from repro.core import DPConfig
from repro.data import synthetic_tokens
from repro.launch.mesh import make_host_mesh
from repro.models import init_model, apply_model
from repro.serve.engine import ServeEngine
from repro.train.loss import lm_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--dp-strategy", default="flat",
                    help="any registered strategy name "
                         "(repro.core.available_strategies())")
    args = ap.parse_args()

    cfg = smoke_config(args.arch).with_overrides(dtype="float32")
    print(f"arch={cfg.name} (reduced): {cfg.num_layers}L d={cfg.d_model} "
          f"family={cfg.family}")
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n/1e6:.2f}M")

    toks = synthetic_tokens(key, 4, 64, cfg.vocab_size)
    batch = ({"tokens": toks} if cfg.frontend == "none"
             and not cfg.is_encoder_decoder else None)
    if batch is None:
        if cfg.is_encoder_decoder:
            batch = {"src_embeds": jax.random.normal(
                key, (4, 64, cfg.d_model)), "tgt_tokens": toks}
        else:
            batch = {"tokens": toks[:, :48],
                     "vision_embeds": jax.random.normal(
                         key, (4, cfg.num_frontend_tokens, 1024))}

    # --- Trainer quickstart: the one-object training surface ---------
    # strategy, state layout, checkpointing and the perf model all live
    # behind Trainer; change dp.strategy and nothing else changes.
    def loss_fn(p, b):
        out = apply_model(cfg, p, b, mode="train")
        return lm_loss(cfg, out, b)[0]

    ndev = len(jax.devices())
    workers = 4 if ndev >= 4 else (2 if ndev >= 2 else 1)  # batch of 4
    trainer = Trainer.create(
        loss_fn=loss_fn, params=params, optimizer=optim.adam(1e-3),
        dp=DPConfig(sync="grads", strategy=args.dp_strategy),
        mesh=make_host_mesh(workers))
    desc = trainer.describe()
    print(f"trainer: strategy={desc['strategy']} "
          f"world={desc['world_size']} "
          f"opt_bytes/dev={desc['memory_per_device_bytes']['opt_state']:.0f}")

    t0 = time.time()
    for i in range(args.steps):
        metrics = trainer.step(batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")
    params = trainer.params          # full pytree, whatever the layout

    if cfg.frontend == "none" and not cfg.is_encoder_decoder:
        eng = ServeEngine(cfg, params, batch_size=2, max_len=96,
                          dtype=jnp.float32)
        out = eng.generate(toks[:2, :16], max_new_tokens=8)
        print("generated token ids:", out.tolist())


if __name__ == "__main__":
    main()
