"""Quickstart: build an architecture, train a few steps, generate.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]

Uses the reduced (smoke) variant of the chosen architecture so it runs
in seconds on CPU; the same code drives the full config on a TPU mesh.
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro import optim
from repro.configs import ARCHITECTURES, smoke_config
from repro.data import synthetic_tokens
from repro.models import init_model, apply_model
from repro.serve.engine import ServeEngine
from repro.train.loss import lm_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).with_overrides(dtype="float32")
    print(f"arch={cfg.name} (reduced): {cfg.num_layers}L d={cfg.d_model} "
          f"family={cfg.family}")
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n/1e6:.2f}M")

    toks = synthetic_tokens(key, 4, 64, cfg.vocab_size)
    batch = ({"tokens": toks} if cfg.frontend == "none"
             and not cfg.is_encoder_decoder else None)
    if batch is None:
        if cfg.is_encoder_decoder:
            batch = {"src_embeds": jax.random.normal(
                key, (4, 64, cfg.d_model)), "tgt_tokens": toks}
        else:
            batch = {"tokens": toks[:, :48],
                     "vision_embeds": jax.random.normal(
                         key, (4, cfg.num_frontend_tokens, 1024))}

    opt = optim.adam(1e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            out = apply_model(cfg, p, batch, mode="train")
            return lm_loss(cfg, out, batch)[0]
        l, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        return params, state, l

    t0 = time.time()
    for i in range(args.steps):
        params, state, loss = step(params, state)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss {float(loss):.4f}")
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")

    if cfg.frontend == "none" and not cfg.is_encoder_decoder:
        eng = ServeEngine(cfg, params, batch_size=2, max_len=96,
                          dtype=jnp.float32)
        out = eng.generate(toks[:2, :16], max_new_tokens=8)
        print("generated token ids:", out.tolist())


if __name__ == "__main__":
    main()
