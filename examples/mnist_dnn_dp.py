"""End-to-end driver of the paper's own experiment (Figure 1):
MNIST-DNN (784-200-100-10) trained with synchronous data-parallel
allreduce across p workers, with the full pipeline — rank-0 scatter,
per-step gradient averaging, checkpointing, restart.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/mnist_dnn_dp.py --workers 8

On real hardware the same script runs across a TPU slice: only the mesh
construction changes (launch/mesh.py).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.api import Trainer
from repro.compat import auto_axis_types, make_mesh
from repro.configs.paper_nets import MNIST_DNN
from repro.core import DPConfig, available_strategies
from repro.data import make_dataset
from repro.data.pipeline import ShardedLoader
from repro.models import init_paper_net, apply_paper_net


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = all available devices")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--samples", type=int, default=8192)
    ap.add_argument("--strategy", default="flat",
                    choices=sorted(available_strategies()))
    ap.add_argument("--pods", type=int, default=1,
                    help=">1 builds a (pod, data) mesh — the multi-pod "
                         "layout zero1_hier / hierarchical stage their "
                         "collectives over")
    ap.add_argument("--sync", default="grads", choices=["grads", "weights"])
    ap.add_argument("--sync-period", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_mnist_ckpt")
    args = ap.parse_args()

    p = args.workers or len(jax.devices())
    if args.pods > 1:
        if p % args.pods:
            ap.error(f"--pods {args.pods} must divide the {p} workers")
        mesh = make_mesh((args.pods, p // args.pods), ("pod", "data"),
                         axis_types=auto_axis_types(2))
    else:
        mesh = make_mesh((p,), ("data",), axis_types=auto_axis_types(1))
    print(f"mesh: {p} data-parallel workers (paper's replicated-model DP)")

    net = MNIST_DNN
    ds = make_dataset("mnist", n=args.samples)
    loader = ShardedLoader({"x": ds.x, "y": ds.y}, args.batch, mesh=mesh)

    def loss_fn(params, b):
        lg = apply_paper_net(net, params, b["x"])
        n = lg.shape[0]
        return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(n), b["y"]])

    key = jax.random.PRNGKey(0)
    dp = DPConfig(sync=args.sync, sync_period=args.sync_period,
                  strategy=args.strategy)
    trainer = Trainer.create(loss_fn=loss_fn,
                             params=init_paper_net(net, key),
                             optimizer=optim.momentum(0.2, 0.9), dp=dp,
                             mesh=mesh)
    print("trainer:", trainer.describe())

    for epoch in range(args.epochs):
        t0 = time.time()
        losses = []
        for batch in loader.epoch(epoch):
            losses.append(float(trainer.step(batch)["loss"]))
        # eval (trainer.params reassembles zero3's flat shards on host)
        logits = apply_paper_net(net, trainer.params,
                                 jnp.asarray(ds.x[:1024]))
        acc = float(jnp.mean(jnp.argmax(logits, -1)
                             == jnp.asarray(ds.y[:1024])))
        print(f"epoch {epoch}: loss {np.mean(losses):.4f}  acc {acc:.3f}  "
              f"({time.time()-t0:.1f}s)")
        trainer.save(args.ckpt)

    # restart demo (the paper's ULFM story: reload + continue) — a fresh
    # trainer is the template; restore streams each worker's own shards
    fresh = Trainer.create(loss_fn=loss_fn,
                           params=init_paper_net(net, key),
                           optimizer=optim.momentum(0.2, 0.9), dp=dp,
                           mesh=mesh)
    at = fresh.restore(args.ckpt)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree_util.tree_leaves(fresh.state.params),
                  jax.tree_util.tree_leaves(trainer.state.params)))
    print(f"restart: restored step {at} OK (max|Δ|={err:.1e})")


if __name__ == "__main__":
    main()
