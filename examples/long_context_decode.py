"""Long-context decode: why the long_500k shape is native for SSM/hybrid
architectures — the recurrent state is O(1) in context length while a
dense transformer's KV cache grows linearly.

Feeds a long prompt through rwkv6/jamba (reduced) in CHUNKS (prefill
extends the state, not a cache), then decodes; prints the state/cache
memory a dense model would need at the same context.

    PYTHONPATH=src python examples/long_context_decode.py --context 4096
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import smoke_config, get_config
from repro.data import synthetic_tokens
from repro.models import init_model, apply_model, init_cache


def state_bytes(tree):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b",
                    choices=["rwkv6-1.6b", "jamba-v0.1-52b"])
    ap.add_argument("--context", type=int, default=4096)
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).with_overrides(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    prompt = synthetic_tokens(key, 1, args.context, cfg.vocab_size)

    # SSM state is allocated once; attention layers (jamba) still keep a
    # cache, sized to the full context
    cache = init_cache(cfg, 1, args.context + args.new_tokens, jnp.float32)
    print(f"{args.arch} (reduced): context={args.context}")
    print(f"  recurrent-state+cache bytes: {state_bytes(cache)/1e6:.1f} MB")

    # chunked prefill: state carries across chunks
    t0 = time.time()
    pos = 0
    for s in range(0, args.context, args.chunk):
        toks = prompt[:, s:s + args.chunk]
        out = apply_model(cfg, params, {"tokens": toks},
                          mode="prefill" if s == 0 else "decode",
                          cache=cache, cache_pos=pos)
        cache = out["cache"]
        pos += toks.shape[1]
    print(f"  prefilled {pos} tokens in {time.time()-t0:.1f}s "
          f"(chunked, state carried)")

    tok = jnp.argmax(out["logits"][:, -1], axis=-1)[:, None]
    gen = [int(tok[0, 0])]
    for _ in range(args.new_tokens - 1):
        out = apply_model(cfg, params, {"tokens": tok}, mode="decode",
                          cache=cache, cache_pos=pos)
        cache = out["cache"]
        pos += 1
        tok = jnp.argmax(out["logits"][:, -1], axis=-1)[:, None]
        gen.append(int(tok[0, 0]))
    print(f"  decoded: {gen}")

    # compare: a dense transformer KV cache at the FULL config scale —
    # as a static slab (every slot reserves max_len) and as the paged
    # pool the serving subsystem actually allocates (docs/serving.md):
    # pages for the tokens that exist, page 0 reserved as trash
    from repro.core import perf_model
    full = get_config("deepseek-coder-33b")
    tok_bytes = perf_model.kv_bytes_per_token(full)  # bf16
    slab = args.context * tok_bytes
    # 8 serving slots at mixed depths, each with this context as headroom
    contexts = [args.context * (i + 1) // 8 for i in range(8)]
    paged = perf_model.paged_pool_bytes(contexts, 16, tok_bytes)
    print(f"  [contrast] deepseek-coder-33b KV at this context: "
          f"{slab/1e6:.1f} MB/sequence slab (vs O(1) SSM state); "
          f"8 mixed-depth serving slots: {8*slab/1e6:.1f} MB slab -> "
          f"{paged/1e6:.1f} MB paged pool")


if __name__ == "__main__":
    main()
